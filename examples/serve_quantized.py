"""Continuous-batching INT8 serving example (wraps the production driver,
which runs the slot-pool engine — see src/repro/serving/):

    PYTHONPATH=src python examples/serve_quantized.py
    PYTHONPATH=src python examples/serve_quantized.py --trace 12 --slots 4

Extra arguments are forwarded to repro.launch.serve and override the
example defaults (argparse last-wins).
"""
import sys

from repro.launch.serve import main

DEFAULTS = [
    "--arch", "qwen2-0.5b", "--smoke", "--quantize", "w8a16",
    "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
]

if __name__ == "__main__":
    main(DEFAULTS + sys.argv[1:])
