"""Batched INT8 serving example (wraps the production driver):

    PYTHONPATH=src python examples/serve_quantized.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen2-0.5b", "--smoke",
                "--quantize", "w8a16", "--batch", "4",
                "--prompt-len", "16", "--gen-len", "16"]
    main()
