"""The paper's experiment, end to end (Tables 1–2 style ablation on the
MobileNetV2-family CNN trained in this repo):

    PYTHONPATH=src python examples/dfq_cnn_repro.py
"""
from benchmarks.tables import table1_cle, table2_bias_correction


def main():
    print("== Table 1 (cross-layer equalization) ==")
    for name, acc in table1_cle():
        print(f"  {name:28s} {acc:.4f}")
    print("== Table 2 (bias correction) ==")
    for name, acc in table2_bias_correction():
        print(f"  {name:28s} {acc:.4f}")


if __name__ == "__main__":
    main()
