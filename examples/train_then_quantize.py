"""End-to-end driver: train an LM for a few hundred steps, then DFQ-quantize
and serve it INT8 — the full deployment lifecycle the paper targets.

Default runs a reduced model sized for this CPU container; pass --full-100m
for the ~100M-parameter configuration (same code, more hours on CPU —
sized for a single accelerator host).

    PYTHONPATH=src python examples/train_then_quantize.py --steps 200
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import sqnr_db
from repro.data import TokenStream, calibration_tokens
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule


def make_cfg(full_100m: bool) -> ModelConfig:
    if full_100m:
        return ModelConfig(
            name="repro-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
            act="silu_glu", norm="rms", dtype="float32", remat=False,
            max_seq=1024)
    return ModelConfig(
        name="repro-8m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, head_dim=64, d_ff=704, vocab_size=4096,
        act="silu_glu", norm="rms", dtype="float32", remat=False,
        max_seq=512, logit_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    cfg = make_cfg(args.full_100m)
    model = build_model(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    stream = TokenStream(0, 0, 1, args.batch, args.seq, cfg.vocab_size)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
        lr = cosine_schedule(opt.step, peak_lr=1e-3, warmup=20, total=args.steps)
        params, opt, _ = adamw_update(grads, opt, params, lr=lr)
        return params, opt, loss

    losses = []
    for s in range(args.steps):
        params, opt, loss = step(params, opt, stream.batch(s))
        losses.append(float(loss))
        if (s + 1) % 25 == 0:
            print(f"step {s+1}: loss {np.mean(losses[-25:]):.4f}")
    print(f"trained: loss {np.mean(losses[:10]):.3f} → {np.mean(losses[-10:]):.3f}")

    # ---- DFQ + INT8 serving: one pipeline call -----------------------------
    qm = repro.quantize(model, params=params, recipe="serve-w8a16")
    s = qm.serving_summary()
    print(f"INT8 params: {s['int8_bytes']/1e6:.1f} MB "
          f"({s['compression']:.2f}x smaller than fp32)")

    toks = calibration_tokens(5, 4, 64, cfg.vocab_size)
    logits_fp, _ = model.apply(params, toks)
    logits_q, _ = qm.apply(toks)
    print(f"quantized-serving logits SQNR: {float(sqnr_db(logits_fp, logits_q)):.2f} dB")
    agree = float(jnp.mean(jnp.argmax(logits_fp, -1) == jnp.argmax(logits_q, -1)))
    print(f"greedy-token agreement: {agree:.2%}")


if __name__ == "__main__":
    main()
