"""Quickstart: the paper's promise — near-FP32 INT8 with one API call.

    PYTHONPATH=src python examples/quickstart.py

``repro.quantize(arch_or_model, recipe=...)`` is the whole public surface:
a recipe names the stage sequence (see ``repro.pipeline.list_recipes()``),
and the returned ``QuantizedModel`` carries the quantized params, per-stage
diagnostics (``.report``), and the serving entry points
(``.apply``/``.prefill``/``.decode_step``/``.save``).
"""
import jax
import jax.numpy as jnp

import repro
from repro.configs import get_config
from repro.core import sqnr_db
from repro.core.adversarial import hostile_rescale
from repro.data import calibration_tokens
from repro.models import build_model


def main():
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # make the model hostile to per-tensor INT8 (function-preserving rescale)
    params = hostile_rescale(params, model.dfq_plan(), decades=1.2)
    tokens = calibration_tokens(0, 2, 32, cfg.vocab_size)
    logits_fp, _ = model.apply(params, tokens)

    # --- naive per-tensor INT8 --------------------------------------------
    naive = repro.quantize(model, params=params, recipe="naive-int8")
    logits_naive, _ = naive.apply(tokens)

    # --- DFQ: one call (fold → CLE → absorb → bias-correct → quant) --------
    dfq = repro.quantize(model, params=params, recipe="dfq-int8")
    logits_dfq, _ = dfq.apply(tokens)

    print(f"naive INT8 logits SQNR: {float(sqnr_db(logits_fp, logits_naive)):6.2f} dB")
    print(f"DFQ   INT8 logits SQNR: {float(sqnr_db(logits_fp, logits_dfq)):6.2f} dB")
    agree_naive = float(jnp.mean(jnp.argmax(logits_fp, -1) == jnp.argmax(logits_naive, -1)))
    agree_dfq = float(jnp.mean(jnp.argmax(logits_fp, -1) == jnp.argmax(logits_dfq, -1)))
    print(f"greedy-token agreement: naive {agree_naive:.2%} → DFQ {agree_dfq:.2%}")
    wq = dfq.stage_record("weight_quant")["metrics"]
    print(f"per-site weight SQNR: min {wq['sqnr_min_db']:.1f} dB, "
          f"mean {wq['sqnr_mean_db']:.1f} dB across {wq['sites']} sites")


if __name__ == "__main__":
    main()
