"""Quickstart: the paper's promise — near-FP32 INT8 with one API call.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import DFQConfig, dfq_quantize, sqnr_db
from repro.data import calibration_tokens
from repro.models import build_model


def main():
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = model.dfq_plan()

    # make the model hostile to per-tensor INT8 (function-preserving rescale)
    from repro.core.adversarial import hostile_rescale

    params = hostile_rescale(params, plan, decades=1.2)
    tokens = calibration_tokens(0, 2, 32, cfg.vocab_size)
    logits_fp, _ = model.apply(params, tokens)

    # --- naive per-tensor INT8 --------------------------------------------
    from repro.core import quantize_weights

    naive = quantize_weights(params, plan, DFQConfig(cle=False, bias_absorb=False))
    logits_naive, _ = model.apply(naive, tokens)

    # --- DFQ: one call (CLE → bias absorption → quant → bias correction) ---
    q = dfq_quantize(
        params, plan, DFQConfig(),
        input_means_fn=lambda p: model.calibration_stats(
            p, calibration_tokens(1, 2, 32, cfg.vocab_size)),
    )
    logits_dfq, _ = model.apply(q, tokens)

    print(f"naive INT8 logits SQNR: {float(sqnr_db(logits_fp, logits_naive)):6.2f} dB")
    print(f"DFQ   INT8 logits SQNR: {float(sqnr_db(logits_fp, logits_dfq)):6.2f} dB")
    agree_naive = float(jnp.mean(jnp.argmax(logits_fp, -1) == jnp.argmax(logits_naive, -1)))
    agree_dfq = float(jnp.mean(jnp.argmax(logits_fp, -1) == jnp.argmax(logits_dfq, -1)))
    print(f"greedy-token agreement: naive {agree_naive:.2%} → DFQ {agree_dfq:.2%}")


if __name__ == "__main__":
    main()
