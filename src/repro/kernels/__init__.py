"""Pallas TPU kernels for the INT8 deployment path DFQ enables.

Three kernels (taxonomy B.12 — W8A8 / weight-only / dynamic-quant):

  * ``qmatmul_w8a8``  — int8×int8 → int32 MXU GEMM, dequant epilogue fused
                        with the DFQ bias-correction term (compute-bound
                        prefill path; int8 doubles v5e MXU peak vs bf16),
  * ``qmatmul_w8a16`` — bf16 activations × int8 weights dequantized in VMEM
                        (memory-bound decode path; halves HBM weight bytes),
  * ``quantize_act``  — fused per-row absmax reduce + scale + round
                        (dynamic activation quantization),
  * ``kv_attention``  — single-token decode attention with the int8 KV cache
                        dequantized in VMEM (one HBM pass over the cache —
                        the EXPERIMENTS §Perf C5 roofline term, fused).
                        Handles GQA (q heads / kv heads via in-kernel
                        reshape), ragged per-slot lengths through zero-scale
                        masking, and ships ``quantize_kv`` /
                        ``kv_attention_decode`` — the fused append-quantize
                        step the serving engine's int8-KV mode decodes
                        through (``ServingEngine(kv_bits=8)`` or a
                        ``serve-*-kv8`` recipe).

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper with padding + XLA fallback), ref.py (pure-jnp oracle).
Kernels VALIDATE in interpret mode on CPU; TPU is the compile target.

``serving_kernel_specs`` / ``lower_serving_kernels`` expose the standalone
kernels to the graph linter (analysis/lint): representative smoke-shape
argument sets, and the traced-but-never-run lowered modules built from them.
"""
from __future__ import annotations


def serving_kernel_specs(*, head_dim: int = 16, n_kv_heads: int = 2,
                         n_q_heads: int = 4, seq: int = 32, batch: int = 2,
                         d_in: int = 64, d_out: int = 128) -> dict:
    """{name: (fn, args, kwargs)} for each standalone serving kernel at a
    representative smoke shape — everything the lint layer needs to trace
    (``jax.make_jaxpr``) or lower (``jax.jit(...).lower``) the kernels
    without running them. Shapes default to the smoke-config attention
    geometry so kernel contracts line up with the engine contracts."""
    import jax.numpy as jnp

    from .kv_attention.ops import kv_attention_decode
    from .qmatmul_w8a8.ops import qmatmul_w8a8
    from .qmatmul_w8a16.ops import qmatmul_w8a16
    from .quantize_act.ops import quantize_act

    B, S, Hq, Hkv, hd = batch, seq, n_q_heads, n_kv_heads, head_dim
    M, K, N = 8, d_in, d_out
    a = jnp.zeros((M, K), jnp.float32)
    w_q = jnp.zeros((K, N), jnp.int8)
    w_scale = jnp.ones((N,), jnp.float32)
    a_q = jnp.zeros((M, K), jnp.int8)
    a_scale = jnp.ones((M,), jnp.float32)
    return {
        "qmatmul_w8a16": (
            qmatmul_w8a16, (a, w_q, w_scale), {"out_dtype": jnp.float32}),
        "qmatmul_w8a8": (
            qmatmul_w8a8, (a_q, w_q, a_scale, w_scale), {}),
        "quantize_act": (quantize_act, (a,), {}),
        "kv_attention_decode": (
            kv_attention_decode,
            (jnp.zeros((B, Hq, hd), jnp.float32),        # q
             jnp.zeros((B, S, Hkv, hd), jnp.int8),       # cache_k
             jnp.ones((B, S, Hkv), jnp.float32),         # cache_ks
             jnp.zeros((B, S, Hkv, hd), jnp.int8),       # cache_v
             jnp.ones((B, S, Hkv), jnp.float32),         # cache_vs
             jnp.zeros((B, 1, Hkv, hd), jnp.float32),    # k_new
             jnp.zeros((B, 1, Hkv, hd), jnp.float32),    # v_new
             jnp.zeros((B, 1), jnp.int32)),              # idx
            {"valid": jnp.ones((B, S), bool)},
        ),
    }


def lower_serving_kernels(**shape_kw) -> dict:
    """{name: jax.stages.Lowered} for every standalone serving kernel —
    traced and lowered (StableHLO), NOT compiled or run."""
    import jax

    out = {}
    for name, (fn, args, kw) in serving_kernel_specs(**shape_kw).items():
        out[name] = jax.jit(lambda *a, _fn=fn, _kw=kw: _fn(*a, **_kw)
                            ).lower(*args)
    return out
