"""Pallas TPU kernels for the INT8 deployment path DFQ enables.

Five ops (taxonomy B.12 — W8A8 / weight-only / dynamic-quant):

  * ``qmatmul_w8a8``  — int8×int8 → int32 MXU GEMM, dequant epilogue fused
                        with the DFQ bias-correction term (compute-bound
                        prefill path; int8 doubles v5e MXU peak vs bf16).
                        ``quantize_out=True`` re-quantizes the output row in
                        the epilogue (int8 + per-row scale out).
  * ``qmatmul_w8a16`` — bf16 activations × int8 weights dequantized in VMEM
                        (memory-bound decode path; halves HBM weight bytes),
                        same ``quantize_out`` epilogue variant.
  * ``quantize_act``  — fused per-row absmax reduce + scale + round
                        (dynamic activation quantization),
  * ``kv_attention``  — single-token decode attention with the int8 KV cache
                        dequantized in VMEM (one HBM pass over the cache —
                        the EXPERIMENTS §Perf C5 roofline term, fused).
                        Handles GQA (q heads / kv heads via in-kernel
                        reshape) and ragged per-slot lengths through
                        zero-scale masking.
  * ``fused_decode``  — the decode megakernel: append-quantize + int8
                        attention (+ optional W8A8 quantize-out epilogue)
                        in ONE ``pallas_call`` with the cache leaves
                        aliased in place — the ``kv_attention_decode``
                        composition collapsed to a single dispatch.

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (public
wrapper), ref.py (pure-jnp oracle). Kernels VALIDATE in interpret mode on
CPU; TPU is the compile target.

Backend selection, padding policy, and the op registry all live in
``dispatch.py`` — every op registers its pallas/xla/interpret/ref tiers
with ``@register_impl`` and resolves through ``dispatch.resolve`` (env
override ``REPRO_KERNEL_BACKEND``). ``serving_kernel_specs`` /
``lower_serving_kernels`` enumerate the registry's ``@register_spec``
entries, so the graph linter (analysis/lint) traces every registered
serving op without a hand-maintained list.
"""
from __future__ import annotations

from . import dispatch


def _import_ops():
    """Importing the op packages populates the dispatch registry."""
    from .fused_decode import ops as _fd          # noqa: F401
    from .kv_attention import ops as _kv          # noqa: F401
    from .qmatmul_w8a8 import ops as _w8a8        # noqa: F401
    from .qmatmul_w8a16 import ops as _w8a16      # noqa: F401
    from .quantize_act import ops as _qa          # noqa: F401


def serving_kernel_specs(*, head_dim: int = 16, n_kv_heads: int = 2,
                         n_q_heads: int = 4, seq: int = 32, batch: int = 2,
                         d_in: int = 64, d_out: int = 128) -> dict:
    """{name: (fn, args, kwargs)} for each registered serving op at a
    representative smoke shape — everything the lint layer needs to trace
    (``jax.make_jaxpr``) or lower (``jax.jit(...).lower``) the kernels
    without running them. Shapes default to the smoke-config attention
    geometry so kernel contracts line up with the engine contracts."""
    _import_ops()
    return dispatch.iter_specs(
        head_dim=head_dim, n_kv_heads=n_kv_heads, n_q_heads=n_q_heads,
        seq=seq, batch=batch, d_in=d_in, d_out=d_out)


def lower_serving_kernels(**shape_kw) -> dict:
    """{name: jax.stages.Lowered} for every registered serving op —
    traced and lowered (StableHLO), NOT compiled or run."""
    import jax

    out = {}
    for name, (fn, args, kw) in serving_kernel_specs(**shape_kw).items():
        out[name] = jax.jit(lambda *a, _fn=fn, _kw=kw: _fn(*a, **_kw)
                            ).lower(*args)
    return out
