"""Pallas TPU kernels for the INT8 deployment path DFQ enables.

Three kernels (taxonomy B.12 — W8A8 / weight-only / dynamic-quant):

  * ``qmatmul_w8a8``  — int8×int8 → int32 MXU GEMM, dequant epilogue fused
                        with the DFQ bias-correction term (compute-bound
                        prefill path; int8 doubles v5e MXU peak vs bf16),
  * ``qmatmul_w8a16`` — bf16 activations × int8 weights dequantized in VMEM
                        (memory-bound decode path; halves HBM weight bytes),
  * ``quantize_act``  — fused per-row absmax reduce + scale + round
                        (dynamic activation quantization),
  * ``kv_attention``  — single-token decode attention with the int8 KV cache
                        dequantized in VMEM (one HBM pass over the cache —
                        the EXPERIMENTS §Perf C5 roofline term, fused).
                        Handles GQA (q heads / kv heads via in-kernel
                        reshape), ragged per-slot lengths through zero-scale
                        masking, and ships ``quantize_kv`` /
                        ``kv_attention_decode`` — the fused append-quantize
                        step the serving engine's int8-KV mode decodes
                        through (``ServingEngine(kv_bits=8)`` or a
                        ``serve-*-kv8`` recipe).

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper with padding + XLA fallback), ref.py (pure-jnp oracle).
Kernels VALIDATE in interpret mode on CPU; TPU is the compile target.
"""
