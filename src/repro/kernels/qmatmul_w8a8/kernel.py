"""Pallas TPU kernel: int8 × int8 → int32 GEMM with fused dequant epilogue.

Tiling: grid (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics) with an
int32 VMEM scratch accumulator; the epilogue (executed on the last K step)
applies per-row activation scales × per-col weight scales and adds the bias —
which, after DFQ, already contains the paper's ε·E[x] bias-correction term,
so correction costs zero extra bandwidth at inference.

Block defaults (bm, bn, bk) = (128, 128, 512) keep the MXU dims at the
native 128 lane width and the working set
  bm·bk (int8) + bk·bn (int8) + bm·bn (int32 acc + fp32 out) ≈ 260 KiB
far under the ~16 MiB v5e VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only submodule; absent on CPU wheels — interpret mode doesn't need it
    from jax.experimental.pallas import tpu as pltpu

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    _SCRATCH = lambda bm, bn: [pltpu.VMEM((bm, bn), jnp.int32)]
    _PARAMS = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda bm, bn: [jax.ShapeDtypeStruct((bm, bn), jnp.int32)]
    _PARAMS = lambda: {}


def _kernel(a_ref, w_ref, sa_ref, sw_ref, bias_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out = acc * sa_ref[...][:, None] * sw_ref[...][None, :]
        out = out + bias_ref[...][None, :]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def qmatmul_w8a8_pallas(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    M, K = a_q.shape
    K2, N = w_q.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=_SCRATCH(bm, bn),
        interpret=interpret,
        **_PARAMS(),
    )(a_q, w_q, a_scale.astype(jnp.float32), w_scale.astype(jnp.float32),
      bias.astype(jnp.float32))
