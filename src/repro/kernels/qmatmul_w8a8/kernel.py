"""Pallas TPU kernel: int8 × int8 → int32 GEMM with fused dequant epilogue.

Tiling: grid (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics) with an
int32 VMEM scratch accumulator; the epilogue (executed on the last K step)
applies per-row activation scales × per-col weight scales and adds the bias —
which, after DFQ, already contains the paper's ε·E[x] bias-correction term,
so correction costs zero extra bandwidth at inference.

Block defaults (bm, bn, bk) = (128, 128, 512) keep the MXU dims at the
native 128 lane width and the working set
  bm·bk (int8) + bk·bn (int8) + bm·bn (int32 acc + fp32 out) ≈ 260 KiB
far under the ~16 MiB v5e VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only submodule; absent on CPU wheels — interpret mode doesn't need it
    from jax.experimental.pallas import tpu as pltpu

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    _SCRATCH = lambda bm, bn: [pltpu.VMEM((bm, bn), jnp.int32)]
    _PARAMS = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    )
    _PARAMS_MK = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda bm, bn: [jax.ShapeDtypeStruct((bm, bn), jnp.int32)]
    _PARAMS = lambda: {}
    _PARAMS_MK = lambda: {}


def _kernel(a_ref, w_ref, sa_ref, sw_ref, bias_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out = acc * sa_ref[...][:, None] * sw_ref[...][None, :]
        out = out + bias_ref[...][None, :]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def qmatmul_w8a8_pallas(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    M, K = a_q.shape
    K2, N = w_q.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=_SCRATCH(bm, bn),
        interpret=interpret,
        **_PARAMS(),
    )(a_q, w_q, a_scale.astype(jnp.float32), w_scale.astype(jnp.float32),
      bias.astype(jnp.float32))


def _kernel_q8(a_ref, w_ref, sa_ref, sw_ref, bias_ref, q_ref, s_ref, acc_ref,
               *, n_k, qmax):
    """Quantize-out epilogue variant: the dequantized row never leaves VMEM —
    the last K step re-quantizes it per-row (the exact ``quantize_act``
    formula) so the next layer's W8A8 GEMM reads int8 straight from here."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out = acc * sa_ref[...][:, None] * sw_ref[...][None, :]
        out = out + bias_ref[...][None, :]
        amax = jnp.max(jnp.abs(out), axis=-1)
        scale = jnp.maximum(amax, 1e-8) / qmax
        q = jnp.clip(jnp.round(out / scale[:, None]), -qmax - 1, qmax)
        q_ref[...] = q.astype(jnp.int8)
        s_ref[...] = scale


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bits", "interpret"),
)
def qmatmul_w8a8_q8_pallas(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bm: int = 128,
    bk: int = 512,
    bits: int = 8,
    interpret: bool = False,
):
    """W8A8 GEMM emitting (int8 out, per-row scale). The N axis is a single
    block (the per-row absmax needs the whole output row in the epilogue),
    so the grid is (M/bm, K/bk) — decode/prefill N fits VMEM comfortably."""
    M, K = a_q.shape
    K2, N = w_q.shape
    assert K == K2 and M % bm == 0 and K % bk == 0
    n_k = K // bk
    qmax = 2 ** (bits - 1) - 1
    grid = (M // bm, n_k)
    return pl.pallas_call(
        functools.partial(_kernel_q8, n_k=n_k, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, N), lambda i, k: (k, 0)),
            pl.BlockSpec((bm,), lambda i, k: (i,)),
            pl.BlockSpec((N,), lambda i, k: (0,)),
            pl.BlockSpec((N,), lambda i, k: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, N), lambda i, k: (i, 0)),
            pl.BlockSpec((bm,), lambda i, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M,), jnp.float32),
        ],
        scratch_shapes=_SCRATCH(bm, N),
        interpret=interpret,
        **_PARAMS_MK(),
    )(a_q, w_q, a_scale.astype(jnp.float32), w_scale.astype(jnp.float32),
      bias.astype(jnp.float32))
