"""Pure-jnp oracle for the W8A8 quantized GEMM."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def qmatmul_w8a8_ref(
    a_q: jnp.ndarray,          # [M, K] int8 (symmetric per-row quantized)
    w_q: jnp.ndarray,          # [K, N] int8 (symmetric)
    a_scale: jnp.ndarray,      # [M] or scalar
    w_scale: jnp.ndarray,      # [N] or scalar
    bias: Optional[jnp.ndarray] = None,  # [N] fp32 (carries DFQ's ε·E[x] term)
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    acc = jnp.matmul(
        a_q.astype(jnp.int32), w_q.astype(jnp.int32)
    )                                                     # exact int32
    out = acc.astype(jnp.float32)
    out = out * jnp.atleast_1d(a_scale)[:, None] * jnp.atleast_1d(w_scale)[None, :]
    if bias is not None:
        out = out + bias[None, :]
    return out.astype(out_dtype)
