"""Pure-jnp oracle for the W8A8 quantized GEMM."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def qmatmul_w8a8_ref(
    a_q: jnp.ndarray,          # [M, K] int8 (symmetric per-row quantized)
    w_q: jnp.ndarray,          # [K, N] int8 (symmetric)
    a_scale: jnp.ndarray,      # [M] or scalar
    w_scale: jnp.ndarray,      # [N] or scalar
    bias: Optional[jnp.ndarray] = None,  # [N] fp32 (carries DFQ's ε·E[x] term)
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    acc = jnp.matmul(
        a_q.astype(jnp.int32), w_q.astype(jnp.int32)
    )                                                     # exact int32
    out = acc.astype(jnp.float32)
    out = out * jnp.atleast_1d(a_scale)[:, None] * jnp.atleast_1d(w_scale)[None, :]
    if bias is not None:
        out = out + bias[None, :]
    return out.astype(out_dtype)


def qmatmul_w8a8_q8_ref(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    bits: int = 8,
):
    """Quantize-out oracle: the fp GEMM result (exact — int32 accumulation)
    re-quantized per-row with the ``quantize_act`` formula. Bit-identical to
    the Pallas epilogue variant AND to the stepwise GEMM → quantize_act
    composition it replaces."""
    from ..quantize_act.ref import quantize_act_ref

    out = qmatmul_w8a8_ref(a_q, w_q, a_scale, w_scale, bias, jnp.float32)
    return quantize_act_ref(out, bits)
