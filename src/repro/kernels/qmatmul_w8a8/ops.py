"""Public W8A8 GEMM op: padding, backend selection, asymmetric handling.

Asymmetric activations are supported by folding the cross terms outside the
MXU loop (DESIGN.md §5):  with a = (a_q − zp)·s_a,
    y = s_a s_w (Σ a_q w_q − zp Σ_k w_q[k,:])
the ``zp·colsum(w_q)`` term is static per output channel → folded into bias.
Weights are symmetric by default (the paper observes CLE makes weight
distributions near-symmetric — Table 7).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import qmatmul_w8a8_pallas
from .ref import qmatmul_w8a8_ref


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def qmatmul_w8a8(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    a_zero_point: Optional[jnp.ndarray] = None,
    *,
    out_dtype=jnp.float32,
    backend: Optional[str] = None,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
):
    """y = dequant(a_q) @ dequant(w_q) + bias.  a_q [M,K] int8, w_q [K,N] int8,
    a_scale [M]|scalar, w_scale [N]|scalar, bias [N]."""
    backend = backend or default_backend()
    M, K = a_q.shape
    N = w_q.shape[1]
    a_scale = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (M,))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (N,))
    bias = jnp.zeros((N,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    if a_zero_point is not None:
        # fold zp·colsum(w) into a per-(row, col) rank-1 correction; since
        # zp is per-row and colsum per-col, we add it post-GEMM (cheap VPU).
        colsum = jnp.sum(w_q.astype(jnp.int32), axis=0).astype(jnp.float32)
        zp_term = (
            jnp.broadcast_to(jnp.asarray(a_zero_point, jnp.float32), (M,))[:, None]
            * colsum[None, :]
            * a_scale[:, None]
            * w_scale[None, :]
        )
    else:
        zp_term = None

    if backend == "xla":
        out = qmatmul_w8a8_ref(a_q, w_q, a_scale, w_scale, bias, out_dtype)
    else:
        bm_e = min(bm, max(8, M))
        a_p = _pad_to(_pad_to(a_q, bm_e, 0), bk, 1)
        w_p = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
        sa_p = _pad_to(a_scale, bm_e, 0)
        sw_p = _pad_to(w_scale, bn, 0)
        b_p = _pad_to(bias, bn, 0)
        out = qmatmul_w8a8_pallas(
            a_p, w_p, sa_p, sw_p, b_p,
            bm=bm_e, bn=bn, bk=bk, out_dtype=out_dtype,
            interpret=(backend == "interpret"),
        )[:M, :N]
    if zp_term is not None:
        out = (out.astype(jnp.float32) - zp_term).astype(out_dtype)
    return out
