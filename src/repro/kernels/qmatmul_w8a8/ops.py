"""Public W8A8 GEMM op: registry-dispatched backends, asymmetric handling.

Asymmetric activations are supported by folding the cross terms outside the
MXU loop (DESIGN.md §5):  with a = (a_q − zp)·s_a,
    y = s_a s_w (Σ a_q w_q − zp Σ_k w_q[k,:])
the ``zp·colsum(w_q)`` term is static per output channel → folded into bias.
Weights are symmetric by default (the paper observes CLE makes weight
distributions near-symmetric — Table 7).

``quantize_out=True`` selects the epilogue variant: the GEMM emits
(int8 out, per-row scale) straight from VMEM — the exact ``quantize_act``
formula applied to the fp result, so the stepwise GEMM → quantize_act pair
collapses into one dispatch bit-identically.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..dispatch import _pad_to, register_impl, register_spec, resolve
from .kernel import qmatmul_w8a8_pallas, qmatmul_w8a8_q8_pallas
from .ref import qmatmul_w8a8_q8_ref, qmatmul_w8a8_ref


def _pallas_impl(a_q, w_q, a_scale, w_scale, bias, *, out_dtype, bm, bn, bk,
                 quantize_out, interpret):
    M, K = a_q.shape
    N = w_q.shape[1]
    bm_e = min(bm, max(8, M))
    a_p = _pad_to(_pad_to(a_q, bm_e, 0), bk, 1)
    sa_p = _pad_to(a_scale, bm_e, 0)
    if quantize_out:
        # single-N-block variant: pad N to the lane width only (padded cols
        # carry zero weights + zero bias → exact 0s that can't win a row's
        # absmax, matching the zero-pad convention of the base GEMM)
        w_p = _pad_to(_pad_to(w_q, bk, 0), 128, 1)
        q, s = qmatmul_w8a8_q8_pallas(
            a_p, w_p, sa_p, _pad_to(w_scale, 128, 0), _pad_to(bias, 128, 0),
            bm=bm_e, bk=bk, interpret=interpret)
        return q[:M, :N], s[:M]
    w_p = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    out = qmatmul_w8a8_pallas(
        a_p, w_p, sa_p, _pad_to(w_scale, bn, 0), _pad_to(bias, bn, 0),
        bm=bm_e, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret,
    )
    return out[:M, :N]


@register_impl("qmatmul_w8a8", "pallas", pad="zero")
def _w8a8_pallas(a_q, w_q, a_scale, w_scale, bias, *, out_dtype, bm, bn, bk,
                 quantize_out):
    return _pallas_impl(a_q, w_q, a_scale, w_scale, bias, out_dtype=out_dtype,
                        bm=bm, bn=bn, bk=bk, quantize_out=quantize_out,
                        interpret=False)


@register_impl("qmatmul_w8a8", "interpret", pad="zero")
def _w8a8_interpret(a_q, w_q, a_scale, w_scale, bias, *, out_dtype, bm, bn,
                    bk, quantize_out):
    return _pallas_impl(a_q, w_q, a_scale, w_scale, bias, out_dtype=out_dtype,
                        bm=bm, bn=bn, bk=bk, quantize_out=quantize_out,
                        interpret=True)


@register_impl("qmatmul_w8a8", "xla", pad="zero")
@register_impl("qmatmul_w8a8", "ref", pad="zero")
def _w8a8_ref(a_q, w_q, a_scale, w_scale, bias, *, out_dtype, bm, bn, bk,
              quantize_out):
    # int32 accumulation is exact, so the folded-scale oracle IS the
    # production XLA path — one impl serves both tiers
    if quantize_out:
        return qmatmul_w8a8_q8_ref(a_q, w_q, a_scale, w_scale, bias)
    return qmatmul_w8a8_ref(a_q, w_q, a_scale, w_scale, bias, out_dtype)


def qmatmul_w8a8(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    a_zero_point: Optional[jnp.ndarray] = None,
    *,
    out_dtype=jnp.float32,
    backend: Optional[str] = None,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    quantize_out: bool = False,
):
    """y = dequant(a_q) @ dequant(w_q) + bias.  a_q [M,K] int8, w_q [K,N] int8,
    a_scale [M]|scalar, w_scale [N]|scalar, bias [N].

    ``quantize_out=True`` returns (y_q int8 [M,N], y_scale fp32 [M]) instead
    — the fused GEMM+quantize epilogue feeding the next W8A8 layer."""
    impl = resolve("qmatmul_w8a8", backend)
    M, K = a_q.shape
    N = w_q.shape[1]
    a_scale = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (M,))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (N,))
    bias = jnp.zeros((N,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    if a_zero_point is not None:
        if quantize_out:
            raise ValueError(
                "qmatmul_w8a8: quantize_out folds the epilogue into the "
                "kernel, but the zero-point correction is applied post-GEMM "
                "— drop a_zero_point (symmetric activations) or quantize_out")
        # fold zp·colsum(w) into a per-(row, col) rank-1 correction; since
        # zp is per-row and colsum per-col, we add it post-GEMM (cheap VPU).
        colsum = jnp.sum(w_q.astype(jnp.int32), axis=0).astype(jnp.float32)
        zp_term = (
            jnp.broadcast_to(jnp.asarray(a_zero_point, jnp.float32), (M,))[:, None]
            * colsum[None, :]
            * a_scale[:, None]
            * w_scale[None, :]
        )
    else:
        zp_term = None

    out = impl(a_q, w_q, a_scale, w_scale, bias, out_dtype=out_dtype,
               bm=bm, bn=bn, bk=bk, quantize_out=quantize_out)
    if zp_term is not None:
        out = (out.astype(jnp.float32) - zp_term).astype(out_dtype)
    return out


@register_spec("qmatmul_w8a8")
def _spec(*, d_in: int = 64, d_out: int = 128, **_):
    M, K, N = 8, d_in, d_out
    return (qmatmul_w8a8,
            (jnp.zeros((M, K), jnp.int8), jnp.zeros((K, N), jnp.int8),
             jnp.ones((M,), jnp.float32), jnp.ones((N,), jnp.float32)),
            {})
