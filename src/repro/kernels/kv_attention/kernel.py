"""Pallas TPU kernel: decode attention with int8 KV dequantized in VMEM.

The EXPERIMENTS §Perf C5 finding made concrete: at 32k context the decode
roofline is the KV-cache stream. This kernel reads the cache as int8 (half
the HBM bytes of bf16) and dequantizes per block inside VMEM, fused with the
online-softmax accumulation — one HBM pass over the cache per token.

Semantics shared with ``ref.kv_attention_ref`` (the bit-exact oracle):

  * **zero-scale masking** — a key position whose scale is exactly 0 is
    invalid (ragged per-slot lengths, ring-buffer holes, block padding): its
    score is forced to ``_NEG`` before the online-softmax update, so stale
    int8 payload contributes an exact 0. Real tokens always carry a scale
    >= 1e-8/127 (see ``ops.quantize_kv``), so 0 is unambiguous.
  * **GQA** — q carries ``Hq = G * Hkv`` heads in the repeat-kv convention
    (q head ``h`` reads kv head ``h // G``), handled by a reshape instead of
    materializing repeated K/V.

Grid (B, S/blk), S innermost; per-(batch) scratch carries the online-softmax
state (m, l [Hq]; acc [Hq, hd] fp32). Block working set at blk = 512,
Hkv = 8, hd = 128: k/v int8 2·512·8·128 = 1 MiB + scales 32 KiB + acc 4 KiB
— well inside VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    def _scratch(H, hd):
        return [pltpu.VMEM((H,), jnp.float32), pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H, hd), jnp.float32)]

    _PARAMS = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )
    )
except ImportError:  # pragma: no cover
    pltpu = None

    def _scratch(H, hd):
        return [jax.ShapeDtypeStruct((H,), jnp.float32),
                jax.ShapeDtypeStruct((H,), jnp.float32),
                jax.ShapeDtypeStruct((H, hd), jnp.float32)]

    _PARAMS = lambda: {}

_NEG = -1e30


def _kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_blk, scale, group):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                        # [Hq, hd]
    ks = ks_ref[0]                                          # [blk, Hkv]
    k = kq_ref[0].astype(jnp.float32) * ks[..., None]       # [blk, Hkv, hd]
    n_kv, hd = k.shape[1], k.shape[2]
    qg = q.reshape(n_kv, group, hd)                         # repeat-kv layout
    s = jnp.einsum("ngd,knd->ngk", qg, k) * scale           # [Hkv, G, blk]
    # zero-scale positions are masked out exactly (ragged lengths / padding)
    s = jnp.where((ks > 0).T[:, None, :], s, _NEG)
    s = s.reshape(n_kv * group, -1)                         # [Hq, blk]

    m_new = jnp.maximum(m_ref[...], jnp.max(s, -1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1)
    m_ref[...] = m_new
    v = vq_ref[0].astype(jnp.float32) * vs_ref[0][..., None]
    pv = jnp.einsum("ngk,knd->ngd", p.reshape(n_kv, group, -1), v)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv.reshape(n_kv * group, hd)

    @pl.when(j == n_blk - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk", "out_dtype", "interpret"))
def kv_attention_pallas(q, k_q, k_s, v_q, v_s, *, blk=512,
                        out_dtype=jnp.float32, interpret=False):
    """q [B, Hq, hd]; k_q/v_q [B, S, Hkv, hd] int8; k_s/v_s [B, S, Hkv].

    S must be a multiple of ``blk`` here — ``ops.kv_attention`` pads ragged
    shapes with zero-scale (masked) positions before dispatching.
    """
    B, S, Hkv, hd = k_q.shape
    Hq = q.shape[1]
    assert S % blk == 0
    assert Hq % Hkv == 0
    group = Hq // Hkv
    n_blk = S // blk
    scale = 1.0 / (hd ** 0.5)
    grid = (B, n_blk)
    return pl.pallas_call(
        functools.partial(_kernel, n_blk=n_blk, scale=scale, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hq, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, blk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, blk, Hkv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, blk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, blk, Hkv), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), out_dtype),
        scratch_shapes=_scratch(Hq, hd),
        interpret=interpret,
        **_PARAMS(),
    )(q, k_q, k_s.astype(jnp.float32), v_q, v_s.astype(jnp.float32))
