"""Pallas TPU kernel: decode attention with int8 KV dequantized in VMEM.

The EXPERIMENTS §Perf C5 finding made concrete: at 32k context the decode
roofline is the KV-cache stream. This kernel reads the cache as int8 (half
the HBM bytes of bf16) and dequantizes per block inside VMEM, fused with the
online-softmax accumulation — one HBM pass over the cache per token.

Grid (B, S/blk), S innermost; per-(batch) scratch carries the online-softmax
state (m, l [H]; acc [H, hd] fp32). Block working set at blk = 512, H = 8,
hd = 128: k/v int8 2·512·8·128 = 1 MiB + scales 32 KiB + acc 4 KiB — well
inside VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    def _scratch(H, hd):
        return [pltpu.VMEM((H,), jnp.float32), pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H, hd), jnp.float32)]

    _PARAMS = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )
    )
except ImportError:  # pragma: no cover
    pltpu = None

    def _scratch(H, hd):
        return [jax.ShapeDtypeStruct((H,), jnp.float32),
                jax.ShapeDtypeStruct((H,), jnp.float32),
                jax.ShapeDtypeStruct((H, hd), jnp.float32)]

    _PARAMS = lambda: {}

_NEG = -1e30


def _kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_blk, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                        # [H, hd]
    k = kq_ref[0].astype(jnp.float32) * ks_ref[0][..., None]  # [blk, H, hd]
    s = jnp.einsum("hd,khd->hk", q, k) * scale              # [H, blk]

    m_new = jnp.maximum(m_ref[...], jnp.max(s, -1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1)
    m_ref[...] = m_new
    v = vq_ref[0].astype(jnp.float32) * vs_ref[0][..., None]
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.einsum("hk,khd->hd", p, v)

    @pl.when(j == n_blk - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk", "out_dtype", "interpret"))
def kv_attention_pallas(q, k_q, k_s, v_q, v_s, *, blk=512,
                        out_dtype=jnp.float32, interpret=False):
    B, S, H, hd = k_q.shape
    assert S % blk == 0
    n_blk = S // blk
    scale = 1.0 / (hd ** 0.5)
    grid = (B, n_blk)
    return pl.pallas_call(
        functools.partial(_kernel, n_blk=n_blk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, blk, H, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, blk, H), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, blk, H, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, blk, H), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), out_dtype),
        scratch_shapes=_scratch(H, hd),
        interpret=interpret,
        **_PARAMS(),
    )(q, k_q, k_s.astype(jnp.float32), v_q, v_s.astype(jnp.float32))
