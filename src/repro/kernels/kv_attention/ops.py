"""Public int8-KV decode-attention op: padding + backend selection."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import kv_attention_pallas
from .ref import kv_attention_ref


def kv_attention(q, k_q, k_s, v_q, v_s, *, blk: int = 512,
                 out_dtype=jnp.float32, backend: Optional[str] = None):
    """Single-token decode attention over an int8 cache.

    q [B,H,hd]; k_q/v_q [B,S,H,hd] int8; k_s/v_s [B,S,H]. Padding positions
    must carry scale 0 (their dequantized keys are 0 ⇒ uniform logits; pass
    fully-populated caches for exactness, as the serving loop does).
    """
    backend = backend or ("pallas" if jax.default_backend() == "tpu" else "interpret")
    if backend == "xla":
        return kv_attention_ref(q, k_q, k_s, v_q, v_s, out_dtype)
    B, S, H, hd = k_q.shape
    blk_e = min(blk, S)
    pad = (-S) % blk_e
    if pad:
        # pad with scale 0 AND logit-masking handled by monotone softmax:
        # zero-scale keys give score 0; to keep exactness we instead pad by
        # REPLICATING the final block's stats — simplest correct route is to
        # require divisibility from the caller; assert instead of silently
        # degrading.
        raise ValueError(f"S={S} must be a multiple of blk={blk_e}")
    return kv_attention_pallas(q, k_q, k_s, v_q, v_s, blk=blk_e,
                               out_dtype=out_dtype,
                               interpret=(backend == "interpret"))
