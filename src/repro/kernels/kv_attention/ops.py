"""Public int8-KV decode-attention ops: quantize, append, attend.

This module is the one truth for the serving KV-quantization scheme — the
paper's symmetric per-token/per-head absmax quantizer (§3) applied to the
decode memory wall:

  * ``quantize_kv``        — K/V tensor → int8 payload + fp32 scales.
  * ``kv_attention``       — single-token decode attention over an int8
    cache (backend-selected: Pallas on TPU, folded-scale XLA elsewhere).
    Ragged shapes are handled by **zero-scale masking**: any position whose
    scale is 0 is invalid and contributes an exact 0; non-multiple-of-blk S
    is padded with zero-scale positions before the Pallas dispatch.
  * ``kv_attention_decode`` — the fused append-quantize decode step: the
    new token's K/V is quantized once, scattered into the int8 cache, and
    attention runs over the updated cache — the cache itself is never
    re-quantized or re-materialized in fp.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..dispatch import register_impl, register_spec, resolve
from .kernel import kv_attention_pallas
from .ref import kv_attention_ref, kv_attention_xla, pad_to_block


def quantize_kv(t: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[..., hd] → (int8 payload, fp32 absmax scale over the last axis).

    The scale floor (1e-8/127) guarantees real tokens never carry scale 0 —
    zero is reserved as the "position invalid" marker the attention ops key
    their masking on.
    """
    tf = t.astype(jnp.float32)
    amax = jnp.max(jnp.abs(tf), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(tf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _pallas_impl(q, k_q, k_s, v_q, v_s, *, blk, out_dtype, interpret):
    # zero-scale padding: padded positions are masked exactly inside the
    # kernel's online softmax, so any S works (ragged serving rings)
    k_q, k_s, v_q, v_s, blk_e = pad_to_block(k_q, k_s, v_q, v_s, blk)
    return kv_attention_pallas(q, k_q, k_s, v_q, v_s, blk=blk_e,
                               out_dtype=out_dtype, interpret=interpret)


@register_impl("kv_attention", "pallas", pad="zero-scale")
def _kv_pallas(q, k_q, k_s, v_q, v_s, *, blk, out_dtype):
    return _pallas_impl(q, k_q, k_s, v_q, v_s, blk=blk, out_dtype=out_dtype,
                        interpret=False)


@register_impl("kv_attention", "interpret", pad="zero-scale")
def _kv_interpret(q, k_q, k_s, v_q, v_s, *, blk, out_dtype):
    return _pallas_impl(q, k_q, k_s, v_q, v_s, blk=blk, out_dtype=out_dtype,
                        interpret=True)


@register_impl("kv_attention", "xla", pad="zero-scale")
def _kv_xla(q, k_q, k_s, v_q, v_s, *, blk, out_dtype):
    return kv_attention_xla(q, k_q, k_s, v_q, v_s, out_dtype)


@register_impl("kv_attention", "ref", pad="zero-scale")
def _kv_ref(q, k_q, k_s, v_q, v_s, *, blk, out_dtype):
    # the blocked oracle pads to the kernel's zero-scale convention itself
    return kv_attention_ref(q, k_q, k_s, v_q, v_s, out_dtype, blk=blk)


def kv_attention(q, k_q, k_s, v_q, v_s, *, blk: int = 512,
                 out_dtype=jnp.float32, backend: Optional[str] = None,
                 v_err: Optional[jnp.ndarray] = None):
    """Single-token decode attention over an int8 cache.

    q [B, Hq, hd]; k_q/v_q [B, S, Hkv, hd] int8; k_s/v_s [B, S, Hkv] with
    Hq a multiple of Hkv (GQA, repeat-kv head order). Positions with scale 0
    are masked (ragged per-slot lengths / ring holes / padding) — zero the
    scales of invalid positions instead of dequantizing-and-masking.
    ``v_err`` ([B, S, Hkv] per-token V dequant-error means) enables the
    optional bias correction — XLA path only: with ``backend=None`` it
    selects "xla", an explicit "pallas"/"interpret" raises (no silent
    hot-path fallback).
    """
    if v_err is not None:
        if backend not in (None, "xla"):
            raise ValueError(
                f"kv_attention: V bias correction (v_err) is implemented on "
                f"the XLA path only, got backend={backend!r}; pass "
                f"backend='xla' or drop v_err"
            )
        return kv_attention_xla(q, k_q, k_s, v_q, v_s, out_dtype, v_err=v_err)
    impl = resolve("kv_attention", backend)
    return impl(q, k_q, k_s, v_q, v_s, blk=blk, out_dtype=out_dtype)


def append_quantize(cache_k, cache_ks, cache_v, cache_vs, k_new, v_new, idx,
                    *, cache_verr=None):
    """Quantize a new token's K/V once and scatter it into the int8 cache.

    k_new/v_new [B, T, Hkv, hd] fp; idx [T] ring offsets (scalar-pos cache)
    or [B, T] per-slot offsets. Returns the updated cache leaves (+ the
    per-token V dequant-error means when ``cache_verr`` is given).
    """
    k_q, k_s = quantize_kv(k_new)
    v_q, v_s = quantize_kv(v_new)
    if idx.ndim == 2:                                  # per-slot [B, T]
        row = jnp.arange(k_new.shape[0])[:, None]
        at = lambda c, u: c.at[row, idx].set(u)
    else:                                              # shared ring offsets
        at = lambda c, u: c.at[:, idx].set(u)
    out = (at(cache_k, k_q), at(cache_ks, k_s),
           at(cache_v, v_q), at(cache_vs, v_s))
    if cache_verr is not None:
        err = jnp.mean(v_q.astype(jnp.float32) * v_s[..., None]
                       - v_new.astype(jnp.float32), axis=-1)
        out = out + (at(cache_verr, err),)
    return out


def kv_attention_decode(q, cache_k, cache_ks, cache_v, cache_vs, k_new, v_new,
                        idx, *, valid=None, out_dtype=jnp.float32,
                        backend: Optional[str] = None, blk: int = 512,
                        cache_verr=None):
    """Fused decode step: append-quantize the new token, then attend.

    q [B, Hq, hd] (the new token's roped query); k_new/v_new [B, 1, Hkv, hd];
    ``valid`` [B, S] marks live cache positions (None = all live). Returns
    (attn_out [B, Hq, hd], updated cache leaves) — the int8 cache is written
    once per token and never re-quantized.
    """
    updated = append_quantize(cache_k, cache_ks, cache_v, cache_vs,
                              k_new, v_new, idx, cache_verr=cache_verr)
    ck, ks, cv, vs = updated[:4]
    verr = updated[4] if cache_verr is not None else None
    ks_eff, vs_eff = ks, vs
    verr_eff = verr
    if valid is not None:
        ks_eff = jnp.where(valid[..., None], ks, 0.0)
        vs_eff = jnp.where(valid[..., None], vs, 0.0)
        if verr is not None:
            verr_eff = jnp.where(valid[..., None], verr, 0.0)
    out = kv_attention(q, ck, ks_eff, cv, vs_eff, blk=blk,
                       out_dtype=out_dtype, backend=backend, v_err=verr_eff)
    return out, updated


@register_spec("kv_attention_decode")
def _spec(*, head_dim: int = 16, n_kv_heads: int = 2, n_q_heads: int = 4,
          seq: int = 32, batch: int = 2, **_):
    B, S, Hq, Hkv, hd = batch, seq, n_q_heads, n_kv_heads, head_dim
    return (kv_attention_decode,
            (jnp.zeros((B, Hq, hd), jnp.float32),        # q
             jnp.zeros((B, S, Hkv, hd), jnp.int8),       # cache_k
             jnp.ones((B, S, Hkv), jnp.float32),         # cache_ks
             jnp.zeros((B, S, Hkv, hd), jnp.int8),       # cache_v
             jnp.ones((B, S, Hkv), jnp.float32),         # cache_vs
             jnp.zeros((B, 1, Hkv, hd), jnp.float32),    # k_new
             jnp.zeros((B, 1, Hkv, hd), jnp.float32),    # v_new
             jnp.zeros((B, 1), jnp.int32)),              # idx
            {"valid": jnp.ones((B, S), bool)})
