"""Reference implementations for int8-KV decode attention.

Two oracles with different jobs:

  * ``kv_attention_ref`` — mirrors the Pallas kernel **block for block**
    (same block order, same fp32 op sequence, same zero-scale masking), so
    the interpret-mode kernel must match it *bit-exactly*: any divergence is
    a BlockSpec/grid/scratch bug, not numerics. The property tests pin this
    over ragged lengths, GQA ratios, and non-multiple-of-blk S.
  * ``kv_attention_xla`` — the production XLA backend for non-TPU serving:
    plain masked softmax with the per-token/per-head scales folded in at
    score granularity (``[B, S, Hkv]``), so neither a dequantized
    ``[B, S, H, hd]`` cache nor repeated GQA K/V is ever materialized.

Both treat scale == 0 as "position invalid" (ragged per-slot lengths,
padding); see kernel.py for why 0 is unambiguous.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


def pad_to_block(k_q, k_s, v_q, v_s, blk: int):
    """Pad S up to a multiple of ``min(blk, S)`` with zero-scale (= masked)
    positions. One helper shared by the op and the ref — the bit-exact
    interpret==ref contract requires both to pad identically."""
    S = k_q.shape[1]
    blk_e = min(blk, S)
    pad = (-S) % blk_e
    if pad:
        k_q = jnp.pad(k_q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_q = jnp.pad(v_q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_s = jnp.pad(k_s, ((0, 0), (0, pad), (0, 0)))
        v_s = jnp.pad(v_s, ((0, 0), (0, pad), (0, 0)))
    return k_q, k_s, v_q, v_s, blk_e


def kv_attention_ref(
    q: jnp.ndarray,        # [B, Hq, hd]
    k_q: jnp.ndarray,      # [B, S, Hkv, hd] int8
    k_s: jnp.ndarray,      # [B, S, Hkv] fp32 per-token, per-head scales
    v_q: jnp.ndarray,      # [B, S, Hkv, hd] int8
    v_s: jnp.ndarray,      # [B, S, Hkv]
    out_dtype=jnp.float32,
    *,
    blk: int = 512,
) -> jnp.ndarray:
    """Blocked online-softmax oracle — the kernel's math in pure jnp."""
    B, S, Hkv, hd = k_q.shape
    Hq = q.shape[1]
    group = Hq // Hkv
    k_q, k_s, v_q, v_s, blk_e = pad_to_block(k_q, k_s, v_q, v_s, blk)
    n_blk = k_q.shape[1] // blk_e
    scale = 1.0 / (hd ** 0.5)

    qg = q.astype(jnp.float32).reshape(B, Hkv, group, hd)
    # [n_blk, B, blk, ...] block streams, scanned in the kernel's grid order
    def blocks(a):
        return a.reshape(B, n_blk, blk_e, *a.shape[2:]).transpose(
            1, 0, *range(2, a.ndim + 1))

    def body(carry, inp):
        m, l, acc = carry                       # [B, Hq], [B, Hq], [B, Hq, hd]
        kq_b, ks_b, vq_b, vs_b = inp
        ks_b = ks_b.astype(jnp.float32)
        k = kq_b.astype(jnp.float32) * ks_b[..., None]      # [B, blk, Hkv, hd]
        s = jnp.einsum("bngd,bknd->bngk", qg, k) * scale    # [B, Hkv, G, blk]
        s = jnp.where((ks_b > 0).transpose(0, 2, 1)[:, :, None, :], s, _NEG)
        s = s.reshape(B, Hq, -1)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, -1)
        v = vq_b.astype(jnp.float32) * vs_b.astype(jnp.float32)[..., None]
        pv = jnp.einsum("bngk,bknd->bngd", p.reshape(B, Hkv, group, -1), v)
        acc = acc * corr[..., None] + pv.reshape(B, Hq, hd)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hq), jnp.float32)
    acc0 = jnp.zeros((B, Hq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (blocks(k_q), blocks(k_s), blocks(v_q), blocks(v_s))
    )
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(out_dtype)


def kv_attention_xla(
    q: jnp.ndarray,        # [B, Hq, hd]
    k_q: jnp.ndarray,      # [B, S, Hkv, hd] int8
    k_s: jnp.ndarray,      # [B, S, Hkv]
    v_q: jnp.ndarray,      # [B, S, Hkv, hd] int8
    v_s: jnp.ndarray,      # [B, S, Hkv]
    out_dtype=jnp.float32,
    v_err: jnp.ndarray = None,   # [B, S, Hkv] optional V dequant-error means
) -> jnp.ndarray:
    """Serving XLA path: scales (and the optional per-token V bias
    correction, paper §4.2 applied to the V dequant error) fold in at
    ``[B, S, Hkv]`` score/probability granularity — the per-token-per-head
    scale factors out of the head_dim dot product, so the int8 payload feeds
    the einsum directly."""
    B, S, Hkv, hd = k_q.shape
    Hq = q.shape[1]
    group = Hq // Hkv
    scale = 1.0 / (hd ** 0.5)
    qg = q.astype(jnp.float32).reshape(B, Hkv, group, hd)
    ks_t = k_s.astype(jnp.float32).transpose(0, 2, 1)       # [B, Hkv, S]
    s = jnp.einsum("bngd,bsnd->bngs", qg, k_q.astype(jnp.float32))
    s = s * (ks_t * scale)[:, :, None, :]
    s = jnp.where((ks_t > 0)[:, :, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)                          # [B, Hkv, G, S]
    vs_t = v_s.astype(jnp.float32).transpose(0, 2, 1)
    out = jnp.einsum("bngs,bsnd->bngd", p * vs_t[:, :, None, :],
                     v_q.astype(jnp.float32))
    if v_err is not None:
        # out_d -= sum_s p_s * E_d[dequant(v_s) - v_s]: removes the mean
        # (per-token, per-head) component of the V quantization error
        e = jnp.einsum("bngs,bsn->bng", p, v_err.astype(jnp.float32))
        out = out - e[..., None]
    return out.reshape(B, Hq, hd).astype(out_dtype)
