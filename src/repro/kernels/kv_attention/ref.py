"""Pure-jnp oracle for single-token decode attention over an int8 KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_attention_ref(
    q: jnp.ndarray,        # [B, H, hd]
    k_q: jnp.ndarray,      # [B, S, H, hd] int8
    k_s: jnp.ndarray,      # [B, S, H] fp32 per-token, per-head scales
    v_q: jnp.ndarray,      # [B, S, H, hd] int8
    v_s: jnp.ndarray,      # [B, S, H]
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    scale = 1.0 / (q.shape[-1] ** 0.5)
    k = k_q.astype(jnp.float32) * k_s[..., None]
    v = v_q.astype(jnp.float32) * v_s[..., None]
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v).astype(out_dtype)
