"""Pure-jnp oracle for fused per-row dynamic activation quantization."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_act_ref(x: jnp.ndarray, bits: int = 8):
    """Per-row symmetric absmax quantization. x: [M, K] → (q int8 [M, K],
    scale fp32 [M])."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[:, None]), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale
