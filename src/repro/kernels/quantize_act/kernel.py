"""Pallas TPU kernel: fused per-row absmax reduce + scale + round → int8.

One pass over the activation row in VMEM: reduce |x|max across K, derive the
scale, round — the quantize stage of the dynamic W8A8 path costs a single
HBM read + int8 write instead of (reduce pass + scale pass).
Block (bm, K): whole rows resident (K ≤ 8k ⇒ ≤ 4 MiB fp32 at bm = 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale[:, None]), -qmax - 1, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def quantize_act_pallas(
    x: jnp.ndarray, *, bits: int = 8, bm: int = 128, interpret: bool = False
):
    M, K = x.shape
    assert M % bm == 0
    qmax = 2 ** (bits - 1) - 1
    grid = (M // bm,)
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.int8),
            jax.ShapeDtypeStruct((M,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
