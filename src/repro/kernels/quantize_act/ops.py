"""Public dynamic-quantize op with padding + backend selection."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import quantize_act_pallas
from .ref import quantize_act_ref


def quantize_act(
    x: jnp.ndarray, *, bits: int = 8, backend: Optional[str] = None, bm: int = 128
):
    backend = backend or ("pallas" if jax.default_backend() == "tpu" else "interpret")
    if backend == "xla":
        return quantize_act_ref(x, bits)
    M, K = x.shape
    bm_e = min(bm, M)
    pad = (-M) % bm_e
    x_p = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    q, s = quantize_act_pallas(x_p, bits=bits, bm=bm_e, interpret=(backend == "interpret"))
    return q[:M], s[:M]
