"""Public dynamic-quantize op, registry-dispatched."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..dispatch import _pad_to, register_impl, register_spec, resolve
from .kernel import quantize_act_pallas
from .ref import quantize_act_ref


def _pallas_impl(x, *, bits, bm, interpret):
    M, K = x.shape
    bm_e = min(bm, M)
    x_p = _pad_to(x, bm_e, 0)
    q, s = quantize_act_pallas(x_p, bits=bits, bm=bm_e, interpret=interpret)
    return q[:M], s[:M]


@register_impl("quantize_act", "pallas", pad="zero")
def _qact_pallas(x, *, bits, bm):
    return _pallas_impl(x, bits=bits, bm=bm, interpret=False)


@register_impl("quantize_act", "interpret", pad="zero")
def _qact_interpret(x, *, bits, bm):
    return _pallas_impl(x, bits=bits, bm=bm, interpret=True)


@register_impl("quantize_act", "xla", pad="zero")
@register_impl("quantize_act", "ref", pad="zero")
def _qact_ref(x, *, bits, bm):
    return quantize_act_ref(x, bits)


def quantize_act(
    x: jnp.ndarray, *, bits: int = 8, backend: Optional[str] = None, bm: int = 128
):
    return resolve("quantize_act", backend)(x, bits=bits, bm=bm)


@register_spec("quantize_act")
def _spec(*, d_in: int = 64, **_):
    return (quantize_act, (jnp.zeros((8, d_in), jnp.float32),), {})
