"""Pallas TPU megakernel: append-quantize + int8 decode attention, fused.

One kernel from roped hidden state to attention out: the decode step that
used to be three dispatches (quantize_kv → cache scatter → kv_attention)
is one ``pallas_call`` — the new token's K/V is quantized in VMEM with the
exact ``ops.quantize_kv`` formula, written into its ring position of the
int8 cache block in flight, and the online-softmax attention runs over the
updated block in the same pass. The cache leaves are outputs aliased onto
their inputs (``input_output_aliases``), so the append is in-place: the
cache makes exactly one HBM round trip per token, and the fp K/V never
touches HBM at all.

Semantics are the ``kv_attention`` kernel's, inherited verbatim (zero-scale
masking, GQA via repeat-kv reshape, grid (B, S/blk) with per-batch
online-softmax scratch) — the attention math below is copied from
``kv_attention/kernel.py`` line for line so the fused path stays bit-exact
to the stepwise composition, which is what the serving parity batteries
pin. The ``valid`` mask is the caller's post-append liveness mask (it must
cover the new token's position — the token attends to itself).

``quantize_out=True`` adds the W8A8 epilogue: the final block re-quantizes
the attention output row (flattened [Hq·hd], the exact ``quantize_act``
formula) so the wo projection reads int8 directly — deleting the standalone
quantize_act dispatch between attention and wo.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

    def _scratch(H, hd):
        return [pltpu.VMEM((H,), jnp.float32), pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H, hd), jnp.float32)]

    _PARAMS = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )
    )
except ImportError:  # pragma: no cover
    pltpu = None

    def _scratch(H, hd):
        return [jax.ShapeDtypeStruct((H,), jnp.float32),
                jax.ShapeDtypeStruct((H,), jnp.float32),
                jax.ShapeDtypeStruct((H, hd), jnp.float32)]

    _PARAMS = lambda: {}

_NEG = -1e30


def _quant127(t):
    """The ``ops.quantize_kv`` formula, in-kernel: [..., hd] fp →
    (int8, fp32 absmax/127 scale). Must stay expression-identical to the
    host-side quantizer — the scale floor keeps 0 reserved for "invalid"."""
    tf = t.astype(jnp.float32)
    amax = jnp.max(jnp.abs(tf), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(tf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, kn_ref, vn_ref, idx_ref,
            valid_ref, o_ref, okq_ref, oks_ref, ovq_ref, ovs_ref,
            m_ref, l_ref, acc_ref, *, n_blk, blk, scale, group,
            quantize_out, qmax, oq_ref=None, os_ref=None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- append-quantize: the new token lands in this block iff its ring
    # offset falls inside [j·blk, (j+1)·blk)
    kq_n, ks_n = _quant127(kn_ref[0])                       # [Hkv, hd], [Hkv]
    vq_n, vs_n = _quant127(vn_ref[0])
    off = idx_ref[0] - j * blk
    hit = jax.lax.broadcasted_iota(jnp.int32, (blk, 1), 0) == off  # [blk, 1]
    kq_u = jnp.where(hit[..., None], kq_n[None], kq_ref[0])  # [blk, Hkv, hd]
    ks_u = jnp.where(hit, ks_n[None], ks_ref[0])             # [blk, Hkv]
    vq_u = jnp.where(hit[..., None], vq_n[None], vq_ref[0])
    vs_u = jnp.where(hit, vs_n[None], vs_ref[0])
    okq_ref[0] = kq_u
    oks_ref[0] = ks_u
    ovq_ref[0] = vq_u
    ovs_ref[0] = vs_u

    # the stored scales are UNMASKED (the cache keeps every written token);
    # only the attention inputs see the caller's liveness mask
    vld = valid_ref[0] > 0                                   # [blk]
    ks_eff = jnp.where(vld[:, None], ks_u, 0.0)
    vs_eff = jnp.where(vld[:, None], vs_u, 0.0)

    # ---- attention over the updated block: kv_attention/kernel.py verbatim
    q = q_ref[0].astype(jnp.float32)                        # [Hq, hd]
    k = kq_u.astype(jnp.float32) * ks_eff[..., None]        # [blk, Hkv, hd]
    n_kv, hd = k.shape[1], k.shape[2]
    qg = q.reshape(n_kv, group, hd)                         # repeat-kv layout
    s = jnp.einsum("ngd,knd->ngk", qg, k) * scale           # [Hkv, G, blk]
    # zero-scale positions are masked out exactly (ragged lengths / padding)
    s = jnp.where((ks_eff > 0).T[:, None, :], s, _NEG)
    s = s.reshape(n_kv * group, -1)                         # [Hq, blk]

    m_new = jnp.maximum(m_ref[...], jnp.max(s, -1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1)
    m_ref[...] = m_new
    v = vq_u.astype(jnp.float32) * vs_eff[..., None]
    pv = jnp.einsum("ngk,knd->ngd", p.reshape(n_kv, group, -1), v)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv.reshape(n_kv * group, hd)

    @pl.when(j == n_blk - 1)
    def _epilogue():
        o = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
             ).astype(o_ref.dtype)
        o_ref[0] = o
        if quantize_out:
            # the exact quantize_act formula on the out_dtype-cast output —
            # bit-identical to the stepwise attention → quantize_act pair
            flat = o.astype(jnp.float32).reshape(1, -1)      # [1, Hq·hd]
            amax = jnp.max(jnp.abs(flat), axis=-1)
            oscale = jnp.maximum(amax, 1e-8) / qmax
            oq = jnp.clip(jnp.round(flat / oscale[:, None]), -qmax - 1, qmax)
            oq_ref[...] = oq.astype(jnp.int8)
            os_ref[...] = oscale


@functools.partial(jax.jit, static_argnames=("blk", "out_dtype",
                                             "quantize_out", "interpret"))
def fused_decode_pallas(q, k_q, k_s, v_q, v_s, k_new, v_new, idx, valid, *,
                        blk=512, out_dtype=jnp.float32, quantize_out=False,
                        interpret=False):
    """q [B, Hq, hd]; k_q/v_q [B, S, Hkv, hd] int8; k_s/v_s [B, S, Hkv];
    k_new/v_new [B, Hkv, hd] fp; idx [B] int32 ring offsets; valid [B, S]
    fp mask (>0 = live, must include each row's new position).

    Returns (out, k_q', k_s', v_q', v_s') — the cache outputs aliased onto
    their inputs — plus (out_q [B, Hq·hd] int8, out_scale [B]) when
    ``quantize_out``. S must be a multiple of ``blk`` (``ops.fused_decode``
    pads with zero-scale masked positions).
    """
    B, S, Hkv, hd = k_q.shape
    Hq = q.shape[1]
    assert S % blk == 0
    assert Hq % Hkv == 0
    group = Hq // Hkv
    n_blk = S // blk
    scale = 1.0 / (hd ** 0.5)
    grid = (B, n_blk)
    out_shape = [
        jax.ShapeDtypeStruct((B, Hq, hd), out_dtype),
        jax.ShapeDtypeStruct(k_q.shape, jnp.int8),
        jax.ShapeDtypeStruct(k_s.shape, jnp.float32),
        jax.ShapeDtypeStruct(v_q.shape, jnp.int8),
        jax.ShapeDtypeStruct(v_s.shape, jnp.float32),
    ]
    out_specs = [
        pl.BlockSpec((1, Hq, hd), lambda b, j: (b, 0, 0)),
        pl.BlockSpec((1, blk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
        pl.BlockSpec((1, blk, Hkv), lambda b, j: (b, j, 0)),
        pl.BlockSpec((1, blk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
        pl.BlockSpec((1, blk, Hkv), lambda b, j: (b, j, 0)),
    ]
    if quantize_out:
        out_shape += [jax.ShapeDtypeStruct((B, Hq * hd), jnp.int8),
                      jax.ShapeDtypeStruct((B,), jnp.float32)]
        out_specs += [pl.BlockSpec((1, Hq * hd), lambda b, j: (b, 0)),
                      pl.BlockSpec((1,), lambda b, j: (b,))]
    kern = functools.partial(
        _kernel, n_blk=n_blk, blk=blk, scale=scale, group=group,
        quantize_out=quantize_out, qmax=127)
    if quantize_out:
        # scratch positions shift: route the two extra out refs by keyword
        def kern(*refs, _n=n_blk, _b=blk, _s=scale, _g=group):  # noqa: F811
            (q_r, kq_r, ks_r, vq_r, vs_r, kn_r, vn_r, ix_r, vl_r,
             o_r, okq_r, oks_r, ovq_r, ovs_r, oq_r, os_r,
             m_r, l_r, a_r) = refs
            _kernel(q_r, kq_r, ks_r, vq_r, vs_r, kn_r, vn_r, ix_r, vl_r,
                    o_r, okq_r, oks_r, ovq_r, ovs_r, m_r, l_r, a_r,
                    n_blk=_n, blk=_b, scale=_s, group=_g,
                    quantize_out=True, qmax=127, oq_ref=oq_r, os_ref=os_r)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hq, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, blk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, blk, Hkv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, blk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, blk, Hkv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, Hkv, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, Hkv, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, blk), lambda b, j: (b, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=_scratch(Hq, hd),
        input_output_aliases={1: 1, 2: 2, 3: 3, 4: 4},
        interpret=interpret,
        **_PARAMS(),
    )(q, k_q, k_s.astype(jnp.float32), v_q, v_s.astype(jnp.float32),
      k_new, v_new, idx.astype(jnp.int32), valid)
