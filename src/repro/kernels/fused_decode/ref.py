"""Oracle for the fused decode megakernel: the stepwise composition.

The fused kernel's contract is that fusing changes NOTHING numerically —
so its oracle is literally the three-step path it replaces (append-quantize
→ zero-scale masking → blocked-oracle attention → quantize_act), each step
already bit-pinned by its own package. The interpret-mode megakernel must
match this composition bit for bit.
"""
from __future__ import annotations

import jax.numpy as jnp


def fused_decode_ref(q, cache_k, cache_ks, cache_v, cache_vs, k_new, v_new,
                    idx, *, valid=None, out_dtype=jnp.float32, blk=512,
                    quantize_out=False):
    from ..kv_attention.ops import kv_attention_decode
    from ..quantize_act.ref import quantize_act_ref

    out, updated = kv_attention_decode(
        q, cache_k, cache_ks, cache_v, cache_vs, k_new, v_new, idx,
        valid=valid, out_dtype=out_dtype, backend="ref", blk=blk)
    if quantize_out:
        B = out.shape[0]
        oq, os = quantize_act_ref(out.astype(jnp.float32).reshape(B, -1))
        return (out, oq, os), updated
    return out, updated
