"""Public fused decode op: one dispatch from roped q/k/v to attention out.

``fused_decode`` is the megakernel face of the decode hot path:

  * **pallas / interpret** — the true fusion (``kernel.py``): in-VMEM
    append-quantize + int8 online-softmax attention (+ optional W8A8
    quantize-out epilogue), cache leaves aliased in place.
  * **xla** — the exact stepwise composition the serving engine shipped
    before this op existed (``kv_attention_decode`` on its XLA tier +
    ``quantize_act``), so CPU serving graphs — and the lint contracts
    pinning them — are unchanged by construction.
  * **ref** — the composition over the blocked oracles (``ref.py``), the
    bit-parity anchor for the interpret-mode kernel.

The V bias correction (``cache_verr``) is XLA-composition-only, mirroring
``kv_attention``: with ``backend=None`` it routes to "xla", an explicit
"pallas"/"interpret" raises.

``REPRO_FUSED_DECODE=0`` turns the op's model-layer routing off (the layers
fall back to the stepwise ops) — the switch the fused-vs-unfused parity
tests and benchmark delta ride on.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from ..dispatch import register_impl, register_spec, resolve
from ..kv_attention.ops import kv_attention_decode
from ..quantize_act.ops import quantize_act
from .kernel import fused_decode_pallas
from .ref import fused_decode_ref


def fusion_enabled() -> bool:
    """The ``REPRO_FUSED_DECODE`` routing flag (default: on)."""
    return os.environ.get("REPRO_FUSED_DECODE", "1").lower() not in (
        "0", "false", "off")


def _compose(q, ck, cks, cv, cvs, k_new, v_new, idx, *, valid, out_dtype,
             blk, quantize_out, backend, cache_verr=None):
    """The stepwise composition at one backend tier."""
    out, updated = kv_attention_decode(
        q, ck, cks, cv, cvs, k_new, v_new, idx, valid=valid,
        out_dtype=out_dtype, backend=backend, blk=blk,
        cache_verr=cache_verr)
    if quantize_out:
        B = out.shape[0]
        oq, os_ = quantize_act(out.astype(jnp.float32).reshape(B, -1),
                               backend=backend)
        return (out, oq, os_), updated
    return out, updated


def _pallas_impl(q, ck, cks, cv, cvs, k_new, v_new, idx, *, valid, out_dtype,
                 blk, quantize_out, interpret):
    from ..kv_attention.ref import pad_to_block

    B, S, Hkv, hd = ck.shape
    # normalize the stepwise op's idx/valid conventions to kernel shapes
    idx_b = idx[:, 0] if idx.ndim == 2 else jnp.broadcast_to(
        idx.reshape(-1)[:1], (B,))
    if valid is None:
        vmask = jnp.ones((B, S), jnp.float32)
    else:
        vmask = jnp.broadcast_to(valid, (B, S)).astype(jnp.float32)
    ck_p, cks_p, cv_p, cvs_p, blk_e = pad_to_block(ck, cks, cv, cvs, blk)
    S_p = ck_p.shape[1]
    if S_p != S:
        vmask = jnp.pad(vmask, ((0, 0), (0, S_p - S)))
    res = fused_decode_pallas(
        q, ck_p, cks_p, cv_p, cvs_p,
        k_new.reshape(B, Hkv, hd), v_new.reshape(B, Hkv, hd),
        idx_b, vmask, blk=blk_e, out_dtype=out_dtype,
        quantize_out=quantize_out, interpret=interpret)
    out, kq_u, ks_u, vq_u, vs_u = res[:5]
    updated = (kq_u[:, :S], ks_u[:, :S], vq_u[:, :S], vs_u[:, :S])
    if quantize_out:
        return (out, res[5], res[6]), updated
    return out, updated


@register_impl("fused_decode", "pallas", pad="zero-scale")
def _fd_pallas(q, ck, cks, cv, cvs, k_new, v_new, idx, *, valid, out_dtype,
               blk, quantize_out):
    return _pallas_impl(q, ck, cks, cv, cvs, k_new, v_new, idx, valid=valid,
                        out_dtype=out_dtype, blk=blk,
                        quantize_out=quantize_out, interpret=False)


@register_impl("fused_decode", "interpret", pad="zero-scale")
def _fd_interpret(q, ck, cks, cv, cvs, k_new, v_new, idx, *, valid,
                  out_dtype, blk, quantize_out):
    return _pallas_impl(q, ck, cks, cv, cvs, k_new, v_new, idx, valid=valid,
                        out_dtype=out_dtype, blk=blk,
                        quantize_out=quantize_out, interpret=True)


@register_impl("fused_decode", "xla", pad="zero-scale")
def _fd_xla(q, ck, cks, cv, cvs, k_new, v_new, idx, *, valid, out_dtype,
            blk, quantize_out):
    return _compose(q, ck, cks, cv, cvs, k_new, v_new, idx, valid=valid,
                    out_dtype=out_dtype, blk=blk, quantize_out=quantize_out,
                    backend="xla")


@register_impl("fused_decode", "ref", pad="zero-scale")
def _fd_ref(q, ck, cks, cv, cvs, k_new, v_new, idx, *, valid, out_dtype,
            blk, quantize_out):
    return fused_decode_ref(q, ck, cks, cv, cvs, k_new, v_new, idx,
                            valid=valid, out_dtype=out_dtype, blk=blk,
                            quantize_out=quantize_out)


def fused_decode(q, cache_k, cache_ks, cache_v, cache_vs, k_new, v_new, idx,
                 *, valid=None, out_dtype=jnp.float32,
                 backend: Optional[str] = None, blk: int = 512,
                 cache_verr=None, quantize_out: bool = False):
    """Fused decode step: append-quantize the new token, attend, and
    (optionally) re-quantize the output row for the W8A8 wo projection.

    q [B, Hq, hd]; cache leaves as in ``kv_attention_decode``; k_new/v_new
    [B, 1, Hkv, hd]; idx [B, 1] per-slot ring offsets (or [1] shared);
    ``valid`` [B|1, S] marks live cache positions (must include the new
    token's). Returns ``(out, updated_leaves)``, where ``out`` becomes the
    triple ``(out, out_q [B, Hq·hd] int8, out_scale [B])`` under
    ``quantize_out=True``.
    """
    if cache_verr is not None:
        if backend not in (None, "xla"):
            raise ValueError(
                f"fused_decode: V bias correction (cache_verr) lives on the "
                f"XLA composition only, got backend={backend!r}; pass "
                f"backend='xla' or drop cache_verr")
        return _compose(q, cache_k, cache_ks, cache_v, cache_vs, k_new,
                        v_new, idx, valid=valid, out_dtype=out_dtype,
                        blk=blk, quantize_out=quantize_out, backend="xla",
                        cache_verr=cache_verr)
    impl = resolve("fused_decode", backend)
    return impl(q, cache_k, cache_ks, cache_v, cache_vs, k_new, v_new, idx,
                valid=valid, out_dtype=out_dtype, blk=blk,
                quantize_out=quantize_out)


@register_spec("fused_decode")
def _spec(*, head_dim: int = 16, n_kv_heads: int = 2, n_q_heads: int = 4,
          seq: int = 32, batch: int = 2, **_):
    B, S, Hq, Hkv, hd = batch, seq, n_q_heads, n_kv_heads, head_dim
    return (fused_decode,
            (jnp.zeros((B, Hq, hd), jnp.float32),        # q
             jnp.zeros((B, S, Hkv, hd), jnp.int8),       # cache_k
             jnp.ones((B, S, Hkv), jnp.float32),         # cache_ks
             jnp.zeros((B, S, Hkv, hd), jnp.int8),       # cache_v
             jnp.ones((B, S, Hkv), jnp.float32),         # cache_vs
             jnp.zeros((B, 1, Hkv, hd), jnp.float32),    # k_new
             jnp.zeros((B, 1, Hkv, hd), jnp.float32),    # v_new
             jnp.zeros((B, 1), jnp.int32)),              # idx
            {"valid": jnp.ones((B, S), bool),
             "quantize_out": True})
