"""The one kernel-dispatch registry every serving op resolves through.

Before this module each ``kernels/*/ops.py`` carried its own copy of the
backend selector (``default_backend()`` + ``interpret=(backend == ...)``)
and its own ``_pad_to`` — four drifting copies of the same policy.  Now:

  * ``@register_impl(op, backend, pad=...)`` registers one implementation
    of ``op`` at one backend **tier** — ``pallas`` (the TPU kernel),
    ``xla`` (pure-XLA fallback, the folded-scale production path off-TPU),
    ``interpret`` (the Pallas kernel in interpret mode — bit-exact CPU
    validation of the TPU lowering), ``ref`` (the blocked pure-jnp oracle
    the tests pin against).
  * ``resolve(op, backend=None)`` returns the implementation: an explicit
    ``backend`` argument wins, else the ``REPRO_KERNEL_BACKEND`` env var,
    else ``default_backend()`` (pallas on TPU, interpret elsewhere —
    the validation default).
  * ``serving_backend(pallas_ok=True)`` is the single copy of the
    *production* ternary every hot call site used to inline ("pallas" on
    TPU, folded-scale "xla" elsewhere); it honors the same env override.
  * ``register_spec(op)`` registers the op's representative smoke-shape
    argument builder, so ``kernels.serving_kernel_specs()`` (and through
    it the QuantLint graph extractor) enumerates the registry instead of
    a hand-maintained dict — a new kernel package registers itself and is
    linted without touching the lint layer.

Padding is policy too: ``_pad_to`` lives here (the previously copy-pasted
helper), and every impl declares its pad convention — ``"zero"`` (GEMMs:
zero rows/cols contribute exact zeros to the contraction) or
``"zero-scale"`` (attention: padded positions carry scale 0, the "invalid"
marker the masking keys on).  Registering two impls of one op under
*different* conventions is an error at import time: silently mixing them is
exactly the class of bug where one backend masks padding and another
contracts over it.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

ENV_VAR = "REPRO_KERNEL_BACKEND"

#: backend tiers in resolution-priority order (also the display order)
TIERS = ("pallas", "xla", "interpret", "ref")

#: pad/mask conventions an impl may declare (None = op never pads)
PAD_CONVENTIONS = ("zero", "zero-scale")

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_PAD: Dict[str, str] = {}
_SPECS: Dict[str, Callable] = {}


def _pad_to(x, m: int, axis: int):
    """Right-pad ``x`` along ``axis`` to a multiple of ``m`` (zeros)."""
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def register_impl(op: str, backend: str, *, pad: Optional[str] = None):
    """Decorator: register ``fn`` as ``op``'s implementation at ``backend``.

    ``pad`` declares the impl's padding/masking convention; all impls of an
    op must agree (or declare nothing) — a conflict raises immediately.
    """
    if backend not in TIERS:
        raise ValueError(
            f"register_impl({op!r}): unknown backend tier {backend!r}; "
            f"tiers are {TIERS}")
    if pad is not None and pad not in PAD_CONVENTIONS:
        raise ValueError(
            f"register_impl({op!r}, {backend!r}): unknown pad convention "
            f"{pad!r}; conventions are {PAD_CONVENTIONS}")

    def deco(fn: Callable) -> Callable:
        impls = _REGISTRY.setdefault(op, {})
        if backend in impls and impls[backend] is not fn:
            raise ValueError(
                f"register_impl: {op!r} already has a {backend!r} impl "
                f"({impls[backend].__name__}); refusing to shadow it with "
                f"{fn.__name__}")
        if pad is not None:
            prev = _PAD.get(op)
            if prev is not None and prev != pad:
                raise ValueError(
                    f"register_impl: {op!r} impls disagree on the pad "
                    f"convention — existing impls declare {prev!r}, "
                    f"{fn.__name__} ({backend!r}) declares {pad!r}. One op "
                    f"= one convention: a mixed op would mask padding on "
                    f"one backend and contract over it on another.")
            _PAD[op] = pad
        impls[backend] = fn
        return fn

    return deco


def ops() -> tuple:
    """The registered op names, sorted."""
    return tuple(sorted(_REGISTRY))


def backends(op: str) -> tuple:
    """The backend tiers ``op`` has implementations for, in tier order."""
    impls = _registered(op)
    return tuple(t for t in TIERS if t in impls)


def pad_convention(op: str) -> Optional[str]:
    """The pad convention ``op``'s impls declared (None = never pads)."""
    _registered(op)
    return _PAD.get(op)


def _registered(op: str) -> Dict[str, Callable]:
    try:
        return _REGISTRY[op]
    except KeyError:
        raise KeyError(
            f"unknown kernel op {op!r}; registered ops: "
            f"{', '.join(sorted(_REGISTRY)) or '(none)'}") from None


def default_backend() -> str:
    """The *validation* default: the real kernel on TPU, its interpret-mode
    twin elsewhere (bit-exact to the TPU lowering, slow)."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def serving_backend(pallas_ok: bool = True) -> str:
    """The *production* default every serving call site resolves with: the
    Pallas kernel on TPU, the folded-scale XLA op elsewhere (interpret mode
    is far too slow to serve through).  ``pallas_ok=False`` forces the XLA
    tier even on TPU — e.g. a feature only the XLA path implements (V-bias
    correction).  The ``REPRO_KERNEL_BACKEND`` env override wins over both.
    """
    env = os.environ.get(ENV_VAR)
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" and pallas_ok else "xla"


def resolve(op: str, backend: Optional[str] = None) -> Callable:
    """Return ``op``'s implementation: explicit ``backend`` > the
    ``REPRO_KERNEL_BACKEND`` env var > ``default_backend()``."""
    impls = _registered(op)
    chosen = backend or os.environ.get(ENV_VAR) or default_backend()
    try:
        return impls[chosen]
    except KeyError:
        raise ValueError(
            f"op {op!r} has no {chosen!r} implementation; registered "
            f"tiers: {', '.join(backends(op))}") from None


def count_pallas_calls(fn: Callable, *args, **kwargs) -> int:
    """Kernel launches in ``fn``'s traced jaxpr — the dispatch count a TPU
    step would issue, counted from the trace so it is exact on any host
    (recursing through scan/cond/pjit bodies). This is THE metric the
    fused-decode megakernel exists to shrink."""

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                        v, is_leaf=lambda x: hasattr(x, "jaxpr")):
                    if hasattr(sub, "jaxpr"):
                        n += walk(sub.jaxpr)
        return n

    return walk(jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args).jaxpr)


def register_spec(op: str):
    """Decorator: register ``op``'s smoke-shape spec builder — a callable
    ``(**shape_kw) -> (fn, args, kwargs)`` the lint layer traces/lowers.
    Ops without a spec (pure-composition wrappers) simply don't register.
    """

    def deco(build: Callable) -> Callable:
        if op in _SPECS and _SPECS[op] is not build:
            raise ValueError(f"register_spec: {op!r} already has a spec")
        _SPECS[op] = build
        return build

    return deco


def iter_specs(**shape_kw) -> Dict[str, Any]:
    """{op: (fn, args, kwargs)} over every registered spec builder."""
    return {op: _SPECS[op](**shape_kw) for op in sorted(_SPECS)}
