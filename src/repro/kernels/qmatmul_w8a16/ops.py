"""Public weight-only GEMM op, registry-dispatched.

``quantize_out=True`` selects the epilogue variant emitting (int8, per-row
scale) — validated against the blocked ``qmatmul_w8a16_q8_ref`` oracle
(fp32 accumulation order matters here, unlike the int32-exact W8A8 case).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..dispatch import _pad_to, register_impl, register_spec, resolve
from .kernel import qmatmul_w8a16_pallas, qmatmul_w8a16_q8_pallas
from .ref import qmatmul_w8a16_q8_ref, qmatmul_w8a16_ref


def _pallas_impl(a, w_q, w_scale, bias, *, out_dtype, bm, bn, bk,
                 quantize_out, interpret):
    M, K = a.shape
    N = w_q.shape[1]
    bm_e = min(bm, max(1, M))
    bk_e = min(bk, K)
    a_p = _pad_to(_pad_to(a, bm_e, 0), bk_e, 1)
    if quantize_out:
        w_p = _pad_to(_pad_to(w_q, bk_e, 0), 128, 1)
        q, s = qmatmul_w8a16_q8_pallas(
            a_p, w_p, _pad_to(w_scale, 128, 0), _pad_to(bias, 128, 0),
            bm=bm_e, bk=bk_e, interpret=interpret)
        return q[:M, :N], s[:M]
    bn_e = min(bn, N)
    w_p = _pad_to(_pad_to(w_q, bk_e, 0), bn_e, 1)
    out = qmatmul_w8a16_pallas(
        a_p, w_p, _pad_to(w_scale, bn_e, 0), _pad_to(bias, bn_e, 0),
        bm=bm_e, bn=bn_e, bk=bk_e, out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:M, :N]


@register_impl("qmatmul_w8a16", "pallas", pad="zero")
def _w8a16_pallas(a, w_q, w_scale, bias, *, out_dtype, bm, bn, bk,
                  quantize_out):
    return _pallas_impl(a, w_q, w_scale, bias, out_dtype=out_dtype, bm=bm,
                        bn=bn, bk=bk, quantize_out=quantize_out,
                        interpret=False)


@register_impl("qmatmul_w8a16", "interpret", pad="zero")
def _w8a16_interpret(a, w_q, w_scale, bias, *, out_dtype, bm, bn, bk,
                     quantize_out):
    return _pallas_impl(a, w_q, w_scale, bias, out_dtype=out_dtype, bm=bm,
                        bn=bn, bk=bk, quantize_out=quantize_out,
                        interpret=True)


@register_impl("qmatmul_w8a16", "xla", pad="zero")
@register_impl("qmatmul_w8a16", "ref", pad="zero")
def _w8a16_ref(a, w_q, w_scale, bias, *, out_dtype, bm, bn, bk,
               quantize_out):
    if quantize_out:
        return qmatmul_w8a16_q8_ref(a, w_q, w_scale, bias, bk=bk)
    return qmatmul_w8a16_ref(a, w_q, w_scale, bias, out_dtype)


def qmatmul_w8a16(
    a: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    out_dtype=jnp.bfloat16,
    backend: Optional[str] = None,
    bm: int = 8,
    bn: int = 512,
    bk: int = 1024,
    quantize_out: bool = False,
):
    """y = a @ dequant(w_q) + bias; ``quantize_out=True`` returns
    (y_q int8 [M,N], y_scale fp32 [M]) from the fused epilogue instead."""
    impl = resolve("qmatmul_w8a16", backend)
    M, K = a.shape
    N = w_q.shape[1]
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (N,))
    bias = jnp.zeros((N,), jnp.float32) if bias is None else bias.astype(jnp.float32)
    return impl(a, w_q, w_scale, bias, out_dtype=out_dtype, bm=bm, bn=bn,
                bk=bk, quantize_out=quantize_out)


@register_spec("qmatmul_w8a16")
def _spec(*, d_in: int = 64, d_out: int = 128, **_):
    M, K, N = 8, d_in, d_out
    return (qmatmul_w8a16,
            (jnp.zeros((M, K), jnp.float32), jnp.zeros((K, N), jnp.int8),
             jnp.ones((N,), jnp.float32)),
            {"out_dtype": jnp.float32})
