"""Public weight-only GEMM op with padding + backend selection."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import qmatmul_w8a16_pallas
from .ref import qmatmul_w8a16_ref


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qmatmul_w8a16(
    a: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    out_dtype=jnp.bfloat16,
    backend: Optional[str] = None,
    bm: int = 8,
    bn: int = 512,
    bk: int = 1024,
):
    backend = backend or ("pallas" if jax.default_backend() == "tpu" else "interpret")
    M, K = a.shape
    N = w_q.shape[1]
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (N,))
    bias = jnp.zeros((N,), jnp.float32) if bias is None else bias.astype(jnp.float32)
    if backend == "xla":
        return qmatmul_w8a16_ref(a, w_q, w_scale, bias, out_dtype)
    bm_e = min(bm, max(1, M))
    bn_e = min(bn, N)
    bk_e = min(bk, K)
    a_p = _pad_to(_pad_to(a, bm_e, 0), bk_e, 1)
    w_p = _pad_to(_pad_to(w_q, bk_e, 0), bn_e, 1)
    out = qmatmul_w8a16_pallas(
        a_p, w_p, _pad_to(w_scale, bn_e, 0), _pad_to(bias, bn_e, 0),
        bm=bm_e, bn=bn_e, bk=bk_e, out_dtype=out_dtype,
        interpret=(backend == "interpret"),
    )
    return out[:M, :N]
