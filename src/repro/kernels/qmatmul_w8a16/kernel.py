"""Pallas TPU kernel: bf16 activations × int8 weights, dequant-in-VMEM.

The decode-shape kernel: weights stream from HBM as int8 (half the bytes of
bf16 ⇒ ~2× the HBM roofline for the memory-bound single-token GEMM) and are
dequantized to bf16 inside VMEM right before the MXU dot. fp32 accumulation
via a VMEM scratch; bias/scale epilogue on the last K step.

Decode blocks default to (bm, bn, bk) = (8, 512, 1024): M is the (small)
batch; wide N amortizes the per-block scale/bias loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    _SCRATCH = lambda bm, bn: [pltpu.VMEM((bm, bn), jnp.float32)]
    _PARAMS = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda bm, bn: [jax.ShapeDtypeStruct((bm, bn), jnp.float32)]
    _PARAMS = lambda: {}


def _kernel(a_ref, w_ref, sw_ref, bias_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(a_ref.dtype)  # int8 → compute dtype, in VMEM
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...] * sw_ref[...][None, :] + bias_ref[...][None, :]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def qmatmul_w8a16_pallas(
    a: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bm: int = 8,
    bn: int = 512,
    bk: int = 1024,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
):
    M, K = a.shape
    K2, N = w_q.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=_SCRATCH(bm, bn),
        interpret=interpret,
        **_PARAMS(),
    )(a, w_q, w_scale.astype(jnp.float32), bias.astype(jnp.float32))
