"""Pallas TPU kernel: bf16 activations × int8 weights, dequant-in-VMEM.

The decode-shape kernel: weights stream from HBM as int8 (half the bytes of
bf16 ⇒ ~2× the HBM roofline for the memory-bound single-token GEMM) and are
dequantized to bf16 inside VMEM right before the MXU dot. fp32 accumulation
via a VMEM scratch; bias/scale epilogue on the last K step.

Decode blocks default to (bm, bn, bk) = (8, 512, 1024): M is the (small)
batch; wide N amortizes the per-block scale/bias loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    _SCRATCH = lambda bm, bn: [pltpu.VMEM((bm, bn), jnp.float32)]
    _PARAMS = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    )
    _PARAMS_MK = lambda: dict(
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda bm, bn: [jax.ShapeDtypeStruct((bm, bn), jnp.float32)]
    _PARAMS = lambda: {}
    _PARAMS_MK = lambda: {}


def _kernel(a_ref, w_ref, sw_ref, bias_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(a_ref.dtype)  # int8 → compute dtype, in VMEM
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...] * sw_ref[...][None, :] + bias_ref[...][None, :]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def qmatmul_w8a16_pallas(
    a: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bm: int = 8,
    bn: int = 512,
    bk: int = 1024,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
):
    M, K = a.shape
    K2, N = w_q.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=_SCRATCH(bm, bn),
        interpret=interpret,
        **_PARAMS(),
    )(a, w_q, w_scale.astype(jnp.float32), bias.astype(jnp.float32))


def _kernel_q8(a_ref, w_ref, sw_ref, bias_ref, q_ref, s_ref, acc_ref,
               *, n_k, qmax):
    """Quantize-out epilogue: per-row absmax + round in VMEM on the last K
    step — the GEMM hands the next W8A8 layer int8 directly."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(a_ref.dtype)  # int8 → compute dtype, in VMEM
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...] * sw_ref[...][None, :] + bias_ref[...][None, :]
        amax = jnp.max(jnp.abs(out), axis=-1)
        scale = jnp.maximum(amax, 1e-8) / qmax
        q = jnp.clip(jnp.round(out / scale[:, None]), -qmax - 1, qmax)
        q_ref[...] = q.astype(jnp.int8)
        s_ref[...] = scale


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bits", "interpret")
)
def qmatmul_w8a16_q8_pallas(
    a: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bm: int = 8,
    bk: int = 1024,
    bits: int = 8,
    interpret: bool = False,
):
    """Weight-only GEMM emitting (int8 out, per-row scale). Single N block
    (the row absmax needs the whole row in the epilogue) → grid (M/bm, K/bk)."""
    M, K = a.shape
    K2, N = w_q.shape
    assert K == K2 and M % bm == 0 and K % bk == 0
    n_k = K // bk
    qmax = 2 ** (bits - 1) - 1
    grid = (M // bm, n_k)
    return pl.pallas_call(
        functools.partial(_kernel_q8, n_k=n_k, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, N), lambda i, k: (k, 0)),
            pl.BlockSpec((N,), lambda i, k: (0,)),
            pl.BlockSpec((N,), lambda i, k: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, N), lambda i, k: (i, 0)),
            pl.BlockSpec((bm,), lambda i, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M,), jnp.float32),
        ],
        scratch_shapes=_SCRATCH(bm, N),
        interpret=interpret,
        **_PARAMS_MK(),
    )(a, w_q, w_scale.astype(jnp.float32), bias.astype(jnp.float32))
