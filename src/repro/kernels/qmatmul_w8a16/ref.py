"""Pure-jnp oracle for the weight-only (W8A16) GEMM."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def qmatmul_w8a16_ref(
    a: jnp.ndarray,            # [M, K] bf16/f32 activations
    w_q: jnp.ndarray,          # [K, N] int8 (symmetric)
    w_scale: jnp.ndarray,      # [N] or scalar
    bias: Optional[jnp.ndarray] = None,
    out_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    w = w_q.astype(jnp.float32) * jnp.atleast_1d(w_scale)[None, :]
    out = jnp.matmul(a.astype(jnp.float32), w)
    if bias is not None:
        out = out + bias[None, :]
    return out.astype(out_dtype)


def qmatmul_w8a16_q8_ref(
    a: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    bits: int = 8,
    *,
    bk: int = 1024,
):
    """Blocked quantize-out oracle. Unlike the int32-exact W8A8 case, the
    weight-only GEMM accumulates in fp32 — so this oracle mirrors the
    kernel's K-block loop (dequant per block, f32 partial sums in kernel
    order) before applying the ``quantize_act`` epilogue formula, keeping
    the interpret-mode kernel bit-identical to the oracle for any K."""
    M, K = a.shape
    N = w_q.shape[1]
    bk_e = min(bk, K)
    pad = (-K) % bk_e
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        w_q = jnp.pad(w_q, ((0, pad), (0, 0)))
    acc = jnp.zeros((M, N), jnp.float32)
    for k0 in range(0, K + pad, bk_e):
        w_blk = w_q[k0:k0 + bk_e].astype(a.dtype)
        acc = acc + jax.lax.dot_general(
            a[:, k0:k0 + bk_e], w_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out = acc * jnp.atleast_1d(w_scale)[None, :]
    if bias is not None:
        out = out + bias[None, :]
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(out), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(out / scale[:, None]), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale
