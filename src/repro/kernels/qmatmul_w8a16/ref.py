"""Pure-jnp oracle for the weight-only (W8A16) GEMM."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def qmatmul_w8a16_ref(
    a: jnp.ndarray,            # [M, K] bf16/f32 activations
    w_q: jnp.ndarray,          # [K, N] int8 (symmetric)
    w_scale: jnp.ndarray,      # [N] or scalar
    bias: Optional[jnp.ndarray] = None,
    out_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    w = w_q.astype(jnp.float32) * jnp.atleast_1d(w_scale)[None, :]
    out = jnp.matmul(a.astype(jnp.float32), w)
    if bias is not None:
        out = out + bias[None, :]
    return out.astype(out_dtype)
