"""Elastic scaling: resume the same logical job on a different mesh.

Checkpoints are mesh-independent (host arrays); the two things that must be
recomputed on a world-size change are (a) leaf shardings for the new mesh and
(b) the data-shard assignment. Both are pure functions here, so an elastic
restart is:  mesh' = make_production_mesh(...) → elastic_restore(...) →
continue at the restored step.
"""
from __future__ import annotations

from typing import Any, Optional

from jax.sharding import Mesh

from ..checkpoint import Checkpointer
from ..sharding import named_shardings, params_pspecs


def elastic_restore(
    ckpt: Checkpointer,
    target_tree: Any,
    new_mesh: Mesh,
    step: Optional[int] = None,
):
    """Restore a checkpoint onto a NEW mesh (different shape/size than the
    one it was written from)."""
    specs = params_pspecs(target_tree, new_mesh)
    shardings = named_shardings(specs, new_mesh)
    return ckpt.restore(target_tree, step=step, shardings=shardings)


def shard_assignment(global_batch: int, world: int, host: int) -> tuple[int, int]:
    """(shard_index, per_host_batch) under the current world size. Data
    streams key on the GLOBAL shard index so a host joining/leaving changes
    only the assignment, never the content of a shard."""
    assert global_batch % world == 0, (global_batch, world)
    return host, global_batch // world
