from .fault_tolerance import FaultTolerantLoop, StragglerMonitor  # noqa: F401
from .elastic import elastic_restore, shard_assignment  # noqa: F401
