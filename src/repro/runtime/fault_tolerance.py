"""Fault tolerance: retry-with-restore, preemption, straggler mitigation.

At 1000+ nodes, *something* fails every few minutes. The loop here assumes:

  * the step function is pure (params, opt, batch) → (params, opt, metrics),
    so any step can be replayed from the last checkpoint;
  * the data pipeline is a pure function of (seed, step, shard)
    (``repro.data.synthetic``) — replaying a step re-reads identical data;
  * checkpoints are atomic and mesh-independent (``repro.checkpoint``), so a
    restart may come up with a different world size (→ ``runtime.elastic``).

Mechanisms:
  * **retry-with-restore** — a failing step (device error, NaN loss if
    ``abort_on_nan``) restores the latest checkpoint and replays; bounded
    retries per step index to avoid crash loops,
  * **preemption hook** — SIGTERM sets a flag; the loop checkpoints at the
    next step boundary and exits cleanly (standard TPU preemption contract),
  * **straggler monitor** — per-step wall-time EMA; steps slower than
    ``threshold ×`` EMA are logged and counted, and a user hook can
    re-dispatch work (on real fleets: mark the host suspect / trigger the
    elastic path). Synchronous SPMD turns one slow host into a slow fleet —
    detection is the actionable signal.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..checkpoint import Checkpointer


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ema_decay: float = 0.9,
                 warmup_steps: int = 3,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.ema_decay = ema_decay
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.events: list[tuple[int, float, float]] = []
        self._seen = 0
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        self._seen += 1
        if self._seen <= self.warmup:
            return False
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = dt > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, dt, self.ema))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
        # slow steps don't poison the EMA
        self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * min(
            dt, self.threshold * self.ema
        )
        return is_straggler


@dataclasses.dataclass
class LoopMetrics:
    steps_run: int = 0
    retries: int = 0
    restores: int = 0
    preempted: bool = False
    straggler_events: int = 0
    last_loss: float = float("nan")


class FaultTolerantLoop:
    def __init__(
        self,
        step_fn: Callable[[Any, Any, Any], tuple],   # (state, batch) → (state, metrics)
        data_fn: Callable[[int], Any],               # step → batch
        checkpointer: Checkpointer,
        *,
        ckpt_every: int = 50,
        max_retries_per_step: int = 2,
        abort_on_nan: bool = True,
        install_sigterm: bool = False,
        straggler: Optional[StragglerMonitor] = None,
    ):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries_per_step
        self.abort_on_nan = abort_on_nan
        self.straggler = straggler or StragglerMonitor()
        self.metrics = LoopMetrics()
        self._preempt = False
        if install_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, *_):
        self._preempt = True

    def request_preemption(self):
        """Testable preemption entry point (same path as SIGTERM)."""
        self._preempt = True

    def run(self, state: Any, start_step: int, num_steps: int,
            inject_failure: Optional[Callable[[int], bool]] = None):
        """Run [start_step, start_step + num_steps). ``inject_failure(step)``
        is a test hook that raises inside the step when it returns True."""
        step = start_step
        end = start_step + num_steps
        retries_here = 0
        while step < end:
            if self._preempt:
                self.ckpt.save(step, state, blocking=True)
                self.metrics.preempted = True
                return state, step
            t0 = time.monotonic()
            try:
                if inject_failure is not None and inject_failure(step):
                    raise RuntimeError(f"injected failure at step {step}")
                batch = self.data_fn(step)
                state, m = self.step_fn(state, batch)
                loss = float(m.get("loss", np.nan)) if isinstance(m, dict) else float(m)
                if self.abort_on_nan and not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                self.metrics.last_loss = loss
            except Exception:
                retries_here += 1
                self.metrics.retries += 1
                if retries_here > self.max_retries:
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, restored_step = self.ckpt.restore(state)
                    step = restored_step
                    self.metrics.restores += 1
                continue
            if self.straggler.observe(step, time.monotonic() - t0):
                self.metrics.straggler_events += 1
            retries_here = 0
            step += 1
            self.metrics.steps_run += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.save(end, state, blocking=True)
        return state, end
