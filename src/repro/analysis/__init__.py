from .roofline import (  # noqa: F401
    HW_V5E,
    collective_bytes_from_hlo,
    model_flops,
    roofline_report,
)
