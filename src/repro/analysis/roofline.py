"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh), from the assignment's formulas with
TPU v5e constants:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` gives per-device FLOPs/bytes of the SPMD module;
we convert to global (× chips) before the formulas (so both conventions
agree). Collective bytes are NOT in cost_analysis — we parse the optimized
per-device HLO and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, × chips.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

HW_V5E = {
    "peak_flops_bf16": 197e12,       # per chip
    "peak_flops_int8": 394e12,       # MXU int8 = 2× bf16 on v5e
    "hbm_bw": 819e9,                 # bytes/s per chip
    "ici_bw": 50e9,                  # bytes/s per link (assignment constant)
    "hbm_per_chip": 16e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device collective result bytes by op kind, from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        # "%name = TYPE op-name(...)" — match the op position to avoid
        # counting fusions whose operands merely mention a collective name.
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:[a-z0-9\[\],{}: ]+?))\s+"
                     r"([a-z\-]+?)(-start|-done)?\(", s)
        if not m:
            continue
        type_str, base, phase = m.group(1), m.group(2), m.group(3)
        if base in _COLLECTIVES and phase != "-done":
            b = _shape_bytes(type_str)
            # XLA:CPU promotes bf16 all-reduce accumulation to f32 (the
            # reduction computation gets a "_promoted" suffix); on the TPU
            # target the wire payload stays bf16 — count at true width.
            if "_promoted" in s and "f32[" in type_str:
                b //= 2
            out[base] += b
            counts[base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def analytic_hbm_bytes(cfg, shape, *, chips: int, model_n: int = 16,
                       quantized: bool = False) -> float:
    """Per-device HBM traffic estimate for one step (TPU-fusion view).

    XLA:CPU's ``bytes accessed`` counts every unfused elementwise op — on TPU
    those fuse into VMEM-resident loops, so the HLO number overstates HBM
    traffic ~10×. This analytic floor counts only HBM-resident tensors:

      train:   weight shards ×3 passes (fwd + 2 bwd) + fp32 grads + AdamW
               state r/w + per-layer activation checkpoints + sharded logits,
      prefill: weight shard ×1 + activation stream + KV-cache write,
      decode:  weight shard ×1 (the W8A16 target halves this) + KV/SSM cache
               read + tiny activations.
    """
    dp_n = chips // model_n
    N = cfg.param_count()
    Na = cfg.active_param_count()
    B_loc = max(1, shape.global_batch // dp_n)
    T = shape.seq_len
    D = cfg.d_model
    L = cfg.n_layers + cfg.n_enc_layers
    V_loc = cfg.vocab_size / model_n
    kv_dim = 2 * cfg.kv_dim if cfg.n_kv_heads else 0
    ssm_state_bytes = 0
    if cfg.ssm_state:
        ssm_state_bytes = cfg.n_layers * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4

    w_bytes = 1 if quantized else 2           # int8 (W8A16) halves weight HBM
    w_shard = N / model_n * w_bytes
    w_active_shard = Na / model_n * w_bytes
    opt = N / chips * 4 * 6                   # fp32 p/m/v read+write
    grads = N / chips * 4 * 2                 # fp32 grad reduce-scatter r/w

    if shape.kind == "train":
        acts = L * B_loc * T * D * 2 * 2 * 2  # ckpt write+read, fwd+bwd
        logits = B_loc * T * V_loc * 4 * 2 * 2
        return 3 * w_shard + opt + grads + acts + logits
    if shape.kind == "prefill":
        acts = L * B_loc * T * D * 2 * 2
        cache_w = cfg.n_layers * B_loc * min(T, cfg.sliding_window or T) * kv_dim * 2
        return w_active_shard + acts + cache_w
    # decode: one token
    S = min(T, cfg.sliding_window or T)
    cache_layers = cfg.n_layers
    if cfg.family == "hybrid":
        cache_layers = cfg.n_layers // max(cfg.hybrid_attn_every, 1)
    kv_bytes = cfg.kv_cache_bits / 8 if hasattr(cfg, "kv_cache_bits") else 2
    cache_r = cache_layers * B_loc * S * kv_dim * kv_bytes
    state_rw = B_loc * ssm_state_bytes * 2
    return w_active_shard + cache_r + state_rw + B_loc * (L * D * 2 * 4 + V_loc * 4)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D for training (dense; N_active for MoE), 2·N·D for
    inference-forward — the "useful work" yardstick."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   shape.seq_len if shape.kind == "prefill" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float                 # from HLO bytes (formula; CPU-fusion upper bound)
    collective_s: float
    flops_global: float
    bytes_global: float
    collective_bytes_global: float
    model_flops: float
    chips: int
    memory_analytic_s: float = 0.0  # analytic HBM floor (TPU-fusion view)

    @property
    def dominant(self) -> str:
        """Bottleneck classification uses the ANALYTIC memory term — the HLO
        byte count is reported alongside as the pessimistic bound."""
        terms = {"compute": self.compute_s, "memory": self.memory_analytic_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_analytic_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the roofline the USEFUL work achieves if the program
        runs at its bound: (model_flops / peak) / bound_time."""
        ideal = self.model_flops / (self.chips * HW_V5E["peak_flops_bf16"])
        return ideal / max(self.bound_time_s, 1e-30)


def roofline_report(
    per_device_flops: float,
    per_device_bytes: float,
    per_device_collective_bytes: float,
    chips: int,
    cfg=None,
    shape=None,
    mf: Optional[float] = None,
    quantized: bool = False,
) -> RooflineTerms:
    flops_g = per_device_flops * chips
    bytes_g = per_device_bytes * chips
    coll_g = per_device_collective_bytes * chips
    mf = mf if mf is not None else (model_flops(cfg, shape) if cfg else 0.0)
    mem_an = 0.0
    if cfg is not None and shape is not None:
        mem_an = analytic_hbm_bytes(cfg, shape, chips=chips,
                                    quantized=quantized) / HW_V5E["hbm_bw"]
    return RooflineTerms(
        compute_s=flops_g / (chips * HW_V5E["peak_flops_bf16"]),
        memory_s=bytes_g / (chips * HW_V5E["hbm_bw"]),
        collective_s=coll_g / (chips * HW_V5E["ici_bw"]),
        flops_global=flops_g,
        bytes_global=bytes_g,
        collective_bytes_global=coll_g,
        model_flops=mf,
        chips=chips,
        memory_analytic_s=mem_an,
    )
