"""HLO collective diagnostics — the dry-run 'profiler' (DESIGN §7).

Groups every collective in an optimized per-device module by (op, shape) and
ranks by bytes: the hypothesis generator for the perf loop.

Built on ``analysis.lint.hlo_model``'s real instruction parser rather than a
regex per line — the old regex dropped any result type carrying a layout
annotation (``{1,0:T(8,128)}`` nests parens) or a tuple (async
``all-reduce-start`` results), silently under-counting exactly the largest
collectives. ``shape_bytes`` now warns (once per dtype) and counts 0 for
dtypes it does not know instead of silently skipping them.
"""
from __future__ import annotations

from .lint.hlo_model import COLLECTIVE_OPS, parse_hlo_module, type_bytes


def shape_bytes(type_str: str) -> int:
    """Total payload bytes of an HLO type string (arrays, tuples, layouts).
    Unknown dtypes contribute 0 — with a warning, never silently."""
    return type_bytes(type_str, warn_unknown=True)


def top_collectives(hlo_text: str, k: int = 15):
    """Top-k collectives by aggregate result bytes: rows of
    ``(bytes, count, base_opcode, result_type[:70])``. Async pairs count
    once (``-done`` halves are skipped; a ``-start``'s operand/result tuple
    is halved so the transferred payload is not double-counted)."""
    module = parse_hlo_module(hlo_text)
    agg: dict = {}
    for instr in module.collectives():
        if instr.base_opcode not in COLLECTIVE_OPS:
            continue
        key = (instr.base_opcode, instr.result_type[:70])
        b, n = agg.get(key, (0, 0))
        agg[key] = (b + instr.result_bytes(), n + 1)
    rows = [(b, n, base, t) for (base, t), (b, n) in agg.items()]
    rows.sort(reverse=True)
    return rows[:k]


def print_top(hlo_text: str, k: int = 15):
    for b, n, base, t in top_collectives(hlo_text, k):
        print(f"{b/1e9:9.3f} GB  ×{n:<4d} {base:18s} {t}")
