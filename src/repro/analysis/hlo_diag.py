"""HLO collective diagnostics — the dry-run 'profiler' (DESIGN §7).

Groups every collective in an optimized per-device module by (op, shape) and
ranks by bytes: the hypothesis generator for the perf loop.
"""
from __future__ import annotations

import collections
import re

from .roofline import _DTYPE_BYTES, _SHAPE_RE


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(",") if dims else []:
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def top_collectives(hlo_text: str, k: int = 15):
    agg = collections.Counter()
    count = collections.Counter()
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:[a-z0-9\[\],{}: ]+?))\s+"
            r"([a-z\-]+?)(-start|-done)?\(", s)
        if not m:
            continue
        tstr, base, phase = m.groups()
        if base in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute") and phase != "-done":
            key = (base, tstr[:70])
            agg[key] += shape_bytes(tstr)
            count[key] += 1
    rows = [(b, n, base, t) for (base, t), b in agg.items()
            for n in [count[(base, t)]]]
    rows.sort(reverse=True)
    return rows[:k]


def print_top(hlo_text: str, k: int = 15):
    for b, n, base, t in top_collectives(hlo_text, k):
        print(f"{b/1e9:9.3f} GB  ×{n:<4d} {base:18s} {t}")
