"""The lint rule registry and the five core serving-graph rules.

A rule is a function ``fn(graph, contract) -> list[Finding]`` registered
under a name (mirroring the pipeline's ``@register_stage`` idiom) —
external code can add project-specific rules without touching the runner:

    @register_rule("my-rule")
    def my_rule(graph, contract):
        return [Finding("my-rule", "error", jit="decode", where="...",
                        message="...")]

``graph`` is an ``extract.LintGraph`` (duck-typed — the tests drive rules
with hand-built miniatures); ``contract`` is the parsed contract JSON for
the recipe, or ``None`` when none exists yet (structural checks still run;
contract-relative budgets are skipped).

The five core rules:

  * **dtype-ledger** — no float materialization of int8 weights/KV on the
    serve path: every ``convert`` from s8 at full-cache size must feed a
    contraction (the scale folds downstream), never an elementwise
    dequantize-multiply. Decode jits are strict; the chunked-prefill dequant
    (by design, for now) must be pinned as contract ``known_debt``. All s8
    converts are tallied into a per-jit ledger diffed against the contract.
  * **collective-budget** — per-jit (count, bytes) of every collective kind
    must match the contract exactly; any collective whose result is a whole
    cache-pool leaf is an error under TP unless pinned as ``known_debt``
    (the PR-5 pooled ``take``/``.at[].set`` prefill gather).
  * **donation-audit** — every cache-pool leaf must appear in the compiled
    module's ``input_output_alias`` map on every engine jit (the pool
    updates in place; a dropped alias doubles cache HBM silently).
  * **recompilation-guard** — the dispatchable shape set (every prefill
    width / decode horizon the runtime can choose) must be CLOSED under the
    warmup set, and the warmup set must match the contract — a decode step
    may never introduce a new compiled shape.
  * **scale-coupling** — every int8 payload leaf's scale leaf shares its
    out-feature sharding axis (params) / its slot+head axes (KV cache), so
    a TP shard dequantizes locally without gathering foreign scales.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from .hlo_model import HloModule, parse_array_type

# jaxpr primitives that consume an int8 operand *inside* the contraction —
# the convert is fused into the dot read, nothing f32-sized materializes
_FUSED_CONSUMERS = frozenset({"dot_general", "conv_general_dilated"})

SEVERITIES = ("error", "warn", "info")


@dataclasses.dataclass
class Finding:
    rule: str
    severity: str          # "error" | "warn" | "info"
    jit: str               # jit / kernel name ("" = recipe-level)
    where: str             # instruction name, leaf path, or shape signature
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")

    def format(self) -> str:
        loc = f"{self.jit}:{self.where}" if self.where else self.jit
        return f"[{self.severity}] {self.rule} @ {loc}: {self.message}"


_RULES: dict[str, Callable] = {}


def register_rule(name: str):
    """Decorator: register ``fn(graph, contract) -> list[Finding]``."""

    def deco(fn):
        if name in _RULES:
            raise ValueError(f"lint rule {name!r} is already registered "
                             f"(by {_RULES[name].__module__})")
        _RULES[name] = fn
        return fn

    return deco


def list_rules() -> list[str]:
    return sorted(_RULES)


def run_rules(graph, contract: Optional[dict] = None,
              rules: Optional[list[str]] = None) -> list[Finding]:
    """Run ``rules`` (default: all registered) over one lint graph."""
    out: list[Finding] = []
    for name in rules or list_rules():
        try:
            fn = _RULES[name]
        except KeyError:
            raise ValueError(
                f"unknown lint rule {name!r}; registered: {list_rules()}"
            ) from None
        out.extend(fn(graph, contract))
    return out


# =========================================================== jaxpr analysis
@dataclasses.dataclass
class ConvertRecord:
    """One s8→float ``convert_element_type`` found in a traced jaxpr."""

    shape: tuple
    dtype: str
    elems: int
    consumers: tuple        # primitive names consuming the converted value
    in_pallas: bool         # inside a pallas_call body (VMEM tile — exempt)

    @property
    def fused(self) -> bool:
        return bool(self.consumers) and set(self.consumers) <= _FUSED_CONSUMERS


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else [v]
        for sub in vals:
            inner = getattr(sub, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner            # ClosedJaxpr
            elif hasattr(sub, "eqns"):
                yield sub              # raw Jaxpr


def _consumers_of(var, jaxpr, depth: int = 0) -> list[str]:
    """Primitive names that read ``var``, following 1:1 call-like primitives
    (pjit / scan map eqn.invars onto the body's invars index-wise) one level
    so a convert feeding ``pjit(dot_general)`` classifies as fused."""
    names: list[str] = []
    for eqn in jaxpr.eqns:
        if not any(v is var for v in eqn.invars):
            continue
        subs = list(_sub_jaxprs(eqn))
        followed = False
        if depth < 2 and len(subs) == 1:
            body = subs[0]
            body = getattr(body, "jaxpr", body)
            if len(body.invars) == len(eqn.invars):
                for i, v in enumerate(eqn.invars):
                    if v is var:
                        names.extend(
                            _consumers_of(body.invars[i], body, depth + 1))
                followed = True
        if not followed:
            names.append(eqn.primitive.name)
    return names


def s8_convert_records(closed_jaxpr) -> list[ConvertRecord]:
    """All s8→float converts in a (closed) jaxpr, recursing through scan /
    pjit / while bodies. Converts inside ``pallas_call`` kernels are tagged
    ``in_pallas`` — a blocked in-VMEM dequant is the kernel working as
    designed, not a graph-level materialization."""
    records: list[ConvertRecord] = []

    def walk(jaxpr, in_pallas: bool):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "convert_element_type":
                iv, ov = eqn.invars[0], eqn.outvars[0]
                src = getattr(getattr(iv, "aval", None), "dtype", None)
                dst = getattr(getattr(ov, "aval", None), "dtype", None)
                if (src is not None and str(src) == "int8"
                        and dst is not None and "float" in str(dst)
                        or str(dst) in ("bfloat16", "float16")
                        and str(src) == "int8"):
                    shape = tuple(ov.aval.shape)
                    elems = 1
                    for d in shape:
                        elems *= int(d)
                    records.append(ConvertRecord(
                        shape=shape, dtype=str(dst), elems=elems,
                        consumers=tuple(sorted(set(_consumers_of(ov, jaxpr)))),
                        in_pallas=in_pallas,
                    ))
            sub_pallas = in_pallas or eqn.primitive.name == "pallas_call"
            for sub in _sub_jaxprs(eqn):
                walk(getattr(sub, "jaxpr", sub), sub_pallas)

    walk(closed_jaxpr.jaxpr, False)
    return records


def convert_ledger(closed_jaxpr) -> dict:
    """Per-jit dtype ledger: totals + the materialized (non-fused) converts."""
    recs = s8_convert_records(closed_jaxpr)
    from .hlo_model import DTYPE_BYTES

    def nbytes(r):
        width = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}
        return r.elems * width.get(r.dtype, 4)

    return {
        "count": len(recs),
        "bytes": sum(nbytes(r) for r in recs),
        "materialized": [
            {"shape": list(r.shape), "dtype": r.dtype, "elems": r.elems,
             "consumers": list(r.consumers)}
            for r in recs if not r.fused and not r.in_pallas
        ],
    }


# ============================================================ HLO analysis
def collective_table(module: HloModule) -> dict[str, list]:
    """{base op: [count, total result bytes]} over every computation."""
    table: dict[str, list] = {}
    for instr in module.collectives():
        row = table.setdefault(instr.base_opcode, [0, 0])
        row[0] += 1
        row[1] += instr.result_bytes()
    return table


def pool_collective_hits(module: HloModule, artifact) -> list[dict]:
    """Collectives whose result is a whole cache-pool leaf (global or
    per-device shape, rank >= 2) — the pooled-gather pattern GSPMD inserts
    for ``take``/``.at[].set`` on a sharded pool.

    The paged pool's ``page_table`` is carved out of the matching: it is
    replicated, read-only inside every dispatch, and tiny (4 B per table
    entry), and its ``[num_slots, pages_per_slot]`` shape collides with
    TP reduction lattices like argmax's ``[B, model_shards]`` partials —
    matching it would flag every sharded argmax as whole-pool movement.
    Payload and kpos/pos leaves (the bytes that matter) stay matched."""
    targets = {
        (dt, dims)
        for dt, dims in (artifact.cache_leaves_global
                         + artifact.cache_leaves_local)
        if len(dims) >= 2
    } - set(artifact.page_table_shapes)
    hits = []
    for instr in module.collectives():
        for dt, dims in instr.result_shapes():
            if (dt, tuple(dims)) in targets:
                hits.append({
                    "op": instr.base_opcode, "instr": instr.name,
                    "type": f"{dt}[{','.join(map(str, dims))}]",
                    "bytes": instr.result_bytes(),
                })
                break
    return hits


def donation_info(module: HloModule, artifact) -> dict:
    """Compare the module's ``input_output_alias`` against the expected
    per-device cache-pool leaves."""
    expected = sorted(
        f"{dt}[{','.join(map(str, dims))}]"
        for dt, dims in artifact.cache_leaves_local
    )
    n_aliased = len(module.alias)
    aliased = []
    for t in module.aliased_param_types():
        try:
            dt, dims = parse_array_type(t)
            aliased.append(f"{dt}[{','.join(map(str, dims))}]")
        except ValueError:
            pass
    info = {"expected_leaves": len(expected), "aliased": n_aliased, "ok": True,
            "missing": []}
    if n_aliased < len(expected):
        info["ok"] = False
    if aliased:  # entry layout available: match leaf-for-leaf by (dtype, dims)
        remaining = sorted(aliased)
        missing = []
        for leaf in expected:
            if leaf in remaining:
                remaining.remove(leaf)
            else:
                missing.append(leaf)
        if missing:
            info["ok"] = False
            info["missing"] = missing
    return info


# ============================================================== known debt
def _debt_entries(contract: Optional[dict], rule: str, jit: str) -> list[dict]:
    if not contract:
        return []
    return [d for d in contract.get("known_debt", [])
            if d.get("rule") == rule and d.get("jit") == jit]


def _debt_covers(entries: list[dict], key: str, value) -> bool:
    return any(d.get(key) == value for d in entries)


# ============================================================== core rules
def is_cache_dequant(record: ConvertRecord, artifact) -> bool:
    """A materialized s8→float convert whose trailing dims are a whole
    cache-ring footprint ([..., S, Hkv, hd]) — the "full [B,S,H,hd]
    dequant" the paper-level invariant forbids. Weight dequants ([K,N],
    the w8a16 XLA-fallback scale-fold) never match: they are pinned by the
    ledger totals instead of erroring per instance."""
    dims = tuple(getattr(artifact, "cache_payload_dims", ()) or ())
    return (bool(dims) and len(record.shape) >= len(dims)
            and tuple(record.shape[-len(dims):]) == dims)


@register_rule("dtype-ledger")
def rule_dtype_ledger(graph, contract) -> list[Finding]:
    out: list[Finding] = []
    for name, art in graph.jits.items():
        if art.jaxpr is None:
            continue
        recs = s8_convert_records(art.jaxpr)
        for r in recs:
            if r.fused or r.in_pallas or not is_cache_dequant(r, art):
                continue
            shape = "x".join(map(str, r.shape))
            if art.kind == "decode":
                out.append(Finding(
                    "dtype-ledger", "error", name, shape,
                    f"s8 -> {r.dtype} convert materializes a full "
                    f"[{shape}] dequant (consumers: "
                    f"{', '.join(r.consumers) or 'none'}) on the decode "
                    f"path — int8 KV/weights must only be converted inside "
                    f"a contraction (scale-fold) or a Pallas tile",
                ))
            else:
                debt = _debt_entries(contract, "dtype-ledger", name)
                if _debt_covers(debt, "shape", list(r.shape)):
                    out.append(Finding(
                        "dtype-ledger", "info", name, shape,
                        "full-cache dequant pinned as known_debt "
                        "(chunked-prefill batched attention)",
                    ))
                else:
                    out.append(Finding(
                        "dtype-ledger", "error", name, shape,
                        f"s8 -> {r.dtype} convert materializes a full "
                        f"[{shape}] dequant not pinned in the contract's "
                        f"known_debt — run --update only if this "
                        f"materialization is intentional",
                    ))
        if contract:
            want = contract.get("jits", {}).get(name, {}).get("s8_converts")
            if want is not None:
                led = convert_ledger(art.jaxpr)
                for k in ("count", "bytes"):
                    if led[k] != want.get(k):
                        out.append(Finding(
                            "dtype-ledger", "error", name, k,
                            f"s8-convert ledger drift: {k} = {led[k]} but "
                            f"contract pins {want.get(k)} — the int8 path "
                            f"changed shape; rerun with --update if "
                            f"intentional",
                        ))
    return out


@register_rule("collective-budget")
def rule_collective_budget(graph, contract) -> list[Finding]:
    out: list[Finding] = []
    tp = bool(graph.mesh_shape) and graph.mesh_shape[-1] > 1
    for name, art in graph.jits.items():
        if art.module is None:
            continue
        table = collective_table(art.module)
        # pool-touching collectives: error under TP unless pinned as debt
        debt = _debt_entries(contract, "collective-budget", name)
        for hit in pool_collective_hits(art.module, art):
            if tp and not _debt_covers(debt, "type", hit["type"]):
                out.append(Finding(
                    "collective-budget", "error", name, hit["instr"],
                    f"{hit['op']} materializes a whole cache-pool leaf "
                    f"{hit['type']} ({hit['bytes']} B/device) — the pool "
                    f"must stay shard-resident under TP; pin as known_debt "
                    f"only with a ROADMAP item to remove it",
                ))
            elif tp:
                out.append(Finding(
                    "collective-budget", "info", name, hit["instr"],
                    f"pool-leaf {hit['op']} {hit['type']} covered by "
                    f"known_debt (pooled take/.at[].set gather)",
                ))
        if contract:
            want = contract.get("jits", {}).get(name, {}).get("collectives")
            if want is not None:
                for op in sorted(set(table) | set(want)):
                    got_c, got_b = table.get(op, [0, 0])
                    want_c, want_b = want.get(op, [0, 0])
                    if (got_c, got_b) != (want_c, want_b):
                        direction = ("new collective traffic"
                                     if got_b > want_b or got_c > want_c
                                     else "less traffic than pinned (a win "
                                          "— record it)")
                        out.append(Finding(
                            "collective-budget", "error", name, op,
                            f"{op}: {got_c} ops / {got_b} B vs contract "
                            f"{want_c} ops / {want_b} B — {direction}; "
                            f"run --update to re-pin",
                        ))
    return out


@register_rule("donation-audit")
def rule_donation_audit(graph, contract) -> list[Finding]:
    out: list[Finding] = []
    for name, art in graph.jits.items():
        if art.module is None or not art.cache_leaves_local:
            continue
        info = donation_info(art.module, art)
        if info["ok"]:
            continue
        missing = (", ".join(info["missing"]) if info["missing"]
                   else f"{info['expected_leaves'] - info['aliased']} leaves")
        out.append(Finding(
            "donation-audit", "error", name, "input_output_alias",
            f"cache-pool donation dropped: {info['aliased']} aliased "
            f"entry params but {info['expected_leaves']} pool leaves "
            f"(missing: {missing}) — without input_output_alias the pool "
            f"is copied every step (2x cache HBM + a memcpy per dispatch)",
        ))
    return out


@register_rule("recompilation-guard")
def rule_recompilation_guard(graph, contract) -> list[Finding]:
    out: list[Finding] = []
    extra = set(graph.dispatch_shapes) - set(graph.warmup_shapes)
    for jit, dim in sorted(extra):
        out.append(Finding(
            "recompilation-guard", "error", jit, str(dim),
            f"dispatchable shape ({jit}, {dim}) is not covered by "
            f"engine.warmup() — a live decode step would hit an XLA "
            f"compile mid-traffic; extend warmup_shapes() or quantize the "
            f"dispatch choice back onto the warmed set",
        ))
    if contract:
        want = {tuple(s) for s in contract.get("warmup_shapes", [])}
        got = {tuple(s) for s in graph.warmup_shapes}
        for jit, dim in sorted(got - want):
            out.append(Finding(
                "recompilation-guard", "error", str(jit), str(dim),
                f"new post-warmup shape ({jit}, {dim}) not in the "
                f"contract — the compiled-shape set grew; --update to "
                f"accept the new compile",
            ))
        for jit, dim in sorted(want - got):
            out.append(Finding(
                "recompilation-guard", "error", str(jit), str(dim),
                f"contract shape ({jit}, {dim}) is no longer compiled at "
                f"warmup — the warmed set shrank; --update to re-pin",
            ))
    return out


def _axis_entry(spec, dim: int):
    """Normalized axis assignment of ``spec`` at ``dim`` (None if the spec
    is shorter than the rank — trailing dims replicate)."""
    if spec is None:
        return None
    entries = tuple(spec)
    return entries[dim] if dim < len(entries) else None


@register_rule("scale-coupling")
def rule_scale_coupling(graph, contract) -> list[Finding]:
    out: list[Finding] = []
    leaves = graph.param_leaves or {}
    for q_path, s_path in graph.scale_pairs or []:
        q = leaves.get(q_path)
        s = leaves.get(s_path)
        if q is None:
            continue
        if s is None:
            out.append(Finding(
                "scale-coupling", "error", "params", q_path,
                f"int8 payload {q_path} has no scale leaf at {s_path} — "
                f"a QTensor without its scale cannot dequantize",
            ))
            continue
        q_axis = _axis_entry(q.get("spec"), len(q["shape"]) - 1)
        s_axis = _axis_entry(s.get("spec"), len(s["shape"]) - 1)
        per_tensor = not s["shape"] or s["shape"][-1] == 1
        if per_tensor:
            if s_axis is not None:
                out.append(Finding(
                    "scale-coupling", "error", "params", s_path,
                    f"per-tensor scale {s_path} is sharded on {s_axis!r} — "
                    f"a size-1 scale must replicate",
                ))
            continue
        if q_axis != s_axis:
            out.append(Finding(
                "scale-coupling", "error", "params", s_path,
                f"scale out-feature axis {s_axis!r} != payload out-feature "
                f"axis {q_axis!r} for {q_path} — a TP shard would gather "
                f"foreign scales to dequantize its own columns",
            ))
    # KV cache: scale / v_err leaves follow their payload's slot + head axes
    cache = graph.cache_spec_leaves or {}
    for pay_name, follow_name in (("k", "k_scale"), ("v", "v_scale"),
                                  ("v", "v_err")):
        pay = cache.get(f"/{pay_name}")
        fol = cache.get(f"/{follow_name}")
        if pay is None or fol is None:
            continue
        for dim, what in ((1, "slot"), (3, "head")):
            pa = _axis_entry(pay.get("spec"), dim)
            fa = _axis_entry(fol.get("spec"), dim)
            if (dim < len(fol["shape"]) and fol["shape"][dim] > 1
                    and pa != fa):
                out.append(Finding(
                    "scale-coupling", "error", "cache", f"/{follow_name}",
                    f"cache {follow_name} {what} axis {fa!r} != payload "
                    f"{pay_name} {what} axis {pa!r} — scales must live on "
                    f"their payload's shard",
                ))
    return out
