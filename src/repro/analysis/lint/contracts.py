"""Per-recipe contract snapshots: the checked-in source of truth for what
each compiled serve graph is ALLOWED to look like.

A contract (``contracts/<recipe>[.<DxM>].json``) pins, per jit:

  * the s8-convert ledger (count + bytes of every int8→float convert in the
    traced jaxpr),
  * the collective budget (count + result bytes per collective kind in the
    optimized per-device HLO),
  * the donation audit (cache-pool leaves vs ``input_output_alias``),

plus the engine fingerprint (arch + serving knobs — a contract only applies
to the geometry it was generated under), the warmup shape set, and an
explicit ``known_debt`` list. Debt entries are the deliberate violations the
linter tolerates — e.g. the PR-5 pooled ``take``/``.at[].set`` prefill
gathers under TP, and the chunked-prefill batched dequant of the int8 cache
— each carrying a ``why`` so removing the debt later (ROADMAP shard_map
gather item) forces a contract update that SHOWS the win.

``--update`` regenerates snapshots (auto-deriving the debt list from the
current graph); ``--check`` diffs and turns any drift into a blocking
failure. Legitimate ``--update`` occasions: an intentional serving-path
change, or a jax/XLA upgrade that re-shapes the compiled modules (the
jaxpr-level ledger is version-stable; the HLO collective split is not).
"""
from __future__ import annotations

import json
import os
from typing import Optional

from .rules import (
    collective_table,
    convert_ledger,
    donation_info,
    is_cache_dequant,
    pool_collective_hits,
    s8_convert_records,
)

CONTRACT_DIR = os.path.join(os.path.dirname(__file__), "contracts")

_DEBT_WHY = {
    "dtype-ledger": (
        "chunked prefill dequantizes the slot's int8 ring once per chunk "
        "(batched attention over the gathered sub-cache); fusing the "
        "scale-fold into the prefill contraction is open ROADMAP work"
    ),
    "collective-budget": (
        "GSPMD materializes the pooled take/.at[].set pair as whole-leaf "
        "collectives on the sharded prefill paths (PR-5 known-bad case); "
        "the ROADMAP shard_map-gather item removes this — deleting this "
        "entry then makes the win visible in the contract diff"
    ),
}


def contract_path(stem: str) -> str:
    return os.path.join(CONTRACT_DIR, f"{stem}.json")


def load_contract(stem: str) -> Optional[dict]:
    path = contract_path(stem)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def save_contract(stem: str, contract: dict) -> str:
    os.makedirs(CONTRACT_DIR, exist_ok=True)
    path = contract_path(stem)
    with open(path, "w") as f:
        json.dump(contract, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def snapshot(graph) -> dict:
    """Build a contract from a lint graph, auto-deriving ``known_debt``:
    every prefill-path full-cache dequant and every pool-leaf collective in
    the CURRENT graph becomes an explicit debt entry (with a ``why``), so a
    fresh ``--update`` never silently blesses *new* decode-path violations —
    those have no debt channel and stay hard errors."""
    debt: list = []
    jits: dict = {}
    for name, art in sorted(graph.jits.items()):
        entry: dict = {"kind": art.kind}
        if art.jaxpr is not None:
            entry["s8_converts"] = convert_ledger(art.jaxpr)
            if art.kind != "decode":
                for r in s8_convert_records(art.jaxpr):
                    if (not r.fused and not r.in_pallas
                            and is_cache_dequant(r, art)):
                        debt.append({
                            "rule": "dtype-ledger", "jit": name,
                            "shape": list(r.shape), "dtype": r.dtype,
                            "why": _DEBT_WHY["dtype-ledger"],
                        })
        if art.module is not None:
            entry["collectives"] = {
                op: list(row)
                for op, row in sorted(collective_table(art.module).items())
            }
            entry["donation"] = donation_info(art.module, art)
            for hit in pool_collective_hits(art.module, art):
                debt.append({
                    "rule": "collective-budget", "jit": name,
                    "op": hit["op"], "type": hit["type"],
                    "bytes": hit["bytes"],
                    "why": _DEBT_WHY["collective-budget"],
                })
        jits[name] = entry
    return {
        "recipe": graph.recipe,
        "mesh": ("x".join(map(str, graph.mesh_shape))
                 if graph.mesh_shape else None),
        "engine": dict(graph.engine),
        "warmup_shapes": sorted([j, int(d)] for j, d in graph.warmup_shapes),
        "jits": jits,
        "known_debt": debt,
    }


def debt_growth(old: Optional[dict], new: dict) -> list[dict]:
    """``known_debt`` entries present in ``new`` but not in ``old`` — the
    CI lint gate turns each into a blocking error (debt may shrink or hold,
    never grow silently). A missing ``old`` contract grows nothing here;
    that case is already the louder "no contract" error."""
    if old is None:
        return []
    o = {json.dumps(d, sort_keys=True) for d in old.get("known_debt", [])}
    return [json.loads(d)
            for d in sorted({json.dumps(d, sort_keys=True)
                             for d in new.get("known_debt", [])} - o)]


def diff_contracts(old: Optional[dict], new: dict) -> list[str]:
    """Human-readable drift lines between two contracts (for --update
    output and the CI step summary). Empty list = identical."""
    if old is None:
        return [f"new contract ({len(new.get('jits', {}))} jits, "
                f"{len(new.get('known_debt', []))} known_debt entries)"]
    lines: list[str] = []
    for key in ("recipe", "mesh", "engine"):
        if old.get(key) != new.get(key):
            lines.append(f"{key}: {old.get(key)} -> {new.get(key)}")
    if old.get("warmup_shapes") != new.get("warmup_shapes"):
        o = {tuple(s) for s in old.get("warmup_shapes", [])}
        n = {tuple(s) for s in new.get("warmup_shapes", [])}
        for s in sorted(n - o):
            lines.append(f"warmup shape added: {s}")
        for s in sorted(o - n):
            lines.append(f"warmup shape removed: {s}")
    o_jits, n_jits = old.get("jits", {}), new.get("jits", {})
    for name in sorted(set(o_jits) | set(n_jits)):
        if name not in o_jits:
            lines.append(f"{name}: new jit")
            continue
        if name not in n_jits:
            lines.append(f"{name}: jit removed")
            continue
        o, n = o_jits[name], n_jits[name]
        if o.get("s8_converts") != n.get("s8_converts"):
            ol, nl = o.get("s8_converts") or {}, n.get("s8_converts") or {}
            lines.append(
                f"{name}: s8-convert ledger {ol.get('count')} ops / "
                f"{ol.get('bytes')} B -> {nl.get('count')} ops / "
                f"{nl.get('bytes')} B")
        oc, nc = o.get("collectives") or {}, n.get("collectives") or {}
        for op in sorted(set(oc) | set(nc)):
            if oc.get(op) != nc.get(op):
                lines.append(
                    f"{name}: {op} {oc.get(op, [0, 0])} -> "
                    f"{nc.get(op, [0, 0])} [count, bytes]")
        if (o.get("donation") or {}).get("ok") != \
                (n.get("donation") or {}).get("ok"):
            lines.append(f"{name}: donation ok "
                         f"{(o.get('donation') or {}).get('ok')} -> "
                         f"{(n.get('donation') or {}).get('ok')}")
    o_debt = {json.dumps(d, sort_keys=True)
              for d in old.get("known_debt", [])}
    n_debt = {json.dumps(d, sort_keys=True)
              for d in new.get("known_debt", [])}
    for d in sorted(n_debt - o_debt):
        e = json.loads(d)
        lines.append(f"known_debt added: {e.get('rule')} @ {e.get('jit')}")
    for d in sorted(o_debt - n_debt):
        e = json.loads(d)
        lines.append(f"known_debt REMOVED (a win): {e.get('rule')} @ "
                     f"{e.get('jit')}")
    return lines
