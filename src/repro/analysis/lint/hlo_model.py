"""Instruction-level model of optimized HLO text.

``analysis/hlo_diag.py`` grew out of a regex-per-line Counter; that was fine
for ranking collectives but is too lossy to *enforce* anything: it drops
instructions whose result is a tuple containing layout annotations (the
``{0:T(256)}`` tiling syntax nests parentheses, which breaks a ``[^)]*``
scan), it cannot see the module-level ``input_output_alias`` map donation
produces, and it has no notion of operands. This module parses the text XLA
emits (``compiled.as_text()``) into a small object model the lint rules (and
the fixed ``hlo_diag``) operate on:

  * :class:`HloInstr` — name, opcode, flattened result types, operand names,
    enclosing computation, raw line,
  * :class:`HloModule` — the computations, the entry computation, the
    ``input_output_alias`` map, and the entry parameter layouts.

Pure text processing: no jax import, so the parser is usable from fixtures
and from ``hlo_diag`` without pulling in the accelerator stack.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Optional

# HLO dtype → bytes. Mirrors roofline._DTYPE_BYTES but owned here so the
# parser stays import-light; unknown dtypes WARN and count 0 (never a silent
# skip — a new dtype showing up in a budget is itself a signal).
DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_WARNED_DTYPES: set = set()


def _balanced(s: str, i: int, open_ch: str, close_ch: str) -> int:
    """Index of the close bracket matching ``s[i] == open_ch``."""
    depth = 0
    for j in range(i, len(s)):
        if s[j] == open_ch:
            depth += 1
        elif s[j] == close_ch:
            depth -= 1
            if depth == 0:
                return j
    raise ValueError(f"unbalanced {open_ch!r} at {i} in {s[:120]!r}")


def _scan_type(s: str, i: int) -> tuple[str, int]:
    """Scan one HLO type starting at ``s[i]``; returns (type_str, next_i).

    Handles tuple types ``(f32[4]{0}, s32[])``, array layouts with nested
    parens (``f32[8,128]{1,0:T(8,128)}``), and scalar types (``f32[]``).
    """
    if s[i] == "(":
        j = _balanced(s, i, "(", ")")
        return s[i : j + 1], j + 1
    m = re.match(r"[a-z][a-z0-9]*", s[i:])
    if not m:
        raise ValueError(f"no type at {i} in {s[:120]!r}")
    j = i + m.end()
    if j < len(s) and s[j] == "[":
        j = _balanced(s, j, "[", "]") + 1
    if j < len(s) and s[j] == "{":
        j = _balanced(s, j, "{", "}") + 1
    return s[i:j], j


def _split_top(s: str, sep: str = ",") -> list[str]:
    """Split on ``sep`` at bracket depth 0 ((), [], {} all tracked)."""
    out, depth, start = [], 0, 0
    for i, c in enumerate(s):
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        elif c == sep and depth == 0:
            out.append(s[start:i])
            start = i + 1
    out.append(s[start:])
    return [p.strip() for p in out if p.strip()]


def flatten_type(type_str: str) -> list[str]:
    """Tuple type → leaf array types (a non-tuple flattens to itself)."""
    t = type_str.strip()
    if t.startswith("("):
        leaves: list[str] = []
        for part in _split_top(t[1:-1]):
            leaves.extend(flatten_type(part))
        return leaves
    return [t]


_ARRAY_RE = re.compile(r"^([a-z][a-z0-9]*)(?:\[([0-9,]*)\])?")


def parse_array_type(type_str: str) -> tuple[str, tuple[int, ...]]:
    """``'f32[2,4]{1,0}'`` → ``('f32', (2, 4))``; scalars give ``()``."""
    m = _ARRAY_RE.match(type_str.strip())
    if not m:
        raise ValueError(f"not an array type: {type_str!r}")
    dims = m.group(2)
    return m.group(1), tuple(int(d) for d in dims.split(",")) if dims else ()


def type_bytes(type_str: str, warn_unknown: bool = True) -> int:
    """Total bytes of a (possibly tuple) HLO type.

    Unknown dtypes contribute 0 **with a warning** — the old silent-skip
    behavior hid brand-new dtypes from every byte budget.
    """
    total = 0
    for leaf in flatten_type(type_str):
        try:
            dt, dims = parse_array_type(leaf)
        except ValueError:
            continue
        if dt not in DTYPE_BYTES:
            if warn_unknown and dt not in _WARNED_DTYPES:
                _WARNED_DTYPES.add(dt)
                warnings.warn(
                    f"unknown HLO dtype {dt!r} in {leaf!r}: counting 0 bytes "
                    f"— add it to repro.analysis.lint.hlo_model.DTYPE_BYTES",
                    stacklevel=2,
                )
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class HloInstr:
    """One instruction: ``%name = <type> opcode(operands), attrs...``."""

    name: str
    opcode: str                 # full opcode, e.g. "all-reduce-start"
    result_type: str            # raw type string (may be a tuple)
    result_leaves: list[str]    # flattened leaf array types
    operands: list[str]         # operand instruction names (no leading %)
    computation: str
    is_root: bool
    raw: str

    @property
    def base_opcode(self) -> str:
        """Opcode with the async ``-start``/``-done`` suffix stripped."""
        for suf in ("-start", "-done"):
            if self.opcode.endswith(suf):
                return self.opcode[: -len(suf)]
        return self.opcode

    @property
    def async_phase(self) -> Optional[str]:
        for suf in ("-start", "-done"):
            if self.opcode.endswith(suf):
                return suf
        return None

    def result_bytes(self) -> int:
        """Bytes of the materialized result. Async ``-start`` ops carry an
        (operands..., results...) tuple — count half so a start/done pair
        totals one payload, same as the synchronous form."""
        b = type_bytes(self.result_type)
        if self.async_phase == "-start" and self.result_type.lstrip().startswith("("):
            return b // 2
        return b

    def result_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        out = []
        for leaf in self.result_leaves:
            try:
                out.append(parse_array_type(leaf))
            except ValueError:
                pass
        return out


@dataclasses.dataclass
class HloModule:
    """Parsed module: computations, entry, alias map, entry param layouts."""

    name: str
    computations: dict[str, list[HloInstr]]
    entry: str
    # input_output_alias: {output tuple index: (param number, param tuple idx)}
    alias: dict[tuple[int, ...], tuple[int, tuple[int, ...]]]
    entry_param_types: list[str]    # from entry_computation_layout
    entry_result_types: list[str]

    def instructions(self, computation: Optional[str] = None):
        if computation is not None:
            yield from self.computations.get(computation, [])
            return
        for instrs in self.computations.values():
            yield from instrs

    def collectives(self, computation: Optional[str] = None) -> list[HloInstr]:
        """Every collective instruction (sync or ``-start``; ``-done`` halves
        are bookkeeping for a ``-start`` already counted and are skipped)."""
        return [
            i
            for i in self.instructions(computation)
            if i.base_opcode in COLLECTIVE_OPS and i.async_phase != "-done"
        ]

    def aliased_param_types(self) -> list[str]:
        """Entry parameter types (one per aliased param) named by the
        ``input_output_alias`` map, when the entry layout is available."""
        out = []
        for _, (param, _) in sorted(self.alias.items()):
            if param < len(self.entry_param_types):
                out.append(self.entry_param_types[param])
        return out


_ALIAS_ENTRY_RE = re.compile(
    r"\{\s*([\d,\s]*)\}\s*:\s*\(\s*(\d+)\s*,\s*\{([\d,\s]*)\}\s*,\s*"
    r"(?:may|must)-alias\s*\)"
)
_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{$"
)
_INSTR_RE = re.compile(r"^(ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_IDX_COMMENT_RE = re.compile(r"/\*[^*]*\*/")


def _parse_idx(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.replace(" ", "").split(",") if x != "")


def _parse_module_header(line: str, mod: "HloModule") -> None:
    m = re.match(r"HloModule\s+([\w.\-]+)", line)
    if m:
        mod.name = m.group(1)
    i = line.find("input_output_alias=")
    if i >= 0:
        j = line.index("{", i)
        block = line[j : _balanced(line, j, "{", "}") + 1]
        for out_idx, param, param_idx in (
            (g.group(1), g.group(2), g.group(3))
            for g in _ALIAS_ENTRY_RE.finditer(block)
        ):
            mod.alias[_parse_idx(out_idx)] = (int(param), _parse_idx(param_idx))
    i = line.find("entry_computation_layout=")
    if i >= 0:
        j = line.index("{", i)
        block = line[j + 1 : _balanced(line, j, "{", "}")]
        block = _IDX_COMMENT_RE.sub("", block)
        arrow = block.find("->")
        params = block[:arrow].strip() if arrow >= 0 else block.strip()
        results = block[arrow + 2 :].strip() if arrow >= 0 else ""
        if params.startswith("("):
            mod.entry_param_types = _split_top(params[1:-1])
        elif params:
            mod.entry_param_types = [params]
        if results.startswith("("):
            mod.entry_result_types = _split_top(results[1:-1])
        elif results:
            mod.entry_result_types = [results]


def _parse_instr(line: str, computation: str) -> Optional[HloInstr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    is_root, name = bool(m.group(1)), m.group(2)
    rest = line[m.end():]
    try:
        type_str, i = _scan_type(rest, 0)
    except ValueError:
        return None
    while i < len(rest) and rest[i] == " ":
        i += 1
    op_m = re.match(r"[a-zA-Z][\w\-]*", rest[i:])
    if not op_m:
        return None
    opcode = op_m.group(0)
    i += op_m.end()
    operands: list[str] = []
    if i < len(rest) and rest[i] == "(":
        j = _balanced(rest, i, "(", ")")
        operands = re.findall(r"%([\w.\-]+)", rest[i : j + 1])
    return HloInstr(
        name=name,
        opcode=opcode,
        result_type=type_str,
        result_leaves=flatten_type(type_str),
        operands=operands,
        computation=computation,
        is_root=is_root,
        raw=line,
    )


def parse_hlo_module(hlo_text: str) -> HloModule:
    """Parse ``compiled.as_text()`` (or a hand-written fixture) into an
    :class:`HloModule`. Lines that are not module headers, computation
    headers, or instructions are ignored — the parser is intentionally
    tolerant so lint fixtures can be minimal."""
    mod = HloModule(
        name="", computations={}, entry="", alias={},
        entry_param_types=[], entry_result_types=[],
    )
    comp: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//") or line.startswith("#"):
            continue
        if line.startswith("HloModule"):
            _parse_module_header(line, mod)
            continue
        hm = _COMP_HEADER_RE.match(line)
        if hm and " = " not in line:
            comp = hm.group(2)
            mod.computations.setdefault(comp, [])
            if hm.group(1):
                mod.entry = comp
            continue
        if line == "}":
            comp = None
            continue
        if comp is None:
            # tolerate bare instruction fixtures with no ENTRY wrapper
            if " = " in line:
                comp = mod.entry = mod.entry or "entry"
                mod.computations.setdefault(comp, [])
            else:
                continue
        instr = _parse_instr(line, comp)
        if instr is not None:
            mod.computations[comp].append(instr)
    if not mod.entry and mod.computations:
        mod.entry = next(iter(mod.computations))
    return mod
