"""``python -m repro.analysis.lint`` — see cli.py for the flag reference.

The TP recipes lint under the (2, 4) CI reference mesh, which needs 8
devices; on a CPU host that means forcing virtual devices BEFORE jax
initializes its backend, so this shim sets XLA_FLAGS first (and defers to
any value the caller already exported — the CI job sets it explicitly).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from .cli import main  # noqa: E402  (env must be set before jax imports)

sys.exit(main())
