"""QuantLint: static contract linting for the compiled serving graphs.

Five PRs of serving-stack invariants (int8-everywhere decode, scale/payload
co-sharding, cache donation, bounded TP collectives, warmup shape closure)
are enforced *dynamically* by parity tests — which can silently stop
exercising the property they pin: a dtype upcast or a GSPMD-inserted
all-gather makes the path slower but still bit-correct, so tier-1 stays
green. This package checks the structural properties directly, the way the
paper reasons about quantization (§1.1, §4: quality is decided by *which*
ops run in int8 and *where* scales fold, not by any particular run):

  * ``hlo_model``  — a real instruction model over optimized per-device HLO
    (opcode, flattened result types, operands, input_output_alias), not a
    regex-per-line Counter,
  * ``extract``    — traces/lowers the four engine jits (prefill, decode,
    fused horizon, batched prefill) and the standalone kernels for a
    recipe + mesh WITHOUT running them,
  * ``rules``      — the rule registry (``@register_rule``) with the five
    core rules: dtype-ledger, collective-budget, donation-audit,
    recompilation-guard, scale-coupling,
  * ``contracts``  — per-recipe contract snapshots checked into
    ``contracts/<recipe>[.mesh].json``; ``--update`` regenerates them,
    ``--check`` diffs and fails CI on drift,
  * ``cli``        — ``python -m repro.analysis.lint --check|--update``.

Import note: ``hlo_model`` and ``rules`` are dependency-light (no jax at
import time for the parser); ``extract`` pulls in the serving stack and is
imported lazily.
"""
from __future__ import annotations

from .hlo_model import HloInstr, HloModule, parse_hlo_module, type_bytes
from .rules import (
    Finding,
    list_rules,
    register_rule,
    run_rules,
)

__all__ = [
    "HloInstr",
    "HloModule",
    "parse_hlo_module",
    "type_bytes",
    "Finding",
    "register_rule",
    "run_rules",
    "list_rules",
    "build_graph",
    "graph_from_engine",
    "lint_engine",
]


def __getattr__(name):  # lazy: extract imports jax + the serving stack
    if name in ("build_graph", "graph_from_engine", "LintGraph", "JitArtifact"):
        from . import extract

        return getattr(extract, name)
    if name == "lint_engine":
        from .cli import lint_engine

        return lint_engine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
