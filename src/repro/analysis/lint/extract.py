"""Graph extraction: trace/lower the serve paths WITHOUT running them.

For a recipe + mesh shape this builds a ``LintGraph``:

  * each of the four engine jits (prefill, decode, fused horizon, batched
    prefill) as a ``JitArtifact`` — its traced jaxpr (``jax.make_jaxpr`` on
    the unjitted impl) and its optimized per-device HLO (``.lower()`` +
    ``.compile()``, parsed by ``hlo_model`` — compilation never executes),
  * the standalone serving kernels (jaxpr-only artifacts: no cache pool,
    no donation contract — the dtype ledger still covers them),
  * the cache-pool leaf shapes (global and per-device) the donation and
    collective rules match against,
  * the sharding-spec pytrees (params + cache) and QTensor payload/scale
    pairs the scale-coupling rule checks,
  * the engine's warmup/dispatch shape sets for the recompilation guard.

Everything here is static: no engine step runs, no cache buffer is donated
(donation only invalidates on *execution*), and the whole extraction for a
smoke-config recipe takes a few seconds even on a TP mesh of virtual CPUs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from .hlo_model import HloModule, parse_hlo_module

# numpy dtype name → HLO shorthand (the reverse of hlo_model.DTYPE_BYTES keys)
_NP_TO_HLO = {
    "bool": "pred", "int8": "s8", "int16": "s16", "int32": "s32",
    "int64": "s64", "uint8": "u8", "uint16": "u16", "uint32": "u32",
    "uint64": "u64", "float16": "f16", "bfloat16": "bf16",
    "float32": "f32", "float64": "f64",
}


def hlo_dtype(dtype) -> str:
    name = np.dtype(dtype).name if not hasattr(dtype, "name") else dtype.name
    try:
        return _NP_TO_HLO[name]
    except KeyError:
        raise ValueError(f"no HLO shorthand for dtype {name!r}") from None


def _spec_entries(spec, rank: int) -> list:
    """PartitionSpec → a JSON-able full-rank list of axis entries (None /
    "axis" / ["axis", ...] for multi-axis dims); trailing dims replicate."""
    entries: list = [None] * rank
    if spec is None:
        return entries
    for i, e in enumerate(tuple(spec)[:rank]):
        entries[i] = list(e) if isinstance(e, tuple) else e
    return entries


@dataclasses.dataclass
class JitArtifact:
    """One traced+lowered serve path (or a jaxpr-only standalone kernel)."""

    name: str
    kind: str                    # "prefill" | "decode" | "kernel"
    jaxpr: Any = None            # ClosedJaxpr (None when not traced)
    module: Optional[HloModule] = None
    hlo_text: Optional[str] = None
    # (hlo_dtype, dims) of every cache-pool leaf — global and per-device
    cache_leaves_global: list = dataclasses.field(default_factory=list)
    cache_leaves_local: list = dataclasses.field(default_factory=list)
    # "full dequant" element threshold: one slot's ring of one layer's KV
    slot_cache_elems: int = 1 << 62
    # trailing dims of a cache payload leaf ([S, Hkv, hd]) — a materialized
    # s8 convert matching these is a whole-ring dequant (dtype-ledger)
    cache_payload_dims: tuple = ()
    # (hlo_dtype, dims) of the paged pool's page-table leaf (global + local;
    # empty for contiguous pools) — excluded from pool-collective matching
    page_table_shapes: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class LintGraph:
    recipe: str
    mesh_shape: Optional[tuple]
    engine: dict                                  # fingerprint (arch, knobs)
    jits: dict = dataclasses.field(default_factory=dict)
    warmup_shapes: set = dataclasses.field(default_factory=set)
    dispatch_shapes: set = dataclasses.field(default_factory=set)
    # {path: {"dtype", "shape", "spec"}} for params and cache-pool leaves
    param_leaves: dict = dataclasses.field(default_factory=dict)
    cache_spec_leaves: dict = dataclasses.field(default_factory=dict)
    scale_pairs: list = dataclasses.field(default_factory=list)


def _leaf_table(tree, spec_tree, mesh) -> dict:
    """{path: {"dtype", "shape", "spec"}} over a (possibly QTensor-bearing)
    pytree, with normalized full-rank spec entries when a mesh is given."""
    from ...sharding.partition import _walk, spec_paths

    leaves = dict(_walk(tree))
    specs = dict(spec_paths(spec_tree)) if spec_tree is not None else {}
    out = {}
    for path, leaf in leaves.items():
        shape = tuple(int(d) for d in leaf.shape)
        spec = specs.get(path)
        out[path] = {
            "dtype": hlo_dtype(leaf.dtype),
            "shape": list(shape),
            "spec": (_spec_entries(spec, len(shape))
                     if mesh is not None and spec is not None else None),
        }
    return out


def _cache_leaf_shapes(pool) -> tuple[list, list]:
    """(global, per-device) (hlo_dtype, dims) pairs for the pool leaves."""
    glob, loc = [], []
    for name in sorted(pool.cache):
        leaf = pool.cache[name]
        dt = hlo_dtype(leaf.dtype)
        dims = tuple(int(d) for d in leaf.shape)
        glob.append((dt, dims))
        sh = (pool.shardings or {}).get(name) if pool.shardings else None
        loc.append((dt, tuple(sh.shard_shape(dims)) if sh is not None
                    else dims))
    return glob, loc


def graph_from_engine(engine, recipe: str = "",
                      mesh_shape: Optional[tuple] = None,
                      include_kernels: bool = True,
                      compile_hlo: bool = True) -> LintGraph:
    """Extract a ``LintGraph`` from a live ``ServingEngine`` (nothing runs:
    trace + lower + compile only). ``compile_hlo=False`` skips the XLA
    compile (jaxpr-only rules still work — used by the fast --lint path)."""
    cfg = engine.cfg
    pool = engine.pool
    glob, loc = _cache_leaf_shapes(pool)
    table_shapes = []
    if pool.paged:
        pt = pool.cache["page_table"]
        dims = tuple(int(d) for d in pt.shape)
        sh = (pool.shardings or {}).get("page_table") if pool.shardings \
            else None
        table_shapes = [(hlo_dtype(pt.dtype), dims),
                        (hlo_dtype(pt.dtype),
                         tuple(sh.shard_shape(dims)) if sh is not None
                         else dims)]
    k_shape = pool.cache["k"].shape
    if pool.paged:
        # paged leaves are [L, NP, pg, Hkv, hd], but the jits attend through
        # the gathered DENSE view [L, B, S, Hkv, hd] — the dtype ledger's
        # "whole-ring dequant" threshold and payload-dims matcher must see
        # the view dims or a paged prefill dequant would sail under them
        payload_dims = (engine.max_len, int(k_shape[3]), int(k_shape[4]))
    else:
        payload_dims = tuple(int(d) for d in k_shape[2:])  # [S, Hkv, hd]
    slot_elems = int(np.prod(payload_dims))      # one slot, one layer
    if mesh_shape is None and engine.mesh is not None:
        mesh_shape = tuple(
            int(engine.mesh.shape[a]) for a in engine.mesh.axis_names)

    graph = LintGraph(
        recipe=recipe,
        mesh_shape=tuple(mesh_shape) if mesh_shape else None,
        engine={
            "arch": cfg.name,
            "num_slots": engine.num_slots,
            "max_len": engine.max_len,
            "prefill_chunk": engine.prefill_chunk,
            "decode_horizon": engine.decode_horizon,
            "kv_bits": engine.kv_bits,
            "fast": engine.fast,
            "page_size": engine.page_size,
        },
        warmup_shapes=set(engine.warmup_shapes()),
        dispatch_shapes=set(engine.dispatch_shapes()),
        scale_pairs=[],
    )

    for name, (jit_fn, impl_fn, args, static_kw) in \
            engine.serve_jit_specs().items():
        jaxpr = jax.make_jaxpr(
            lambda *a, _f=impl_fn, _kw=static_kw: _f(*a, **_kw))(*args)
        hlo_text = module = None
        if compile_hlo:
            hlo_text = jit_fn.lower(*args, **static_kw).compile().as_text()
            module = parse_hlo_module(hlo_text)
        graph.jits[name] = JitArtifact(
            name=name,
            kind="decode" if name.startswith("decode") else "prefill",
            jaxpr=jaxpr, module=module, hlo_text=hlo_text,
            cache_leaves_global=glob, cache_leaves_local=loc,
            slot_cache_elems=slot_elems,
            cache_payload_dims=payload_dims,
            page_table_shapes=table_shapes,
        )

    if include_kernels:
        from ...kernels import serving_kernel_specs

        kspecs = serving_kernel_specs(
            head_dim=cfg.head_dim, n_kv_heads=cfg.n_kv_heads,
            n_q_heads=cfg.n_heads, seq=engine.max_len,
            batch=engine.num_slots, d_in=cfg.d_model, d_out=cfg.d_ff,
        )
        for name, (fn, args, kw) in kspecs.items():
            jaxpr = jax.make_jaxpr(
                lambda *a, _f=fn, _kw=kw: _f(*a, **_kw))(*args)
            graph.jits[name] = JitArtifact(
                name=name, kind="kernel", jaxpr=jaxpr,
                slot_cache_elems=slot_elems,
                cache_payload_dims=payload_dims,
            )

    # sharding-spec tables for scale-coupling
    mesh = engine.mesh
    p_shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), engine.params)
    p_specs = None
    if mesh is not None:
        from ...sharding import params_pspecs

        heads = {"n_q": cfg.n_heads, "n_kv": cfg.n_kv_heads}
        p_specs = params_pspecs(p_shapes, mesh, heads, mode="serve")
    graph.param_leaves = _leaf_table(p_shapes, p_specs, mesh)

    c_shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), pool.cache)
    c_specs = None
    if mesh is not None:
        from ...sharding import serve_cache_pspecs

        c_specs = serve_cache_pspecs(c_shapes, mesh)
    graph.cache_spec_leaves = _leaf_table(c_shapes, c_specs, mesh)

    from ...sharding import payload_scale_pairs

    graph.scale_pairs = payload_scale_pairs(engine.params)
    return graph


def build_graph(recipe: str, mesh_shape: Optional[tuple] = None,
                arch: str = "qwen2-0.5b", *, num_slots: int = 4,
                max_len: int = 32, prefill_chunk: int = 8,
                decode_horizon: int = 8, page_size: Optional[int] = None,
                include_kernels: bool = True) -> LintGraph:
    """Quantize a smoke model through ``recipe`` and extract its lint graph
    under ``mesh_shape`` (None = single device). ``page_size`` lints the
    paged-pool engine (the ``+paged`` recipe-flag geometry). The standard
    entry point for ``python -m repro.analysis.lint`` and the CI lint-graph
    job."""
    from ...configs import get_config
    from ...models import build_model
    from ...pipeline import quantize

    mesh = None
    if mesh_shape:
        need = int(np.prod(mesh_shape))
        if need > jax.device_count():
            raise RuntimeError(
                f"recipe {recipe!r} lints under mesh "
                f"{'x'.join(map(str, mesh_shape))} which needs {need} "
                f"devices but jax sees {jax.device_count()}; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
                f"BEFORE jax initializes"
            )
        from ...launch.mesh import make_production_mesh

        mesh = make_production_mesh(shape=tuple(mesh_shape))

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    qm = quantize(model, recipe=recipe)
    from ...serving import ServingEngine

    engine = ServingEngine(
        qm.model, qm.params, qm.cfg, num_slots=num_slots, max_len=max_len,
        prefill_chunk=prefill_chunk, decode_horizon=decode_horizon,
        mesh=mesh, page_size=page_size,
    )
    return graph_from_engine(engine, recipe=recipe, mesh_shape=mesh_shape,
                             include_kernels=include_kernels)
