"""QuantLint entry points.

    # regenerate the checked-in contracts (after an INTENTIONAL graph change
    # or a jax upgrade; review the printed diff before committing):
    python -m repro.analysis.lint --update

    # CI / local gate: fail on any contract drift or rule violation
    python -m repro.analysis.lint --check

    # one recipe, with a JSON report + markdown summary (the CI job wires
    # --summary "$GITHUB_STEP_SUMMARY"):
    python -m repro.analysis.lint --check --recipes serve-w8a16-tp \
        --report lint_report.json --summary summary.md

TP recipes lint under the CI reference mesh (2x4 = 8 devices);
``python -m repro.analysis.lint`` forces 8 virtual CPU devices via
XLA_FLAGS automatically (see __main__.py) unless the variable is already
set.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Optional

DEFAULT_RECIPES = (
    "serve-w8a16",
    "serve-w8a8-kv8",
    "serve-w8a16-tp",
    "serve-w8a8-kv8-tp",
    # +paged: same recipes through the page-table KV pool (page gathers must
    # stay collective-free and dequant-free — the paged acceptance gate)
    "serve-w8a16+paged",
    "serve-w8a8-kv8+paged",
    "serve-w8a16-tp+paged",
    "serve-w8a8-kv8-tp+paged",
)

# the paged lint geometry: ring 32 / page 8 -> 4 pages per slot table
LINT_PAGE_SIZE = 8


def _severity_counts(findings) -> dict:
    out = {"error": 0, "warn": 0, "info": 0}
    for f in findings:
        out[f.severity] += 1
    return out


def format_findings(findings, *, show_info: bool = True) -> str:
    lines = []
    for f in findings:
        if f.severity == "info" and not show_info:
            continue
        lines.append("  " + f.format())
    return "\n".join(lines)


def lint_graph(graph, contract: Optional[dict]):
    """Run the full rule set over one extracted graph; contract-level
    preconditions (missing contract, stale engine fingerprint) surface as
    findings rather than exceptions so the report always renders."""
    from .rules import Finding, run_rules

    pre: list = []
    if contract is not None and contract.get("engine") != graph.engine:
        pre.append(Finding(
            "contract", "error", "", "engine",
            f"engine fingerprint drifted from the contract: contract "
            f"{contract.get('engine')} vs graph {graph.engine} — the "
            f"contract no longer describes this serving geometry; "
            f"regenerate with --update",
        ))
    return pre + run_rules(graph, contract)


def lint_recipe(recipe: str, *, update: bool = False,
                arch: str = "qwen2-0.5b") -> dict:
    """Extract + lint one recipe against its checked-in contract (or
    regenerate the contract when ``update``). Returns a JSON-able result:
    {stem, findings, counts, diff, ok}."""
    from ...pipeline.recipes import (
        contract_stem,
        lint_mesh_shape,
        split_recipe_flags,
    )
    from . import contracts
    from .extract import build_graph
    from .rules import Finding

    base, flags = split_recipe_flags(recipe)
    mesh_shape = lint_mesh_shape(base)
    stem = contract_stem(recipe, mesh_shape)
    graph = build_graph(
        base, mesh_shape, arch=arch,
        page_size=LINT_PAGE_SIZE if "paged" in flags else None,
    )
    graph.recipe = recipe        # contracts record the flagged name
    old = contracts.load_contract(stem)
    diff: list = []
    if update:
        fresh = contracts.snapshot(graph)
        diff = contracts.diff_contracts(old, fresh)
        path = contracts.save_contract(stem, fresh)
        findings = lint_graph(graph, fresh)
        action = f"wrote {path}"
    else:
        findings = lint_graph(graph, old)
        if old is None:
            findings.insert(0, Finding(
                "contract", "error", "", stem,
                f"no contract at {contracts.contract_path(stem)} — generate "
                f"one with: python -m repro.analysis.lint --update "
                f"--recipes {recipe}",
            ))
        else:
            fresh = contracts.snapshot(graph)
            diff = contracts.diff_contracts(old, fresh)
            # the debt ratchet: known_debt may shrink or hold, never grow —
            # a new entry means a new full-pool collective or cache dequant
            # crept into the graph, which is exactly what the paged/sharded
            # refactors are gated on
            for e in contracts.debt_growth(old, fresh):
                findings.append(Finding(
                    "known-debt-growth", "error", e.get("jit", ""),
                    e.get("rule", ""),
                    f"known_debt grew: {json.dumps(e, sort_keys=True)} — "
                    f"fix the graph, or (only if the regression is "
                    f"deliberate) --update and justify the new entry in "
                    f"the PR",
                ))
        action = "checked"
    counts = _severity_counts(findings)
    return {
        "recipe": recipe,
        "stem": stem,
        "mesh": "x".join(map(str, mesh_shape)) if mesh_shape else None,
        "action": action,
        "findings": [dataclasses.asdict(f) for f in findings],
        "counts": counts,
        "diff": diff,
        "ok": counts["error"] == 0,
        "_findings": findings,   # live objects for printing; stripped in report
    }


def lint_engine(engine, recipe: str, *, verbose: bool = True) -> list:
    """Lint a LIVE ServingEngine (the ``serve.py --lint`` path). When the
    engine's geometry matches the recipe's checked-in contract the full
    budget checks run; otherwise (custom slots/chunk/horizon) the linter
    falls back to the structural rules only, so a one-off serving config
    never false-positives on budget pins."""
    from ...pipeline.recipes import contract_stem
    from . import contracts
    from .extract import graph_from_engine

    graph = graph_from_engine(engine, recipe=recipe)
    stem = contract_stem(recipe, graph.mesh_shape)
    contract = contracts.load_contract(stem)
    structural_only = (contract is not None
                       and contract.get("engine") != graph.engine)
    if structural_only:
        contract = None
    from .rules import run_rules

    findings = run_rules(graph, contract)
    if verbose:
        counts = _severity_counts(findings)
        mode = ("structural rules only — engine geometry differs from the "
                "checked-in contract" if structural_only
                else "no contract — structural rules only" if contract is None
                else f"contract {stem}")
        print(f"graph lint ({mode}): {counts['error']} error(s), "
              f"{counts['warn']} warning(s), {counts['info']} info")
        txt = format_findings(findings)
        if txt:
            print(txt)
    return findings


def write_summary(path: str, results: list[dict], mode: str) -> None:
    with open(path, "a") as f:
        f.write(f"## Graph lint ({mode})\n\n")
        f.write("| recipe | mesh | errors | warns | contract drift |\n")
        f.write("|---|---|---|---|---|\n")
        for r in results:
            drift = "; ".join(r["diff"][:4]) or "none"
            if len(r["diff"]) > 4:
                drift += f" (+{len(r['diff']) - 4} more)"
            f.write(f"| {r['recipe']} | {r['mesh'] or '-'} | "
                    f"{r['counts']['error']} | {r['counts']['warn']} | "
                    f"{drift} |\n")
        f.write("\n")
        errs = [f for r in results for f in r["findings"]
                if f["severity"] == "error"]
        if errs:
            f.write("### Errors\n\n")
            for e in errs:
                loc = f"{e['jit']}:{e['where']}" if e["where"] else e["jit"]
                f.write(f"- **{e['rule']}** @ `{loc}`: {e['message']}\n")
            f.write("\n")
        # the full drift, per recipe — including `known_debt REMOVED (a
        # win)` lines, which deserve to be visible in the PR summary, not
        # truncated out of the table above
        drifted = [r for r in results if r["diff"]]
        if drifted:
            f.write("### Contract drift\n\n")
            for r in drifted:
                f.write(f"**{r['recipe']}** ({r['stem']}):\n")
                for line in r["diff"]:
                    f.write(f"- {line}\n")
                f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="QuantLint: static contract linter for the compiled "
                    "int8 serving graphs")
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--check", action="store_true",
                      help="lint against the checked-in contracts; exit 1 "
                           "on any error or contract drift (the blocking "
                           "CI gate)")
    mode.add_argument("--update", action="store_true",
                      help="regenerate the contract snapshots from the "
                           "current graphs (review the printed diff!)")
    mode.add_argument("--list-rules", action="store_true",
                      help="print the registered rule names and exit")
    ap.add_argument("--recipes", default=",".join(DEFAULT_RECIPES),
                    help="comma-separated recipe names "
                         f"(default: {','.join(DEFAULT_RECIPES)})")
    ap.add_argument("--arch", default="qwen2-0.5b",
                    help="smoke arch the graphs are extracted from")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the full findings as JSON (the CI artifact)")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append a markdown summary table (CI wires "
                         "$GITHUB_STEP_SUMMARY here)")
    args = ap.parse_args(argv)

    if args.list_rules:
        from .rules import list_rules

        for name in list_rules():
            print(name)
        return 0

    from ...pipeline.state import RecipeError

    results = []
    ok = True
    for recipe in [r for r in args.recipes.split(",") if r]:
        try:
            res = lint_recipe(recipe.strip(), update=args.update,
                              arch=args.arch)
        except RecipeError as e:
            print(f"== {recipe.strip()}: {e}", file=sys.stderr)
            return 2
        findings = res.pop("_findings")
        results.append(res)
        ok = ok and res["ok"]
        where = f" [{res['mesh']}]" if res["mesh"] else ""
        print(f"== {res['recipe']}{where}: {res['action']} — "
              f"{res['counts']['error']} error(s), "
              f"{res['counts']['warn']} warning(s), "
              f"{res['counts']['info']} info")
        txt = format_findings(findings)
        if txt:
            print(txt)
        for line in res["diff"]:
            print(f"  drift: {line}")

    if args.report:
        with open(args.report, "w") as f:
            json.dump({"mode": "update" if args.update else "check",
                       "ok": ok, "recipes": results}, f, indent=2)
            f.write("\n")
    if args.summary:
        write_summary(args.summary, results,
                      "update" if args.update else "check")
    if args.check and not ok:
        print("graph lint FAILED — fix the violation or, if the change is "
              "intentional, run `python -m repro.analysis.lint --update` "
              "and commit the contract diff", file=sys.stderr)
        return 1
    return 0
