"""Divisibility-aware partition planner.

Assigns each parameter tensor a PartitionSpec over the production mesh
(("pod",) "data", "model"):

  * **TP** ("model") on the last (output-feature) dim — Megatron pattern:
    column-parallel qkv/gate/up, row-parallel o/down emerge automatically
    because each weight's *output* dim is sharded and GSPMD propagates,
  * **FSDP/ZeRO** ("data") on the first suitable non-scan dim — parameters,
    gradients and AdamW moments are all sharded over the data axis and
    all-gathered just-in-time by GSPMD,
  * anything non-divisible **replicates** (graceful degradation — e.g.
    qwen2's 14 heads never block compilation),
  * scan-stacked leading dims ([L] layers, and the [E] expert dim when not
    divisible) are never sharded,
  * the "pod" axis holds pure DP: params replicate across pods (keeps weight
    collectives on intra-pod ICI), batch shards over pod × data.

Embeddings / lm_head special-case: vocab on "model" (vocab-parallel logits +
sharded softmax), d_model on "data".
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


MIN_SHARD_DIM = 128  # don't shard tiny dims — collective overhead dominates


def _divisible(dim: int, size: int) -> bool:
    return dim >= MIN_SHARD_DIM and dim % size == 0


_ROW_PARALLEL = ("wo", "wd", "out_proj")   # consume a TP-sharded activation


def _leaf_spec(path: str, shape, mesh: Mesh, n_stacked: int,
               heads: Optional[dict] = None, mode: str = "train") -> P:
    """Megatron-pattern placement:

      * column-parallel (wq/wk/wv/wg/wu/router/in_proj): in=data (FSDP),
        out=model — but attention projections only when the HEAD COUNT
        divides the model axis (a flat-dim shard that splits heads makes
        GSPMD factor the axis through the [B,T,H,hd] reshape and all-reduce
        score tensors — measured 30 GB/layer on qwen2),
      * row-parallel (wo/wd/out_proj): in=model, out=data — the activation
        stays f-sharded through the pair and one all-reduce of [B,T,D]
        partial sums closes the block,
      * non-divisible dims replicate (graceful degradation).

    ``mode="decode"`` drops the FSDP factor (resident serving weights);
    ``mode="serve"`` is decode placement PLUS co-sharded quantized leaves:
    a per-channel QTensor scale lands on the same "model" shard as its int8
    payload's out-feature columns, so a TP shard dequantizes locally without
    gathering foreign scales.
    """
    axes: list = [None] * len(shape)
    if len(shape) == 0:
        return P()
    model_n = mesh.shape.get("model", 1)
    data_n = mesh.shape.get("data", 1)
    if mode in ("decode", "serve"):
        data_n = 10 ** 9  # nothing divides this → no FSDP factor on weights
    heads = heads or {}
    n_q, n_kv = heads.get("n_q", 0), heads.get("n_kv", 0)

    def head_ok(n):
        return n > 0 and n % model_n == 0

    is_attn = "/attn/" in path or "/cross/" in path
    name = path.rsplit("/", 1)[-1]
    if name in ("q", "scale"):           # QTensor children: rules key off the
        parent = path.rsplit("/", 3)[-2]  # parent weight's name (wq/wd/...)
        if name == "scale":
            # The scale's channel dim mirrors the parent weight's OUT-feature
            # dim. Serve mode co-shards it with the int8 payload: a
            # column-parallel weight's scale follows its columns onto "model";
            # row-parallel weights shard the IN dim, so their scales (and all
            # per-tensor size-1 scales — never divisible) replicate.
            if mode != "serve":
                return P()
            out = len(shape) - 1
            tp_ok = _divisible(shape[out], model_n) and parent not in _ROW_PARALLEL
            if is_attn and parent == "wq":
                tp_ok = tp_ok and head_ok(n_q)
            elif is_attn and parent in ("wk", "wv"):
                tp_ok = tp_ok and head_ok(n_kv)
            elif parent == "in_proj":
                tp_ok = False
            if tp_ok:
                axes[out] = "model"
            return P(*axes)
        name = parent

    is_embed = path.endswith("embed") or path.endswith("lm_head") or path.endswith("dec_pos")
    if is_embed and len(shape) == 2:
        spec = [None, None]
        if _divisible(shape[0], model_n):
            spec[0] = "model"          # vocab-parallel
        if _divisible(shape[1], data_n):
            spec[1] = "data"
        if path.endswith("lm_head"):   # [D, V]: vocab is the LAST dim
            spec = [None, None]
            if _divisible(shape[1], model_n):
                spec[1] = "model"
            if _divisible(shape[0], data_n):
                spec[0] = "data"
        return P(*spec)

    free = list(range(n_stacked, len(shape)))
    if len(free) < 2:
        return P()  # 1-D (biases/norm scales): replicate — sharding is noise

    in_dim, out_dim = free[-2], free[-1]
    if name in _ROW_PARALLEL:
        tp_ok = _divisible(shape[in_dim], model_n)
        if name == "wo":
            tp_ok = tp_ok and head_ok(n_q)
        if tp_ok:
            axes[in_dim] = "model"
        if _divisible(shape[out_dim], data_n):
            axes[out_dim] = "data"
        return P(*axes)

    # column-parallel default
    tp_ok = _divisible(shape[out_dim], model_n)
    if is_attn and name == "wq":
        tp_ok = tp_ok and head_ok(n_q)
    elif is_attn and name in ("wk", "wv"):
        tp_ok = tp_ok and head_ok(n_kv)
    elif name == "in_proj":
        tp_ok = False  # mamba: mixed z/x/B/C/dt segments — replicate out
    if tp_ok:
        axes[out_dim] = "model"
    if _divisible(shape[in_dim], data_n):
        axes[in_dim] = "data"
    return P(*axes)


def _n_stacked(path: str, cfg=None) -> int:
    n = 0
    if "blocks" in path:  # scan-stacked layers (and shared_blocks)
        n += 1
    if "experts" in path:
        n += 1
    return n


def _walk(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{prefix}/{i}")
    elif type(tree).__name__ == "QTensor":  # int8 serving weights: q + scale
        yield from _walk(tree.q, f"{prefix}/q")
        yield from _walk(tree.scale, f"{prefix}/scale")
    else:
        yield prefix, tree


def _rebuild(tree, flat: dict, prefix: str = ""):
    """Re-nest a {path: spec} mapping into the shape tree's structure (the
    inverse of ``_walk`` — one implementation for every *_pspecs builder)."""
    if isinstance(tree, dict):
        return {k: _rebuild(v, flat, f"{prefix}/{k}") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_rebuild(v, flat, f"{prefix}/{i}") for i, v in enumerate(tree)]
        return type(tree)(t) if not hasattr(tree, "_fields") else type(tree)(*t)
    if type(tree).__name__ == "QTensor":
        from ..quantized.qtensor import QTensor

        return QTensor(_rebuild(tree.q, flat, f"{prefix}/q"),
                       _rebuild(tree.scale, flat, f"{prefix}/scale"), tree.mode)
    return flat[prefix]


def _dp_world(mesh: Mesh):
    """(dp_axes, dp_n): the data-parallel axis spec (with the leading "pod"
    when present) and its total world size."""
    dp_axes = ("pod", "data") if "pod" in mesh.shape else "data"
    dp_n = int(np.prod([mesh.shape[a] for a in
                        ((dp_axes,) if isinstance(dp_axes, str) else dp_axes)]))
    return dp_axes, dp_n


def params_pspecs(params_shapes: Any, mesh: Mesh, heads: Optional[dict] = None,
                  mode: str = "train") -> Any:
    """PartitionSpec pytree matching a params (or optimizer-state) pytree of
    arrays / ShapeDtypeStructs. ``heads`` = {"n_q", "n_kv"} enables the
    head-divisibility constraint on attention projections. ``mode="decode"``
    drops the FSDP ("data") factor: serving weights stay device-resident."""

    def spec_of(path, leaf):
        return _leaf_spec(path, leaf.shape, mesh, _n_stacked(path), heads, mode)

    paths = dict(_walk(params_shapes))
    flat_specs = {p: spec_of(p, l) for p, l in paths.items()}
    return _rebuild(params_shapes, flat_specs)


def batch_pspec(mesh: Mesh, ndim: int = 2, batch: Optional[int] = None) -> P:
    """Batch dim over (pod, data); replicate when the global batch doesn't
    divide the DP world (the long-context batch=1 decode cells)."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    if batch is not None and batch % dp_n != 0:
        return P(*([None] * ndim))
    return P(dp, *([None] * (ndim - 1)))


def cache_pspecs(cache_shapes: Any, mesh: Mesh, batch: int) -> Any:
    """KV/SSM cache sharding: batch over (pod, data) when divisible, else
    sequence over "data" (the long-context B=1 case); heads over "model"."""
    dp_axes, dp_n = _dp_world(mesh)
    model_n = mesh.shape.get("model", 1)

    def spec_of(path, leaf):
        shape = leaf.shape
        if len(shape) <= 1:
            return P()
        axes: list = [None] * len(shape)
        # layouts: k/v [L, B, S, H, hd]; ssm [L, B, H, P, S]; conv [L, B, W, C]
        if len(shape) >= 3:
            B_dim = 1
            if shape[B_dim] % dp_n == 0 and shape[B_dim] >= dp_n:
                axes[B_dim] = dp_axes
            elif (path.endswith("/k") or path.endswith("/v")
                  or path.endswith("_scale") or path.endswith("/v_err")):
                S_dim = 2
                if shape[S_dim] % dp_n == 0:
                    axes[S_dim] = dp_axes
            if ((path.endswith("_scale") or path.endswith("/v_err"))
                    and len(shape) == 4):
                # [L, B, S, H] int8-cache scales (and the optional V
                # dequant-error means): follow the payload sharding
                if shape[2] % model_n == 0 and shape[2] >= model_n:
                    axes[2] = "model"
            if (path.endswith("/k") or path.endswith("/v")) and len(shape) == 5:
                # Prefer SEQUENCE sharding of the cache over "model": the
                # pv contraction then psums a tiny [B,H,1,hd] partial per
                # layer. Sharding heads/head_dim instead psums [B,H,1,S]
                # score rows — measured 22.6 GB/device/step on yi-34b
                # decode_32k (EXPERIMENTS §Perf iteration C2).
                if axes[2] is None and shape[2] % model_n == 0 and shape[2] >= model_n:
                    axes[2] = "model"
                elif shape[3] % model_n == 0 and shape[3] >= model_n:
                    axes[3] = "model"
                elif shape[4] % model_n == 0 and shape[4] >= model_n:
                    axes[4] = "model"
            if path.endswith("/ssm") and len(shape) == 5:
                if shape[2] % model_n == 0:
                    axes[2] = "model"
        return P(*axes)

    paths = dict(_walk(cache_shapes))
    flat = {p: spec_of(p, l) for p, l in paths.items()}
    return _rebuild(cache_shapes, flat)


def serve_cache_pspecs(cache_shapes: Any, mesh: Mesh) -> Any:
    """Serving (per-slot pooled) cache sharding for the continuous-batching
    engine: the SLOT axis shards over "data" and KV heads over "model".

    Layouts: k/v [L, B, S, H, hd]; k_scale/v_scale/v_err [L, B, S, H];
    kpos [B, S]; pos [B] — B is the slot axis. Rules:

      * slots over ("pod",) "data" when the pool size divides the DP world —
        no MIN_SHARD_DIM floor here: slot pools are inherently small and
        every slot's computation is row-independent, so slot sharding is
        exact (it never changes a reduction order),
      * KV heads over "model" when divisible (head-parallel attention — each
        head's softmax·V stays device-local),
      * the int8-cache scale leaves (k_scale/v_scale) and the V dequant-error
        means (v_err) FOLLOW their payload tensor: same slot axis, same head
        axis, so a shard dequantizes its own cache columns locally,
      * anything non-divisible replicates (graceful degradation).

    **Paged pools** (a ``page_table`` leaf is present; payload leaves are
    [L, NP, pg, H(, hd)]) shard KV heads (axis 3) over "model" exactly like
    the contiguous layout, but the PAGE axis — and the page tables and
    dense kpos/pos bookkeeping — replicate. Sharding pages over "data"
    looks symmetric to slot-sharding, but the paged jits address pages
    through data-dependent table lookups, so GSPMD would have to all-gather
    whole pool leaves around every page gather/scatter: new full-pool
    collectives, exactly what the lint contracts' collective budget pins at
    zero. Head sharding keeps the capacity win (each shard holds 1/TP of
    every page) without any cross-shard addressing; slot-parallel paged
    serving (shard_map over per-shard page pools) is the ROADMAP follow-on.
    """
    dp_axes, dp_n = _dp_world(mesh)
    model_n = mesh.shape.get("model", 1)
    paths = dict(_walk(cache_shapes))
    paged = any(p.rsplit("/", 1)[-1] == "page_table" for p in paths)

    def spec_of(path, leaf):
        shape = leaf.shape
        axes: list = [None] * len(shape)
        name = path.rsplit("/", 1)[-1]
        if name in ("kpos", "pos"):                     # [B, S] / [B]
            if (not paged and shape and shape[0] % dp_n == 0
                    and shape[0] >= dp_n):
                axes[0] = dp_axes
            return P(*axes)
        if name in ("k", "v", "k_scale", "v_scale", "v_err") and len(shape) >= 4:
            if (not paged and shape[1] % dp_n == 0 and shape[1] >= dp_n):
                axes[1] = dp_axes                       # slot axis
            H_dim = 3                                   # heads (payload + scales)
            if shape[H_dim] % model_n == 0 and shape[H_dim] >= model_n:
                axes[H_dim] = "model"
            return P(*axes)
        return P(*axes)

    flat = {p: spec_of(p, l) for p, l in paths.items()}
    return _rebuild(cache_shapes, flat)


def named_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def payload_scale_pairs(tree: Any, prefix: str = "") -> list:
    """Every (q_path, scale_path) pair of QTensor leaves in a params pytree,
    in ``_walk`` path notation — the scale-coupling lint rule checks each
    pair shares its out-feature sharding axis."""
    pairs: list = []
    if type(tree).__name__ == "QTensor":
        pairs.append((f"{prefix}/q", f"{prefix}/scale"))
    elif isinstance(tree, dict):
        for k, v in tree.items():
            pairs.extend(payload_scale_pairs(v, f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            pairs.extend(payload_scale_pairs(v, f"{prefix}/{i}"))
    return pairs


def spec_paths(spec_tree: Any, prefix: str = ""):
    """Yield (path, PartitionSpec) pairs from a spec pytree. A dedicated
    walker: PartitionSpec subclasses tuple on some jax versions, so the
    generic ``_walk`` would iterate INTO the spec instead of yielding it."""
    if isinstance(spec_tree, P):
        yield prefix, spec_tree
    elif isinstance(spec_tree, dict):
        for k, v in spec_tree.items():
            yield from spec_paths(v, f"{prefix}/{k}")
    elif isinstance(spec_tree, (list, tuple)):
        for i, v in enumerate(spec_tree):
            yield from spec_paths(v, f"{prefix}/{i}")
    elif type(spec_tree).__name__ == "QTensor":
        yield from spec_paths(spec_tree.q, f"{prefix}/q")
        yield from spec_paths(spec_tree.scale, f"{prefix}/scale")
    else:
        yield prefix, spec_tree
