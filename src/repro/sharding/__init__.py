from .partition import (  # noqa: F401
    batch_pspec,
    cache_pspecs,
    named_shardings,
    params_pspecs,
    payload_scale_pairs,
    serve_cache_pspecs,
    spec_paths,
)
