from .partition import (  # noqa: F401
    batch_pspec,
    cache_pspecs,
    named_shardings,
    params_pspecs,
    serve_cache_pspecs,
)
