"""Serving driver: quantize a model through the pipeline API and serve it
with the continuous-batching engine (INT8 weights via the QTensor kernel
dispatch, slot-based KV-cache pool, FIFO admission).

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --quantize w8a16
    python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --recipe serve-w8a8 --verbose --save /tmp/qwen_int8
    python -m repro.launch.serve --load /tmp/qwen_int8
    python -m repro.launch.serve --arch qwen2-0.5b --smoke --trace 20

    # tensor-parallel sharded serving (8 virtual CPU devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.serve --arch qwen2-0.5b --smoke --mesh 2x4
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import time

import jax
import numpy as np

from ..configs import get_config
from ..data import calibration_tokens
from ..models import build_model
from ..pipeline import QuantizedModel, quantize
from ..serving import (
    QueueFull,
    Request,
    ServingEngine,
    open_loop_trace,
    required_cache_len,
    synthetic_trace,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", choices=["none", "w8a16", "w8a8"], default="w8a16")
    ap.add_argument("--recipe", default=None,
                    help="pipeline recipe name (overrides --quantize)")
    ap.add_argument("--kv-bits", type=int, choices=[8, 16], default=None,
                    help="KV-cache precision: 8 = int8 payload + per-token/"
                         "per-head scales (~4x fewer cache bytes/slot, "
                         "decode attends through the kv_attention kernel), "
                         "16 = fp. Default: what the recipe/artifact "
                         "recorded (--quantize w8a16 --kv-bits 8 selects "
                         "the serve-w8a16-kv8 recipe)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve sharded over a device mesh, e.g. 2x4 = "
                         "(\"data\": 2, \"model\": 4) — slots shard over "
                         "data, weights TP over model (a P x D x M form adds "
                         "the leading \"pod\" axis). Needs D*M devices: on "
                         "CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N. "
                         "Default: the mesh recorded in a --load artifact, "
                         "else single-device")
    ap.add_argument("--save", default=None, metavar="DIR",
                    help="persist the QuantizedModel after quantization "
                         "(with --mesh: the serve-mode partition specs are "
                         "recorded in the artifact)")
    ap.add_argument("--load", default=None, metavar="DIR",
                    help="serve a saved QuantizedModel (skips quantization)")
    ap.add_argument("--verbose", action="store_true",
                    help="print per-site weight SQNR diagnostics")
    ap.add_argument("--batch", type=int, default=4,
                    help="without --trace: number of uniform requests")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4,
                    help="engine cache-pool size (decode batch width)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="per-slot KV capacity (default: fits prompt+gen)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=None, metavar="PG",
                    help="switch the KV pool to the paged layout: fixed "
                         "PG-position pages + per-slot page tables, with "
                         "refcounted copy-on-write shared-prefix reuse "
                         "(requests sharing a prompt prefix share its pages "
                         "physically). Tokens are bit-identical to the "
                         "contiguous pool. Default: contiguous")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (with --page-size); default gives "
                         "every slot a full ring — smaller pools admit by "
                         "page demand and lean on prefix sharing")
    ap.add_argument("--no-prefix-reuse", action="store_true",
                    help="with --page-size: disable the scheduler's prefix "
                         "index (pages without sharing)")
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="max decode steps fused into one device dispatch "
                         "(the engine adapts the actual horizon to budgets "
                         "and scheduled arrivals)")
    ap.add_argument("--reference", action="store_true",
                    help="use the stepwise fast=False reference path (one "
                         "dispatch + one host sync per token) instead of "
                         "the device-resident fast path")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile all pow2 prefill/horizon shapes "
                         "before serving (excluded from the timed run)")
    ap.add_argument("--trace", type=int, default=0, metavar="N",
                    help="replay a synthetic arrival schedule of N requests "
                         "(mixed log-uniform lengths, Poisson arrivals)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--max-queue", type=int, default=None, metavar="Q",
                    help="bound the admission queue: submissions beyond Q "
                         "shed with the retryable QueueFull error "
                         "(back-pressure). Default: unbounded")
    ap.add_argument("--serve-async", action="store_true",
                    help="serve the --trace through the overload-safe async "
                         "front-end (serving.AsyncServer): per-request token "
                         "streaming, client retry with backoff + jitter on "
                         "the retryable taxonomy, circuit breaker, and "
                         "priority-aware load shedding; reports the SLO view "
                         "(TTFT / per-token percentiles, goodput)")
    ap.add_argument("--qps", type=float, default=0.5, metavar="R",
                    help="with --serve-async: offered Poisson arrival rate "
                         "in requests per engine tick (open loop)")
    ap.add_argument("--timeout", type=float, default=None, metavar="T",
                    help="with --serve-async: per-request client timeout in "
                         "engine ticks, enforced as the engine deadline "
                         "(tighter of this and --deadline wins)")
    ap.add_argument("--retry-attempts", type=int, default=4,
                    help="with --serve-async: max submission attempts per "
                         "request (retryable rejections back off with "
                         "exponential backoff + full jitter)")
    ap.add_argument("--breaker-cooldown", type=float, default=16.0,
                    help="with --serve-async: circuit-breaker cooldown in "
                         "engine ticks before a half-open probe")
    ap.add_argument("--shed-pressure", type=float, default=0.5,
                    help="with --serve-async: queue pressure (depth/bound) "
                         "at which the lowest priority class is shed; "
                         "deadlines tighten at 1.5x this value and all "
                         "requests are refused at 2x (capped at 1.0)")
    ap.add_argument("--straggler-threshold", type=float, default=None,
                    metavar="X",
                    help="flag an engine step as a straggler when its wall "
                         "time exceeds X times the EMA of recent steps "
                         "(surfaced as stats['straggler_threshold'] and in "
                         "the final report). Default: the monitor's 2.0")
    ap.add_argument("--deadline", type=float, default=None, metavar="T",
                    help="give every request a deadline of T engine ticks "
                         "after its arrival; expired requests are shed "
                         "(queued) or cut short (in flight) at the next "
                         "step boundary and report status 'expired'")
    ap.add_argument("--lint", action="store_true",
                    help="run the QuantLint graph linter over this engine's "
                         "compiled serve paths before serving (warn-only "
                         "here; `python -m repro.analysis.lint --check` is "
                         "the blocking CI gate)")
    args = ap.parse_args(argv)

    # validate flag combinations BEFORE any quantization runs: a typo must
    # not discard minutes of pipeline work
    if args.num_pages is not None and args.page_size is None:
        ap.error("--num-pages needs --page-size")
    if args.max_queue is not None and args.max_queue < 1:
        ap.error("--max-queue must be >= 1")
    if args.deadline is not None and args.deadline <= 0:
        ap.error("--deadline must be > 0 engine ticks")
    if args.no_prefix_reuse and args.page_size is None:
        ap.error("--no-prefix-reuse needs --page-size")
    if args.serve_async and not args.trace:
        ap.error("--serve-async needs --trace N (open-loop arrivals)")
    if args.serve_async and args.qps <= 0:
        ap.error("--qps must be > 0 requests/tick")
    if args.serve_async and args.retry_attempts < 1:
        ap.error("--retry-attempts must be >= 1")
    if not 0.0 < args.shed_pressure <= 1.0:
        ap.error("--shed-pressure must be in (0, 1]")
    if args.straggler_threshold is not None and args.straggler_threshold <= 1:
        ap.error("--straggler-threshold must be > 1 (a slowdown multiplier)")
    cli_shape = None
    if args.mesh:
        try:
            cli_shape = tuple(int(s) for s in args.mesh.lower().split("x"))
        except ValueError:
            cli_shape = ()
        if len(cli_shape) not in (2, 3) or any(s < 1 for s in cli_shape):
            ap.error(f"--mesh wants DxM (or PxDxM), e.g. 2x4; got {args.mesh!r}")
        need = int(np.prod(cli_shape))
        if need > jax.device_count():
            ap.error(
                f"--mesh {args.mesh} needs {need} devices but jax sees "
                f"{jax.device_count()}; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need}"
            )

    def check_servable(cfg, what):
        if cfg.family in ("ssm", "hybrid") or cfg.is_encdec:
            ap.error(
                f"{what}: the continuous-batching engine serves "
                f"attention-family decoder-only models; quantize "
                f"{cfg.family!r} archs via repro.pipeline.cli and run them "
                f"through model.prefill/decode_step directly"
            )

    if args.load:
        if args.recipe or args.smoke or args.quantize != "w8a16":
            print("warning: --load serves the saved artifact as-is; "
                  "--arch/--smoke/--recipe/--quantize are ignored "
                  "(--save re-saves it, recording specs when --mesh is set)")
        qm = QuantizedModel.load(args.load)
        cfg, model, params = qm.cfg, qm.model, qm.params
        check_servable(cfg, f"--load {args.load} (arch {cfg.name})")
        if args.kv_bits is not None and cfg.kv_cache_bits != args.kv_bits:
            # the artifact's kv_cache stage already quantized FOR its
            # recorded precision — silently serving at another one would
            # ship a cache the calibration never saw
            ap.error(
                f"--kv-bits {args.kv_bits} conflicts with --load "
                f"{args.load}: the artifact recorded kv_cache_bits="
                f"{cfg.kv_cache_bits} (recipe {qm.recipe.name!r}). Either "
                f"drop --kv-bits to serve as recorded, or re-quantize with "
                f"a kv{args.kv_bits} recipe"
            )
        print(f"loaded QuantizedModel from {args.load} "
              f"(arch {cfg.name}, recipe {qm.recipe.name!r})")
    else:
        cfg = get_config(args.arch, smoke=args.smoke)
        check_servable(cfg, f"--arch {args.arch}")
        model = build_model(cfg)
        qm = None
        if args.recipe or args.quantize != "none":
            recipe = args.recipe
            if recipe is None:
                from ..pipeline.recipes import BUILTIN_RECIPES

                recipe = (f"serve-{args.quantize}-kv8" if args.kv_bits == 8
                          else f"serve-{args.quantize}")
                # --mesh prefers the -tp recipe variant (adds the shard
                # stage, so the artifact records the parallelism plan); the
                # engine serves any recipe sharded either way
                if args.mesh and f"{recipe}-tp" in BUILTIN_RECIPES:
                    recipe = f"{recipe}-tp"
            qm = quantize(model, recipe=recipe)
            if (args.kv_bits is not None
                    and qm.cfg.kv_cache_bits != args.kv_bits):
                # an explicit --recipe may not carry a kv_cache stage: fold
                # the requested KV precision into the artifact so a --save /
                # --load round trip serves with the same cache as this run
                qm.cfg = dataclasses.replace(
                    qm.cfg, kv_cache_bits=args.kv_bits)
                qm.model = build_model(qm.cfg)
            cfg, model, params = qm.cfg, qm.model, qm.params
        else:
            params = model.init(jax.random.PRNGKey(0))

    # ------------------------------------------------------------------ mesh
    mesh = None
    mesh_src, shape = None, None
    if cli_shape is not None:               # validated up front, pre-pipeline
        shape, mesh_src = cli_shape, "--mesh"
    elif qm is not None and qm.shard_mode and qm.sharding.get("mesh_shape"):
        shape = tuple(qm.sharding["mesh_shape"])
        mesh_src = "artifact-recorded mesh"
        need = int(np.prod(shape))
        if need > jax.device_count():
            # artifact-recorded topology on a smaller host: serve unsharded
            print(f"note: {mesh_src} {'x'.join(map(str, shape))} needs "
                  f"{need} devices but jax sees {jax.device_count()}; on CPU "
                  f"set XLA_FLAGS=--xla_force_host_platform_device_count="
                  f"{need} — serving single-device")
            shape = None
    if shape is not None:
        from .mesh import make_production_mesh

        mesh = make_production_mesh(shape=shape)
        print(f"mesh ({mesh_src}): "
              f"{dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names)))}")
    elif qm is not None and qm.shard_mode and not mesh_src:
        print(f"note: artifact records {qm.shard_mode!r} sharding; pass "
              f"--mesh DxM to serve it across a device mesh")

    if qm is not None:
        s = qm.serving_summary()
        print(f"quantized (recipe {qm.recipe.name!r}): "
              f"{s['int8_bytes'] / 1e6:.1f} MB "
              f"vs fp32 {s['fp32_bytes'] / 1e6:.1f} MB "
              f"({s['compression']:.2f}x)")
        if args.verbose:
            from ..pipeline.cli import print_site_sqnr

            print_site_sqnr(qm)
        if args.save:
            qm.save(args.save, mesh=mesh)
            print(f"saved QuantizedModel to {args.save}"
                  + (" (serve-mode specs recorded)"
                     if mesh is not None and qm.shard_mode else ""))

    # ---------------------------------------------------------------- engine
    C = args.prefill_chunk
    if args.trace:
        if args.prompt_len < 1 or args.gen_len < 1:
            ap.error("--trace needs --prompt-len/--gen-len >= 1")
        p_lo, g_lo = min(4, args.prompt_len), min(4, args.gen_len)
        if args.serve_async:
            # two priority classes so the shedder's lowest-class rung has a
            # victim population (class 1 survives rung 1)
            requests = open_loop_trace(
                args.trace_seed, args.trace, args.qps,
                vocab_size=cfg.vocab_size,
                prompt_lens=(p_lo, args.prompt_len),
                gen_lens=(g_lo, args.gen_len), priority_levels=2,
            )
        else:
            requests = synthetic_trace(
                args.trace_seed, args.trace, vocab_size=cfg.vocab_size,
                prompt_lens=(p_lo, args.prompt_len),
                gen_lens=(g_lo, args.gen_len), mean_interarrival=1.0,
            )
        if args.deadline is not None:
            requests = [dataclasses.replace(
                r, deadline=r.arrival + args.deadline) for r in requests]
        rate = f" at {args.qps:g} req/tick" if args.serve_async else ""
        print(f"trace: {len(requests)} requests, "
              f"prompt {p_lo}..{args.prompt_len}, "
              f"gen {g_lo}..{args.gen_len}, Poisson arrivals{rate}")
    else:
        prompts = np.asarray(
            calibration_tokens(0, args.batch, args.prompt_len, cfg.vocab_size)
        )
        requests = [
            Request(rid=i, prompt=prompts[i], max_new_tokens=args.gen_len,
                    deadline=(args.deadline if args.deadline is not None
                              else None))
            for i in range(args.batch)
        ]

    need = max(
        required_cache_len(len(r.prompt), r.max_new_tokens, C)
        for r in requests
    )
    max_len = args.max_len or need
    straggler = None
    if args.straggler_threshold is not None:
        from ..runtime.fault_tolerance import StragglerMonitor

        straggler = StragglerMonitor(threshold=args.straggler_threshold)
    engine = ServingEngine(
        model, params, cfg, num_slots=args.slots, max_len=max_len,
        prefill_chunk=C, decode_horizon=args.decode_horizon,
        fast=not args.reference, kv_bits=args.kv_bits, mesh=mesh,
        page_size=args.page_size, num_pages=args.num_pages,
        prefix_reuse=not args.no_prefix_reuse, max_queue=args.max_queue,
        straggler=straggler,
    )
    layout = (f"paged ({engine.pool.num_pages} pages x {engine.page_size} "
              f"positions, prefix reuse "
              f"{'on' if engine.prefix_index is not None else 'off'})"
              if engine.paged else f"{args.slots} slots x {max_len} positions")
    print(f"kv cache: {'int8' if engine.kv_bits == 8 else 'fp'} "
          f"({engine.pool.bytes_per_slot() / 1e3:.1f} kB/slot, {layout})")
    if args.lint:
        from ..analysis.lint import lint_engine

        recipe_name = qm.recipe.name if qm is not None else "fp32"
        t0 = time.time()
        findings = lint_engine(engine, recipe_name)
        n_err = sum(f.severity == "error" for f in findings)
        print(f"--lint: {'FAIL' if n_err else 'pass'} "
              f"({time.time() - t0:.1f} s; warn-only at runtime — serving "
              f"continues)")
    if args.warmup:
        t0 = time.time()
        engine.warmup()
        print(f"warmup: compiled serving shapes in {time.time() - t0:.1f} s")

    # SIGTERM → graceful drain: stop admitting, finish in-flight + parked,
    # report, exit 0 (modeled on runtime.fault_tolerance.FaultTolerantLoop).
    t0 = time.time()
    sigterm: list = []   # the async path drains on normal close too, so the
    #                      report needs to know whether SIGTERM actually fired
    if args.serve_async:
        import asyncio

        from ..serving import (
            SLO,
            AsyncClient,
            AsyncServer,
            CircuitBreaker,
            RetryPolicy,
            ShedPolicy,
            run_open_loop,
            summarize,
        )

        sp = args.shed_pressure
        server = AsyncServer(
            engine,
            breaker=CircuitBreaker(cooldown=args.breaker_cooldown),
            shed=ShedPolicy(shed_pressure=sp,
                            tighten_pressure=min(1.0, 1.5 * sp),
                            refuse_pressure=min(1.0, 2.0 * sp)),
        )
        client = AsyncClient(
            server, RetryPolicy(max_attempts=args.retry_attempts),
            seed=args.trace_seed)
        prev_handler = signal.signal(
            signal.SIGTERM,
            lambda *_: (sigterm.append(1), server.drain()))
        try:
            outcomes = asyncio.run(run_open_loop(
                server, client, requests, timeout=args.timeout))
        finally:
            signal.signal(signal.SIGTERM, prev_handler)
        dt = time.time() - t0
        slo = SLO()
        summary = summarize(outcomes, slo=slo)
        print(f"async front-end: offered {summary['offered_qps']:.3f} "
              f"req/tick, goodput {summary['goodput_qps']:.3f} req/tick "
              f"({summary['goodput_fraction']:.0%} of offered; SLO: ttft <= "
              f"{slo.ttft:g}, per-token <= {slo.per_token:g} ticks)")
        print(f"  ttft p50/p99 {summary['ttft_p50']:.1f}/"
              f"{summary['ttft_p99']:.1f} ticks, per-token p50/p99 "
              f"{summary['per_token_p50']:.2f}/"
              f"{summary['per_token_p99']:.2f} ticks, "
              f"mean attempts {summary['mean_attempts']:.2f}")
        srv = server.stats
        print("  admission: " + ", ".join(
            f"{k}={srv[k]}" for k in
            ("submitted", "accepted", "shed_breaker", "shed_priority",
             "shed_refused", "shed_queue", "deadlines_tightened"))
            + f"; breaker opens={server.breaker.opens}")
        results = engine.results
    else:
        prev_handler = signal.signal(
            signal.SIGTERM,
            lambda *_: (sigterm.append(1), engine.request_drain()))
        try:
            shed = []
            for r in requests:
                try:
                    engine.submit(r)
                except QueueFull:
                    shed.append(r.rid)
            results = engine.run()
        finally:
            signal.signal(signal.SIGTERM, prev_handler)
        dt = time.time() - t0
    if sigterm:
        print(f"drain: SIGTERM received — admission stopped, "
              f"{engine.scheduler.pending()} queued requests unserved")
    gen = engine.stats["generated_tokens"]
    path = "reference (stepwise)" if args.reference else \
        f"fast (decode horizon {args.decode_horizon})"
    if mesh is not None:
        path += f", sharded {'x'.join(str(mesh.shape[a]) for a in mesh.axis_names)}"
    print(f"served {len(results)} requests / {gen} generated tokens "
          f"in {dt*1e3:.1f} ms ({gen / max(dt, 1e-9):.1f} tok/s, "
          f"{path} path)")
    print(f"engine: {engine.stats['decode_steps']} decode steps in "
          f"{engine.stats['decode_dispatches']} dispatches, "
          f"{engine.stats['prefill_chunks']} prefill chunks in "
          f"{engine.stats['prefill_dispatches']} dispatches, "
          f"{engine.syncs_per_token():.2f} host syncs/token, "
          f"mean slot occupancy {engine.mean_occupancy():.2f}")
    faults = {k: engine.stats[k] for k in
              ("shed", "preempted", "resumed", "cancelled", "expired",
               "quarantined", "straggler_steps")}
    by_status: dict[str, int] = {}
    for res in results.values():
        by_status[res.status] = by_status.get(res.status, 0) + 1
    if any(faults.values()) or set(by_status) - {"ok"}:
        print("faults: " + ", ".join(f"{k}={v}" for k, v in faults.items())
              + f" (straggler threshold "
                f"{engine.stats['straggler_threshold']:g}x step EMA)")
        print("results by status: " +
              ", ".join(f"{k}={v}" for k, v in sorted(by_status.items())))
    if not results:
        return results
    first = results[min(results)]
    print(f"sample token ids (rid {first.rid}):", first.tokens[:12])
    return results


if __name__ == "__main__":
    main()
