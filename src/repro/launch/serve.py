"""Serving driver: quantize a model through the pipeline API and serve it
with the continuous-batching engine (INT8 weights via the QTensor kernel
dispatch, slot-based KV-cache pool, FIFO admission).

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --quantize w8a16
    python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --recipe serve-w8a8 --verbose --save /tmp/qwen_int8
    python -m repro.launch.serve --load /tmp/qwen_int8
    python -m repro.launch.serve --arch qwen2-0.5b --smoke --trace 20

    # tensor-parallel sharded serving (8 virtual CPU devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.serve --arch qwen2-0.5b --smoke --mesh 2x4

The flag surface is the typed ``ServeConfig`` dataclass (serve_config.py) —
argparse is derived from it, and ``serve(config)`` is the public API peer
of ``repro.quantize``:

    import repro
    repro.serve(repro.ServeConfig(arch="qwen2-0.5b", smoke=True, trace=20))
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from ..configs import get_config
from ..data import calibration_tokens
from ..models import build_model
from ..pipeline import QuantizedModel, quantize
from ..serving import (
    QueueFull,
    Request,
    ServingEngine,
    open_loop_trace,
    required_cache_len,
    synthetic_trace,
)
from .serve_config import (          # noqa: F401  (re-exported API surface)
    ServeConfig,
    ServeConfigError,
    build_parser,
)


def _check_servable(cfg, what):
    if cfg.family in ("ssm", "hybrid") or cfg.is_encdec:
        raise ServeConfigError(
            f"{what}: the continuous-batching engine serves "
            f"attention-family decoder-only models; quantize "
            f"{cfg.family!r} archs via repro.pipeline.cli and run them "
            f"through model.prefill/decode_step directly"
        )


def serve(config: ServeConfig):
    """Quantize (or ``load``) a model and serve it — the whole driver behind
    ``python -m repro.launch.serve``, callable as ``repro.serve(config)``.
    Returns the engine's ``{rid: RequestResult}`` map. Invalid or
    conflicting configuration raises ``ServeConfigError``."""
    config = dataclasses.replace(config).validate()
    cli_mesh = config.mesh        # pre-merge: distinguishes --mesh vs artifact

    qm = None
    if config.load:
        qm = QuantizedModel.load(config.load)
        _check_servable(qm.cfg, f"--load {config.load} (arch {qm.cfg.name})")
        # the artifact's kv_cache stage already quantized FOR its recorded
        # precision (and its weights ARE the recorded recipe) — one
        # precedence rule covers every CLI-vs-artifact field
        config, notes = config.with_artifact(ServeConfig.from_artifact(qm))
        for n in notes:
            print(f"note: {n}")
        cfg, model, params = qm.cfg, qm.model, qm.params
        print(f"loaded QuantizedModel from {config.load} "
              f"(arch {cfg.name}, recipe {qm.recipe.name!r})")
    else:
        cfg = get_config(config.arch, smoke=config.smoke)
        _check_servable(cfg, f"--arch {config.arch}")
        model = build_model(cfg)
        if config.recipe or config.quantize != "none":
            recipe = config.recipe
            if recipe is None:
                from ..pipeline.recipes import BUILTIN_RECIPES

                recipe = (f"serve-{config.quantize}-kv8"
                          if config.kv_bits == 8
                          else f"serve-{config.quantize}")
                # --mesh prefers the -tp recipe variant (adds the shard
                # stage, so the artifact records the parallelism plan); the
                # engine serves any recipe sharded either way
                if config.mesh and f"{recipe}-tp" in BUILTIN_RECIPES:
                    recipe = f"{recipe}-tp"
            qm = quantize(model, recipe=recipe)
            if (config.kv_bits is not None
                    and qm.cfg.kv_cache_bits != config.kv_bits):
                # an explicit --recipe may not carry a kv_cache stage: fold
                # the requested KV precision into the artifact so a --save /
                # --load round trip serves with the same cache as this run
                qm.cfg = dataclasses.replace(
                    qm.cfg, kv_cache_bits=config.kv_bits)
                qm.model = build_model(qm.cfg)
            cfg, model, params = qm.cfg, qm.model, qm.params
        else:
            params = model.init(jax.random.PRNGKey(0))

    # ------------------------------------------------------------------ mesh
    mesh = None
    shape = config.mesh
    mesh_src = ("--mesh" if shape is not None and shape == cli_mesh
                else "artifact-recorded mesh" if shape is not None else None)
    if mesh_src == "artifact-recorded mesh":
        need = int(np.prod(shape))
        if need > jax.device_count():
            # artifact-recorded topology on a smaller host: serve unsharded
            print(f"note: {mesh_src} {'x'.join(map(str, shape))} needs "
                  f"{need} devices but jax sees {jax.device_count()}; on CPU "
                  f"set XLA_FLAGS=--xla_force_host_platform_device_count="
                  f"{need} — serving single-device")
            shape = None
    if shape is not None:
        from .mesh import make_production_mesh

        mesh = make_production_mesh(shape=shape)
        print(f"mesh ({mesh_src}): "
              f"{dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names)))}")
    elif qm is not None and qm.shard_mode and mesh_src is None:
        print(f"note: artifact records {qm.shard_mode!r} sharding; pass "
              f"--mesh DxM to serve it across a device mesh")

    if qm is not None:
        s = qm.serving_summary()
        print(f"quantized (recipe {qm.recipe.name!r}): "
              f"{s['int8_bytes'] / 1e6:.1f} MB "
              f"vs fp32 {s['fp32_bytes'] / 1e6:.1f} MB "
              f"({s['compression']:.2f}x)")
        if config.verbose:
            from ..pipeline.cli import print_site_sqnr

            print_site_sqnr(qm)
        if config.save:
            qm.save(config.save, mesh=mesh)
            print(f"saved QuantizedModel to {config.save}"
                  + (" (serve-mode specs recorded)"
                     if mesh is not None and qm.shard_mode else ""))

    # ---------------------------------------------------------------- engine
    C = config.prefill_chunk
    if config.trace:
        p_lo, g_lo = min(4, config.prompt_len), min(4, config.gen_len)
        if config.serve_async:
            # two priority classes so the shedder's lowest-class rung has a
            # victim population (class 1 survives rung 1)
            requests = open_loop_trace(
                config.trace_seed, config.trace, config.qps,
                vocab_size=cfg.vocab_size,
                prompt_lens=(p_lo, config.prompt_len),
                gen_lens=(g_lo, config.gen_len), priority_levels=2,
            )
        else:
            requests = synthetic_trace(
                config.trace_seed, config.trace, vocab_size=cfg.vocab_size,
                prompt_lens=(p_lo, config.prompt_len),
                gen_lens=(g_lo, config.gen_len), mean_interarrival=1.0,
            )
        if config.deadline is not None:
            requests = [dataclasses.replace(
                r, deadline=r.arrival + config.deadline) for r in requests]
        rate = f" at {config.qps:g} req/tick" if config.serve_async else ""
        print(f"trace: {len(requests)} requests, "
              f"prompt {p_lo}..{config.prompt_len}, "
              f"gen {g_lo}..{config.gen_len}, Poisson arrivals{rate}")
    else:
        prompts = np.asarray(
            calibration_tokens(0, config.batch, config.prompt_len,
                               cfg.vocab_size)
        )
        requests = [
            Request(rid=i, prompt=prompts[i],
                    max_new_tokens=config.gen_len,
                    deadline=(config.deadline
                              if config.deadline is not None else None))
            for i in range(config.batch)
        ]

    need = max(
        required_cache_len(len(r.prompt), r.max_new_tokens, C)
        for r in requests
    )
    max_len = config.max_len or need
    straggler = None
    if config.straggler_threshold is not None:
        from ..runtime.fault_tolerance import StragglerMonitor

        straggler = StragglerMonitor(threshold=config.straggler_threshold)
    engine = ServingEngine(
        model, params, cfg, num_slots=config.slots, max_len=max_len,
        prefill_chunk=C, decode_horizon=config.decode_horizon,
        fast=not config.reference, kv_bits=config.kv_bits, mesh=mesh,
        page_size=config.page_size, num_pages=config.num_pages,
        prefix_reuse=config.prefix_reuse, max_queue=config.max_queue,
        straggler=straggler,
    )
    layout = (f"paged ({engine.pool.num_pages} pages x {engine.page_size} "
              f"positions, prefix reuse "
              f"{'on' if engine.prefix_index is not None else 'off'})"
              if engine.paged
              else f"{config.slots} slots x {max_len} positions")
    print(f"kv cache: {'int8' if engine.kv_bits == 8 else 'fp'} "
          f"({engine.pool.bytes_per_slot() / 1e3:.1f} kB/slot, {layout})")
    if config.lint:
        from ..analysis.lint import lint_engine

        recipe_name = qm.recipe.name if qm is not None else "fp32"
        t0 = time.time()
        findings = lint_engine(engine, recipe_name)
        n_err = sum(f.severity == "error" for f in findings)
        print(f"--lint: {'FAIL' if n_err else 'pass'} "
              f"({time.time() - t0:.1f} s; warn-only at runtime — serving "
              f"continues)")
    if config.warmup:
        t0 = time.time()
        engine.warmup()
        print(f"warmup: compiled serving shapes in {time.time() - t0:.1f} s")

    # SIGTERM → graceful drain: stop admitting, finish in-flight + parked,
    # report, exit 0 (modeled on runtime.fault_tolerance.FaultTolerantLoop).
    t0 = time.time()
    sigterm: list = []   # the async path drains on normal close too, so the
    #                      report needs to know whether SIGTERM actually fired
    if config.serve_async:
        import asyncio

        from ..serving import (
            SLO,
            AsyncClient,
            AsyncServer,
            CircuitBreaker,
            RetryPolicy,
            ShedPolicy,
            run_open_loop,
            summarize,
        )

        sp = config.shed_pressure
        server = AsyncServer(
            engine,
            breaker=CircuitBreaker(cooldown=config.breaker_cooldown),
            shed=ShedPolicy(shed_pressure=sp,
                            tighten_pressure=min(1.0, 1.5 * sp),
                            refuse_pressure=min(1.0, 2.0 * sp)),
        )
        client = AsyncClient(
            server, RetryPolicy(max_attempts=config.retry_attempts),
            seed=config.trace_seed)
        prev_handler = signal.signal(
            signal.SIGTERM,
            lambda *_: (sigterm.append(1), server.drain()))
        try:
            outcomes = asyncio.run(run_open_loop(
                server, client, requests, timeout=config.timeout))
        finally:
            signal.signal(signal.SIGTERM, prev_handler)
        dt = time.time() - t0
        slo = SLO()
        summary = summarize(outcomes, slo=slo)
        print(f"async front-end: offered {summary['offered_qps']:.3f} "
              f"req/tick, goodput {summary['goodput_qps']:.3f} req/tick "
              f"({summary['goodput_fraction']:.0%} of offered; SLO: ttft <= "
              f"{slo.ttft:g}, per-token <= {slo.per_token:g} ticks)")
        print(f"  ttft p50/p99 {summary['ttft_p50']:.1f}/"
              f"{summary['ttft_p99']:.1f} ticks, per-token p50/p99 "
              f"{summary['per_token_p50']:.2f}/"
              f"{summary['per_token_p99']:.2f} ticks, "
              f"mean attempts {summary['mean_attempts']:.2f}")
        srv = server.stats
        print("  admission: " + ", ".join(
            f"{k}={srv[k]}" for k in
            ("submitted", "accepted", "shed_breaker", "shed_priority",
             "shed_refused", "shed_queue", "deadlines_tightened"))
            + f"; breaker opens={server.breaker.opens}")
        results = engine.results
    else:
        prev_handler = signal.signal(
            signal.SIGTERM,
            lambda *_: (sigterm.append(1), engine.request_drain()))
        try:
            shed = []
            for r in requests:
                try:
                    engine.submit(r)
                except QueueFull:
                    shed.append(r.rid)
            results = engine.run()
        finally:
            signal.signal(signal.SIGTERM, prev_handler)
        dt = time.time() - t0
    if sigterm:
        print(f"drain: SIGTERM received — admission stopped, "
              f"{engine.scheduler.pending()} queued requests unserved")
    gen = engine.stats["generated_tokens"]
    path = "reference (stepwise)" if config.reference else \
        f"fast (decode horizon {config.decode_horizon})"
    if mesh is not None:
        path += f", sharded {'x'.join(str(mesh.shape[a]) for a in mesh.axis_names)}"
    print(f"served {len(results)} requests / {gen} generated tokens "
          f"in {dt*1e3:.1f} ms ({gen / max(dt, 1e-9):.1f} tok/s, "
          f"{path} path)")
    print(f"engine: {engine.stats['decode_steps']} decode steps in "
          f"{engine.stats['decode_dispatches']} dispatches, "
          f"{engine.stats['prefill_chunks']} prefill chunks in "
          f"{engine.stats['prefill_dispatches']} dispatches, "
          f"{engine.syncs_per_token():.2f} host syncs/token, "
          f"mean slot occupancy {engine.mean_occupancy():.2f}")
    faults = {k: engine.stats[k] for k in
              ("shed", "preempted", "resumed", "cancelled", "expired",
               "quarantined", "straggler_steps")}
    by_status: dict[str, int] = {}
    for res in results.values():
        by_status[res.status] = by_status.get(res.status, 0) + 1
    if any(faults.values()) or set(by_status) - {"ok"}:
        print("faults: " + ", ".join(f"{k}={v}" for k, v in faults.items())
              + f" (straggler threshold "
                f"{engine.stats['straggler_threshold']:g}x step EMA)")
        print("results by status: " +
              ", ".join(f"{k}={v}" for k, v in sorted(by_status.items())))
    if not results:
        return results
    first = results[min(results)]
    print(f"sample token ids (rid {first.rid}):", first.tokens[:12])
    return results


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    try:
        return serve(ServeConfig.from_args(args))
    except ServeConfigError as e:
        ap.error(str(e))


if __name__ == "__main__":
    main()
