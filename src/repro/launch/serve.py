"""Serving driver: quantize a model through the pipeline API and serve
batched requests through the prefill + decode path (INT8 weights via the
QTensor kernel dispatch).

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --quantize w8a16
    python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --recipe serve-w8a8 --verbose --save /tmp/qwen_int8
    python -m repro.launch.serve --load /tmp/qwen_int8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data import calibration_tokens
from ..models import build_model
from ..pipeline import QuantizedModel, quantize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", choices=["none", "w8a16", "w8a8"], default="w8a16")
    ap.add_argument("--recipe", default=None,
                    help="pipeline recipe name (overrides --quantize)")
    ap.add_argument("--save", default=None, metavar="DIR",
                    help="persist the QuantizedModel after quantization")
    ap.add_argument("--load", default=None, metavar="DIR",
                    help="serve a saved QuantizedModel (skips quantization)")
    ap.add_argument("--verbose", action="store_true",
                    help="print per-site weight SQNR diagnostics")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    if args.load:
        if args.recipe or args.save or args.smoke or args.quantize != "w8a16":
            print("warning: --load serves the saved artifact as-is; "
                  "--arch/--smoke/--recipe/--quantize/--save are ignored")
        qm = QuantizedModel.load(args.load)
        cfg, model, params = qm.cfg, qm.model, qm.params
        print(f"loaded QuantizedModel from {args.load} "
              f"(arch {cfg.name}, recipe {qm.recipe.name!r})")
    else:
        cfg = get_config(args.arch, smoke=args.smoke)
        model = build_model(cfg)
        qm = None
        if args.recipe or args.quantize != "none":
            recipe = args.recipe or f"serve-{args.quantize}"
            qm = quantize(model, recipe=recipe)
            params = qm.params
        else:
            params = model.init(jax.random.PRNGKey(0))

    if qm is not None:
        s = qm.serving_summary()
        print(f"quantized (recipe {qm.recipe.name!r}): "
              f"{s['int8_bytes'] / 1e6:.1f} MB "
              f"vs fp32 {s['fp32_bytes'] / 1e6:.1f} MB "
              f"({s['compression']:.2f}x)")
        if args.verbose:
            from ..pipeline.cli import print_site_sqnr

            print_site_sqnr(qm)
        if args.save:
            qm.save(args.save)
            print(f"saved QuantizedModel to {args.save}")

    B = args.batch
    total = args.prompt_len + args.gen_len
    prompts = calibration_tokens(0, B, args.prompt_len, cfg.vocab_size)
    cache = model.init_cache(B, total, dtype=jnp.float32)
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.enc_seq, cfg.d_model))
        cache = model.warm_cache(params, frames, cache)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen_len - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jnp.concatenate(generated, 1).block_until_ready()
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, 1)
    print(f"prefill: {B}×{args.prompt_len} tokens in {t_prefill*1e3:.1f} ms")
    print(f"decode: {B}×{args.gen_len} tokens in {t_decode*1e3:.1f} ms "
          f"({B*(args.gen_len-1)/max(t_decode,1e-9):.1f} tok/s)")
    print("sample token ids:", out[0, :12].tolist())
    return out


if __name__ == "__main__":
    main()
