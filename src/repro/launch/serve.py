"""Serving driver: DFQ-quantize a model and serve batched requests through
the prefill + decode path (INT8 weights via the QTensor kernel dispatch).

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --quantize w8a16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..core import DFQConfig, apply_dfq
from ..data import calibration_tokens
from ..models import build_model
from ..quantized import quantize_for_serving, serving_summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", choices=["none", "w8a16", "w8a8"], default="w8a16")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = model.dfq_plan()

    if args.quantize != "none":
        params = apply_dfq(params, plan, DFQConfig())     # CLE + absorption
        params = quantize_for_serving(params, plan, mode=args.quantize)
        s = serving_summary(params)
        print(f"quantized ({args.quantize}): {s['int8_bytes']/1e6:.1f} MB "
              f"vs fp32 {s['fp32_bytes']/1e6:.1f} MB "
              f"({s['compression']:.2f}x)")

    B = args.batch
    total = args.prompt_len + args.gen_len
    prompts = calibration_tokens(0, B, args.prompt_len, cfg.vocab_size)
    cache = model.init_cache(B, total, dtype=jnp.float32)
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.enc_seq, cfg.d_model))
        cache = model.warm_cache(params, frames, cache)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen_len - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jnp.concatenate(generated, 1).block_until_ready()
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, 1)
    print(f"prefill: {B}×{args.prompt_len} tokens in {t_prefill*1e3:.1f} ms")
    print(f"decode: {B}×{args.gen_len} tokens in {t_decode*1e3:.1f} ms "
          f"({B*(args.gen_len-1)/max(t_decode,1e-9):.1f} tok/s)")
    print("sample token ids:", out[0, :12].tolist())
    return out


if __name__ == "__main__":
    main()
