"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """Single pod: 16×16 = 256 chips ("data", "model"). Multi-pod adds a
    leading "pod" axis (2 pods = 512 chips): DP spans pod×data; TP stays
    pod-local so model collectives never cross the inter-pod DCI.

    ``shape`` overrides the chip grid: a 2-tuple builds ("data", "model"),
    a 3-tuple ("pod", "data", "model") — the same helper builds the 1×8
    virtual-device CPU test mesh (``--mesh 1x8`` under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and the
    production pod, so axis names never drift between the two."""
    if shape is not None:
        shape = tuple(int(s) for s in shape)
        if len(shape) not in (2, 3) or any(s < 1 for s in shape):
            raise ValueError(
                f"mesh shape must be 2 (data, model) or 3 (pod, data, model) "
                f"positive ints, got {shape!r}"
            )
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (TypeError, AttributeError):  # older jax without axis_types/AxisType
        return jax.make_mesh(shape, axes)
