"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips ("data", "model"). Multi-pod adds a
    leading "pod" axis (2 pods = 512 chips): DP spans pod×data; TP stays
    pod-local so model collectives never cross the inter-pod DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except TypeError:  # older jax without axis_types kwarg
        return jax.make_mesh(shape, axes)
