import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we jit the real program (train_step / prefill_step /
decode_step) with planner shardings, ``.lower().compile()`` it against
ShapeDtypeStruct inputs (no allocation), and record:

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
  * ``compiled.cost_analysis()``    — per-device FLOPs / bytes for §Roofline,
  * collective bytes parsed from the optimized per-device HLO,
  * the derived roofline terms (repro.analysis.roofline).

Results cache to results/dryrun/<cell>.json — reruns skip green cells, so the
full 40-cell × 2-mesh sweep is resumable on this 1-core container.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..analysis.roofline import (
    HW_V5E,
    collective_bytes_from_hlo,
    model_flops,
    roofline_report,
)
from ..configs import get_config, list_archs
from ..models import SHAPES, SHAPE_BY_NAME, build_model, shape_applicable
from ..models.model import input_specs
from .mesh import make_production_mesh
from .steps import make_decode_step, make_prefill_step, make_train_step, shardings_for

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_dict(ma):
    return {
        k: int(getattr(ma, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes")
        if hasattr(ma, k)
    }


def _lower_compile(cfg, shape, mesh, chunk_kv, donate=True, quantized=False):
    """Lower + compile the real program for one cell. Returns (compiled,
    lower_s, compile_s). ``quantized`` swaps the weight sites for int8
    QTensors (the W8A16 serving path) before lowering."""
    from .steps import configure_sharding_hints

    sh = shardings_for(cfg, shape, mesh)
    if quantized:
        from ..quantized import quantize_shapes
        from ..sharding import named_shardings, params_pspecs

        plan = build_model(cfg).dfq_plan()
        qshape = quantize_shapes(sh["params_shape"], plan)
        heads = {"n_q": cfg.n_heads, "n_kv": cfg.n_kv_heads}
        sh["params_shape"] = qshape
        sh["params"] = named_shardings(
            params_pspecs(qshape, mesh, heads,
                          mode="decode" if shape.kind == "decode" else "train"),
            mesh)
    configure_sharding_hints(cfg, mesh)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            model, train_step = make_train_step(cfg, chunk_kv=chunk_kv)
            in_sh = (sh["params"], sh["opt"], {
                "tokens": sh["batch"], "labels": sh["batch"],
                **({"frames": sh["frames"]} if cfg.is_encdec else {}),
            })
            specs = input_specs(cfg, shape)
            batch_spec = {"tokens": specs["tokens"], "labels": specs["labels"]}
            if cfg.is_encdec:
                batch_spec["frames"] = specs["frames"]
            jitted = jax.jit(
                train_step,
                in_shardings=in_sh,
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(sh["params_shape"], sh["opt_shape"], batch_spec)
        elif shape.kind == "prefill":
            model, prefill_step = make_prefill_step(cfg, shape, chunk_kv=chunk_kv)
            specs = input_specs(cfg, shape)
            args = [sh["params_shape"], specs["tokens"]]
            in_sh = [sh["params"], sh["batch"]]
            if cfg.is_encdec:
                args.append(specs["frames"])
                in_sh.append(sh["frames"])
            lowered = jax.jit(prefill_step, in_shardings=tuple(in_sh)).lower(*args)
        else:  # decode
            model, decode_step = make_decode_step(cfg)
            specs = input_specs(cfg, shape)
            jitted = jax.jit(
                decode_step,
                in_shardings=(sh["params"], sh["cache"], sh["batch"]),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(sh["params_shape"], sh["cache_shape"],
                                   specs["token"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    from .steps import clear_sharding_hints

    clear_sharding_hints()
    return compiled, t_lower, t_compile


def _probe_layers(cfg):
    if cfg.family == "hybrid":
        return cfg.hybrid_attn_every, 2 * cfg.hybrid_attn_every
    return 1, 2


def _probe_cfg(cfg, L, shape):
    """Reduced-depth probe with every inner scan disabled, so XLA's
    cost_analysis (which counts while bodies ONCE) is exact; the full-depth
    numbers come from linear extrapolation over L."""
    kw = dict(n_layers=L, logit_chunk=shape.seq_len, unroll_layers=True)
    if cfg.is_encdec:
        kw["n_enc_layers"] = L
    return dataclasses.replace(cfg, **kw)


def _probe_costs(cfg, shape, mesh, chunk_kv, quantized=False):
    """Per-device (flops, bytes, collective_bytes) extrapolated to full depth
    from two shallow probes: X(L) is linear in L for scan-stacked layers.
    Probes run the SAME chunked program, python-unrolled (unroll_layers) so
    XLA's once-per-while-body cost counting becomes exact."""
    L1, L2 = _probe_layers(cfg)
    L_full = cfg.n_layers
    # probes chunk at seq/8 with matched q-chunks: ≤ 8×8 unrolled attention
    # blocks per layer (vs 1000+ at production chunk sizes), while the causal
    # block skipping is exercised at the SAME granularity as the real program
    probe_ckv = max(2048, shape.seq_len // 8)
    vals = []
    for L in (L1, L2):
        compiled, _, _ = _lower_compile(_probe_cfg(cfg, L, shape), shape, mesh,
                                        chunk_kv=probe_ckv, donate=False,
                                        quantized=quantized)
        ca = compiled.cost_analysis() or {}
        coll = collective_bytes_from_hlo(compiled.as_text())
        vals.append((float(ca.get("flops", 0.0)),
                     float(ca.get("bytes accessed", 0.0)),
                     float(coll["total"]), coll))
    slope = [(vals[1][i] - vals[0][i]) / (L2 - L1) for i in range(3)]
    full = [vals[0][i] + slope[i] * (L_full - L1) for i in range(3)]
    return {"flops": full[0], "bytes": full[1], "collective_bytes": full[2],
            "per_layer": {"flops": slope[0], "bytes": slope[1],
                          "collective_bytes": slope[2]},
            "probe_collective_detail": vals[1][3]}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             chunk_kv: int | None = 2048, donate: bool = True,
             with_probes: bool = True, quantized: bool = False,
             kv8: bool = False) -> dict:
    cfg = get_config(arch)
    if kv8:
        cfg = dataclasses.replace(cfg, kv_cache_bits=8)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}
    if quantized and shape.kind != "decode":
        return {"status": "skipped", "reason": "W8A16 variant is decode-only"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    compiled, t_lower, t_compile = _lower_compile(cfg, shape, mesh, chunk_kv,
                                                  donate=donate,
                                                  quantized=quantized)

    ma = compiled.memory_analysis()
    print(f"  memory_analysis: {ma}")
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    if with_probes and not multi_pod:
        costs = _probe_costs(cfg, shape, mesh, chunk_kv, quantized=quantized)
    else:
        ca = compiled.cost_analysis() or {}
        costs = {"flops": float(ca.get("flops", 0.0)),
                 "bytes": float(ca.get("bytes accessed", 0.0)),
                 "collective_bytes": float(coll["total"]),
                 "per_layer": None, "probe_collective_detail": None}
    print(f"  cost (extrapolated): flops={costs['flops']:.3e} "
          f"bytes={costs['bytes']:.3e} coll={costs['collective_bytes']:.3e}")

    terms = roofline_report(
        per_device_flops=costs["flops"],
        per_device_bytes=costs["bytes"],
        per_device_collective_bytes=costs["collective_bytes"],
        chips=chips,
        cfg=cfg,
        shape=shape,
        quantized=quantized,
    )
    mem = _mem_dict(ma)
    hbm_used = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
    result = {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "memory": mem,
        "hbm_used_per_device": hbm_used,
        "fits_hbm": bool(hbm_used < HW_V5E["hbm_per_chip"]),
        "cost": {"flops": costs["flops"], "bytes": costs["bytes"],
                 "collective_bytes": costs["collective_bytes"],
                 "per_layer": costs["per_layer"]},
        "collectives_main_hlo": {k: (v if isinstance(v, dict) else int(v))
                                 for k, v in coll.items()},
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "memory_analytic_s": terms.memory_analytic_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "bound_time_s": terms.bound_time_s,
            "model_flops": terms.model_flops,
            "hlo_flops_global": terms.flops_global,
            "useful_flops_ratio": terms.useful_flops_ratio,
            "roofline_fraction": terms.roofline_fraction,
        },
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
        "hlo_len": len(hlo),
    }
    return result


def cell_path(arch, shape_name, multi_pod, tag=""):
    mesh = "multi" if multi_pod else "single"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh}{tag}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for perf-iteration variants")
    ap.add_argument("--chunk-kv", type=int, default=2048)
    ap.add_argument("--quantized", action="store_true",
                    help="W8A16 QTensor weights (decode cells)")
    ap.add_argument("--kv8", action="store_true", help="int8 KV cache")
    args = ap.parse_args()
    if args.quantized and not args.tag:
        args.tag = "_w8a16" + ("_kv8" if args.kv8 else "")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                path = cell_path(arch, shape_name, multi, args.tag)
                if os.path.exists(path) and not args.force:
                    prev = json.load(open(path))
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} × {shape_name} × "
                              f"{'multi' if multi else 'single'}: {prev['status']}")
                        n_ok += prev["status"] == "ok"
                        n_skip += prev["status"] == "skipped"
                        continue
                print(f"[run] {arch} × {shape_name} × "
                      f"{'multi' if multi else 'single'} ...", flush=True)
                try:
                    result = run_cell(arch, shape_name, multi,
                                      chunk_kv=args.chunk_kv,
                                      quantized=args.quantized,
                                      kv8=args.kv8)
                except Exception as e:  # noqa: BLE001
                    result = {"status": "error", "error": repr(e),
                              "traceback": traceback.format_exc()[-4000:]}
                    n_fail += 1
                    print(f"  ERROR: {e}")
                else:
                    if result["status"] == "ok":
                        n_ok += 1
                        r = result["roofline"]
                        print(f"  ok: dominant={r['dominant']} "
                              f"bound={r['bound_time_s']:.4f}s "
                              f"useful={r['useful_flops_ratio']:.2f} "
                              f"compile={result['timings']['compile_s']:.0f}s")
                    else:
                        n_skip += 1
                        print(f"  skipped: {result['reason']}")
                with open(path, "w") as f:
                    json.dump(result, f, indent=1)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
