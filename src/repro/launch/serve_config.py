"""Typed serving configuration: ONE source of truth for the serve surface.

``ServeConfig`` replaces the 33 loose ``add_argument`` flags that used to
live in ``launch/serve.py``: every knob is a typed dataclass field whose
metadata carries its CLI face (flag, help, choices), so ``build_parser()``
derives the argparse parser FROM the dataclass and the two can never drift.
The same object is the public API:

    import repro
    results = repro.serve(repro.ServeConfig(arch="qwen2-0.5b", smoke=True,
                                            quantize="w8a8", trace=20))

Invalid values raise ``ServeConfigError`` (the CLI maps it to
``parser.error``; the API surfaces it as-is).

CLI-vs-artifact precedence is ONE rule (``with_artifact``), generalizing
what used to be an ad-hoc ``--kv-bits``-vs-``--load`` check: CLI > artifact
> default, except fields the artifact already *is* ("baked": arch / smoke /
quantize / recipe — a differing CLI value is reported as ignored) and
fields the calibration is bound to ("must-match": kv_bits — a differing
CLI value raises, naming both sides).
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Optional, Tuple


class ServeConfigError(ValueError):
    """Invalid or conflicting serving configuration."""


def parse_mesh(spec) -> Optional[Tuple[int, ...]]:
    """"2x4" -> (2, 4); accepts an already-parsed tuple or None."""
    if spec is None or isinstance(spec, tuple):
        return spec
    try:
        shape = tuple(int(s) for s in str(spec).lower().split("x"))
    except ValueError:
        shape = ()
    if len(shape) not in (2, 3) or any(s < 1 for s in shape):
        raise ServeConfigError(
            f"--mesh wants DxM (or PxDxM), e.g. 2x4; got {spec!r}")
    return shape


def _f(default, help=None, **cli):
    """A ServeConfig field plus its argparse face, declared once."""
    return dataclasses.field(default=default, metadata={"help": help, **cli})


@dataclasses.dataclass
class ServeConfig:
    # ------------------------------------------------------ model / artifact
    arch: str = _f("qwen2-0.5b", "architecture id (see configs.registry)")
    smoke: bool = _f(False, "use the arch's smoke-sized config", switch=True)
    quantize: str = _f("w8a16", "weight/activation scheme (none = fp32)",
                       choices=["none", "w8a16", "w8a8"])
    recipe: Optional[str] = _f(
        None, "pipeline recipe name (overrides --quantize)")
    kv_bits: Optional[int] = _f(
        None,
        "KV-cache precision: 8 = int8 payload + per-token/per-head scales "
        "(~4x fewer cache bytes/slot, decode attends through the "
        "kv_attention kernel), 16 = fp. Default: what the recipe/artifact "
        "recorded (--quantize w8a16 --kv-bits 8 selects the serve-w8a16-kv8 "
        "recipe)", type=int, choices=[8, 16], artifact_name="kv_cache_bits")
    mesh: Optional[Tuple[int, ...]] = _f(
        None,
        "serve sharded over a device mesh, e.g. 2x4 = (\"data\": 2, "
        "\"model\": 4) — slots shard over data, weights TP over model (a "
        "P x D x M form adds the leading \"pod\" axis). Needs D*M devices: "
        "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count=N. "
        "Default: the mesh recorded in a --load artifact, else "
        "single-device", metavar="DxM", parse=parse_mesh)
    save: Optional[str] = _f(
        None, "persist the QuantizedModel after quantization (with --mesh: "
        "the serve-mode partition specs are recorded in the artifact)",
        metavar="DIR")
    load: Optional[str] = _f(
        None, "serve a saved QuantizedModel (skips quantization)",
        metavar="DIR")
    verbose: bool = _f(False, "print per-site weight SQNR diagnostics",
                       switch=True)
    # ------------------------------------------------------------- workload
    batch: int = _f(4, "without --trace: number of uniform requests",
                    type=int)
    prompt_len: int = _f(32, None, type=int)
    gen_len: int = _f(32, None, type=int)
    # --------------------------------------------------------------- engine
    slots: int = _f(4, "engine cache-pool size (decode batch width)",
                    type=int)
    max_len: Optional[int] = _f(
        None, "per-slot KV capacity (default: fits prompt+gen)", type=int)
    prefill_chunk: int = _f(16, None, type=int)
    page_size: Optional[int] = _f(
        None, "switch the KV pool to the paged layout: fixed PG-position "
        "pages + per-slot page tables, with refcounted copy-on-write "
        "shared-prefix reuse (requests sharing a prompt prefix share its "
        "pages physically). Tokens are bit-identical to the contiguous "
        "pool. Default: contiguous", type=int, metavar="PG")
    num_pages: Optional[int] = _f(
        None, "page-pool size (with --page-size); default gives every slot "
        "a full ring — smaller pools admit by page demand and lean on "
        "prefix sharing", type=int)
    prefix_reuse: bool = _f(
        True, "with --page-size: disable the scheduler's prefix index "
        "(pages without sharing)", flag="--no-prefix-reuse", invert=True)
    decode_horizon: int = _f(
        8, "max decode steps fused into one device dispatch (the engine "
        "adapts the actual horizon to budgets and scheduled arrivals)",
        type=int)
    reference: bool = _f(
        False, "use the stepwise fast=False reference path (one dispatch + "
        "one host sync per token) instead of the device-resident fast path",
        switch=True)
    warmup: bool = _f(
        False, "pre-compile all pow2 prefill/horizon shapes before serving "
        "(excluded from the timed run)", switch=True)
    # -------------------------------------------------------- trace / async
    trace: int = _f(
        0, "replay a synthetic arrival schedule of N requests (mixed "
        "log-uniform lengths, Poisson arrivals)", type=int, metavar="N")
    trace_seed: int = _f(0, None, type=int)
    max_queue: Optional[int] = _f(
        None, "bound the admission queue: submissions beyond Q shed with "
        "the retryable QueueFull error (back-pressure). Default: unbounded",
        type=int, metavar="Q")
    serve_async: bool = _f(
        False, "serve the --trace through the overload-safe async front-end "
        "(serving.AsyncServer): per-request token streaming, client retry "
        "with backoff + jitter on the retryable taxonomy, circuit breaker, "
        "and priority-aware load shedding; reports the SLO view (TTFT / "
        "per-token percentiles, goodput)", switch=True)
    qps: float = _f(
        0.5, "with --serve-async: offered Poisson arrival rate in requests "
        "per engine tick (open loop)", type=float, metavar="R")
    timeout: Optional[float] = _f(
        None, "with --serve-async: per-request client timeout in engine "
        "ticks, enforced as the engine deadline (tighter of this and "
        "--deadline wins)", type=float, metavar="T")
    retry_attempts: int = _f(
        4, "with --serve-async: max submission attempts per request "
        "(retryable rejections back off with exponential backoff + full "
        "jitter)", type=int)
    breaker_cooldown: float = _f(
        16.0, "with --serve-async: circuit-breaker cooldown in engine ticks "
        "before a half-open probe", type=float)
    shed_pressure: float = _f(
        0.5, "with --serve-async: queue pressure (depth/bound) at which the "
        "lowest priority class is shed; deadlines tighten at 1.5x this "
        "value and all requests are refused at 2x (capped at 1.0)",
        type=float)
    straggler_threshold: Optional[float] = _f(
        None, "flag an engine step as a straggler when its wall time "
        "exceeds X times the EMA of recent steps (surfaced as "
        "stats['straggler_threshold'] and in the final report). Default: "
        "the monitor's 2.0", type=float, metavar="X")
    deadline: Optional[float] = _f(
        None, "give every request a deadline of T engine ticks after its "
        "arrival; expired requests are shed (queued) or cut short (in "
        "flight) at the next step boundary and report status 'expired'",
        type=float, metavar="T")
    lint: bool = _f(
        False, "run the QuantLint graph linter over this engine's compiled "
        "serve paths before serving (warn-only here; `python -m "
        "repro.analysis.lint --check` is the blocking CI gate)", switch=True)

    # ------------------------------------------------------------- plumbing
    @property
    def mesh_str(self) -> Optional[str]:
        return None if self.mesh is None else "x".join(map(str, self.mesh))

    @classmethod
    def from_args(cls, ns: argparse.Namespace) -> "ServeConfig":
        """Build from a parsed ``build_parser()`` namespace."""
        kw = {}
        for f in dataclasses.fields(cls):
            v = getattr(ns, f.name)
            parse = f.metadata.get("parse")
            kw[f.name] = parse(v) if parse is not None else v
        return cls(**kw)

    @classmethod
    def from_artifact(cls, source) -> "ServeConfig":
        """The ServeConfig a saved artifact recorded (what it was quantized
        AS): pass a ``QuantizedModel`` or an artifact directory. Merge with
        the CLI/API config via ``with_artifact``."""
        qm = source
        if isinstance(source, str):
            from ..pipeline import QuantizedModel

            qm = QuantizedModel.load(source)
        name = qm.recipe.name
        quant = ("w8a8" if "w8a8" in name
                 else "w8a16" if "w8a16" in name else "none")
        mesh = (tuple(qm.sharding["mesh_shape"])
                if qm.shard_mode and qm.sharding.get("mesh_shape") else None)
        return cls(arch=qm.cfg.name, quantize=quant, recipe=name,
                   kv_bits=qm.cfg.kv_cache_bits, mesh=mesh)

    def with_artifact(self, art: "ServeConfig"):
        """Merge this (CLI/API) config with an artifact's recorded one under
        the single precedence rule — see ``_ARTIFACT_POLICY``. Returns
        ``(merged, notes)``; a "must-match" conflict raises
        ``ServeConfigError`` naming both sides."""
        merged, notes = {}, []
        for name, policy in _ARTIFACT_POLICY.items():
            cli, rec = getattr(self, name), getattr(art, name)
            flag = _flag(name)
            explicit = cli != _DEFAULTS[name]
            if policy == "cli":
                if explicit:
                    merged[name] = cli
                    if rec is not None and rec != cli:
                        notes.append(
                            f"{flag} {_fmt(cli)} overrides the "
                            f"artifact-recorded {_fmt(rec)}")
                else:
                    merged[name] = rec if rec is not None else cli
            elif policy == "baked":
                merged[name] = rec
                if explicit and cli != rec:
                    notes.append(
                        f"{flag} {_fmt(cli)} ignored: the artifact is "
                        f"served as saved ({name}={_fmt(rec)})")
            else:  # must-match: the calibration saw exactly one value
                if explicit and rec is not None and cli != rec:
                    art_name = _ARTIFACT_NAMES.get(name, name)
                    raise ServeConfigError(
                        f"{flag} {_fmt(cli)} conflicts with the --load "
                        f"artifact: it recorded {art_name}={_fmt(rec)} "
                        f"(recipe {art.recipe!r}). Either drop {flag} to "
                        f"serve as recorded, or re-quantize the model for "
                        f"{art_name}={_fmt(cli)}")
                merged[name] = rec if rec is not None else cli
        return dataclasses.replace(self, **merged), notes

    def validate(self) -> "ServeConfig":
        """Check flag-combination invariants BEFORE any quantization runs:
        a typo must not discard minutes of pipeline work."""
        if self.quantize not in ("none", "w8a16", "w8a8"):
            raise ServeConfigError(
                f"quantize must be none/w8a16/w8a8, got {self.quantize!r}")
        if self.kv_bits not in (None, 8, 16):
            raise ServeConfigError(f"kv_bits must be 8 or 16, "
                                   f"got {self.kv_bits!r}")
        if self.num_pages is not None and self.page_size is None:
            raise ServeConfigError("--num-pages needs --page-size")
        if self.max_queue is not None and self.max_queue < 1:
            raise ServeConfigError("--max-queue must be >= 1")
        if self.deadline is not None and self.deadline <= 0:
            raise ServeConfigError("--deadline must be > 0 engine ticks")
        if not self.prefix_reuse and self.page_size is None:
            raise ServeConfigError("--no-prefix-reuse needs --page-size")
        if self.serve_async and not self.trace:
            raise ServeConfigError(
                "--serve-async needs --trace N (open-loop arrivals)")
        if self.serve_async and self.qps <= 0:
            raise ServeConfigError("--qps must be > 0 requests/tick")
        if self.serve_async and self.retry_attempts < 1:
            raise ServeConfigError("--retry-attempts must be >= 1")
        if not 0.0 < self.shed_pressure <= 1.0:
            raise ServeConfigError("--shed-pressure must be in (0, 1]")
        if (self.straggler_threshold is not None
                and self.straggler_threshold <= 1):
            raise ServeConfigError(
                "--straggler-threshold must be > 1 (a slowdown multiplier)")
        if self.trace and (self.prompt_len < 1 or self.gen_len < 1):
            raise ServeConfigError("--trace needs --prompt-len/--gen-len >= 1")
        if self.mesh is not None:
            self.mesh = parse_mesh(self.mesh)     # tolerate a "2x4" string
            import jax
            import numpy as np

            need = int(np.prod(self.mesh))
            if need > jax.device_count():
                raise ServeConfigError(
                    f"--mesh {self.mesh_str} needs {need} devices but jax "
                    f"sees {jax.device_count()}; on CPU set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={need}")
        return self


# The ONE CLI-vs-artifact precedence rule (per artifact-coupled field):
#   "cli"        — serving can honor either; an explicit CLI value wins
#                  over the recorded one (mesh: re-deploy on a new topology).
#   "baked"      — the saved weights already ARE this value; the artifact
#                  wins and a differing CLI value is reported as ignored.
#   "must-match" — the calibration is bound to the recorded value; a
#                  differing CLI value raises, naming both sides.
_ARTIFACT_POLICY = {
    "mesh": "cli",
    "arch": "baked",
    "smoke": "baked",
    "quantize": "baked",
    "recipe": "baked",
    "kv_bits": "must-match",
}

_DEFAULTS = {f.name: f.default for f in dataclasses.fields(ServeConfig)}
_ARTIFACT_NAMES = {f.name: f.metadata["artifact_name"]
                   for f in dataclasses.fields(ServeConfig)
                   if "artifact_name" in f.metadata}


def _flag(name: str) -> str:
    for f in dataclasses.fields(ServeConfig):
        if f.name == name and "flag" in f.metadata:
            return f.metadata["flag"]
    return "--" + name.replace("_", "-")


def _fmt(v) -> str:
    if isinstance(v, tuple):
        return "x".join(map(str, v))
    return str(v)


def build_parser() -> argparse.ArgumentParser:
    """Derive the ``python -m repro.launch.serve`` argparse surface from the
    ServeConfig fields — the dataclass IS the flag list."""
    ap = argparse.ArgumentParser(
        description="quantize (or --load) a model and serve it with the "
                    "continuous-batching engine")
    for f in dataclasses.fields(ServeConfig):
        md = dict(f.metadata)
        help_ = md.pop("help", None)
        flag = md.pop("flag", "--" + f.name.replace("_", "-"))
        md.pop("parse", None)
        md.pop("artifact_name", None)
        if md.pop("invert", False):
            ap.add_argument(flag, dest=f.name, action="store_false",
                            default=f.default, help=help_)
        elif md.pop("switch", False):
            ap.add_argument(flag, dest=f.name, action="store_true",
                            default=f.default, help=help_)
        else:
            ap.add_argument(flag, dest=f.name, default=f.default,
                            help=help_, **md)
    return ap
