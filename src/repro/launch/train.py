"""Production training driver: mesh → sharded train_step → fault-tolerant
loop (checkpoint/restore, preemption, stragglers) → metrics.

On this CPU container it runs reduced configs end-to-end (the same code path
the dry-run proves out at 512 devices):

    python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 100
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import get_config
from ..data import TokenStream
from ..optim import adamw_init
from ..runtime import FaultTolerantLoop, StragglerMonitor
from ..sharding import named_shardings, params_pspecs
from .steps import configure_sharding_hints, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="results/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    model, train_step = make_train_step(cfg, lr_cfg={
        "peak_lr": 1e-3, "warmup": 20, "total": args.steps})
    configure_sharding_hints(cfg, mesh)

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    heads = {"n_q": cfg.n_heads, "n_kv": cfg.n_kv_heads}
    p_sh = named_shardings(params_pspecs(params, mesh, heads), mesh)
    params = jax.device_put(params, p_sh)

    stream = TokenStream(seed=0, shard=0, n_shards=1,
                         batch_per_shard=args.batch, seq=args.seq,
                         vocab=cfg.vocab_size)

    with mesh:
        jitted = jax.jit(train_step, donate_argnums=(0, 1))

        def step_fn(state, batch):
            params, opt = state
            params, opt, metrics = jitted(params, opt, batch)
            return (params, opt), {"loss": float(metrics["loss"])}

        ckpt = Checkpointer(args.ckpt_dir, keep=2)
        mon = StragglerMonitor(threshold=3.0)
        loop = FaultTolerantLoop(step_fn, lambda s: stream.batch(s), ckpt,
                                 ckpt_every=args.ckpt_every, straggler=mon)
        state = (params, opt)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            state, start = ckpt.restore(state)
            print(f"resumed from step {start}")

        t0 = time.time()
        losses = []
        orig_step = loop.step_fn

        def logging_step(state, batch):
            state, m = orig_step(state, batch)
            losses.append(m["loss"])
            n = len(losses) + start
            if n % args.log_every == 0:
                print(f"step {n}: loss {np.mean(losses[-args.log_every:]):.4f} "
                      f"({(time.time() - t0) / len(losses):.2f}s/step)",
                      flush=True)
            return state, m

        loop.step_fn = logging_step
        state, end = loop.run(state, start, args.steps - start)

    print(f"done at step {end}; loss {np.mean(losses[-10:]):.4f} "
          f"(start {np.mean(losses[:10]):.4f}); "
          f"straggler events: {loop.metrics.straggler_events}; "
          f"retries: {loop.metrics.retries}")
    return state


if __name__ == "__main__":
    main()
