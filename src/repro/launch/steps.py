"""Step builders: sharded train / prefill / decode programs for any arch.

These are the programs the dry-run lowers and the drivers execute. All
shardings come from the divisibility-aware planner (repro.sharding); the
functions themselves are mesh-agnostic pure JAX.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import ModelConfig, ShapeConfig, build_model
from ..models.model import input_specs
from ..optim import adamw_init, adamw_update, cosine_schedule
from ..sharding import batch_pspec, cache_pspecs, named_shardings, params_pspecs


def configure_sharding_hints(cfg: ModelConfig, mesh: Mesh):
    """Arm the in-model sharding constraints (models.layers._SHARD_CTX) for
    tracing under ``mesh``: head-parallel attention when the head count
    divides the model axis, context(sequence)-parallel otherwise."""
    from ..models.layers import set_shard_ctx

    model_n = mesh.shape.get("model", 1)
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if cfg.n_heads == 0:
        set_shard_ctx(enabled=True, dp=dp, model="model", attn_seq=False,
                      mesh=mesh)
        return
    set_shard_ctx(
        enabled=True,
        dp=dp,
        model="model",
        attn_seq=(cfg.n_heads % model_n != 0),
        kv_heads_ok=(cfg.n_kv_heads % model_n == 0),
        mesh=mesh,
    )


def clear_sharding_hints():
    from ..models.layers import set_shard_ctx

    set_shard_ctx(enabled=False)


def state_specs(model, mesh: Mesh):
    """(params, opt) ShapeDtypeStructs + NamedShardings without allocation."""
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    heads = {"n_q": model.cfg.n_heads, "n_kv": model.cfg.n_kv_heads}
    p_spec = params_pspecs(params_shape, mesh, heads)
    o_spec = {
        "step": P(),
        "m": params_pspecs(params_shape, mesh),
        "v": params_pspecs(params_shape, mesh),
    }
    return (params_shape, opt_shape), (p_spec, o_spec)


def _opt_spec_tree(opt_shape, p_spec):
    from ..optim.adamw import AdamWState

    return AdamWState(P(), p_spec, p_spec)


def make_train_step(cfg: ModelConfig, *, lr_cfg: Optional[dict] = None,
                    chunk_kv: Optional[int] = None):
    """(params, opt, batch) → (params, opt, metrics)."""
    model = build_model(cfg)
    lr_cfg = lr_cfg or {"peak_lr": 3e-4, "warmup": 100, "total": 10000}

    def train_step(params, opt, batch):
        loss_fn = lambda p: model.loss(p, batch, chunk_kv=chunk_kv)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = cosine_schedule(opt.step, **lr_cfg)
        params, opt, gnorm = adamw_update(grads, opt, params, lr=lr)
        return params, opt, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return model, train_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                      chunk_kv: Optional[int] = None):
    """tokens (+frames) → (logits of last position, fresh filled cache)."""
    model = build_model(cfg)

    def prefill_step(params, tokens, frames=None):
        cache = model.init_cache(tokens.shape[0], shape.seq_len, jnp.bfloat16)
        if cfg.is_encdec:
            cache = model.warm_cache(params, frames, cache)
        logits, cache = model.prefill(params, tokens, cache, chunk_kv=chunk_kv)
        return logits, cache

    return model, prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, cache, token) → (logits, cache). Cache donated."""
    model = build_model(cfg)

    def decode_step(params, cache, token):
        return model.decode_step(params, token, cache)

    return model, decode_step


def shardings_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """All NamedShardings for one (arch × shape) cell."""
    model = build_model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    heads = {"n_q": cfg.n_heads, "n_kv": cfg.n_kv_heads}
    # decode serves with RESIDENT weights: TP-only sharding, no FSDP — a
    # per-token FSDP all-gather of fp32 weight shards cost 205 MB/layer on
    # yi-34b decode_32k (EXPERIMENTS §Perf iteration C3)
    p_spec = params_pspecs(params_shape, mesh, heads,
                           mode="decode" if shape.kind == "decode" else "train")
    out = {
        "params_shape": params_shape,
        "params": named_shardings(p_spec, mesh),
        "batch": NamedSharding(mesh, batch_pspec(mesh, batch=shape.global_batch)),
    }
    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        o_spec = _opt_spec_tree(opt_shape, p_spec)
        out["opt_shape"] = opt_shape
        out["opt"] = named_shardings(o_spec, mesh)
    if shape.kind in ("decode",):
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
        )
        c_spec = cache_pspecs(cache_shape, mesh, shape.global_batch)
        out["cache_shape"] = cache_shape
        out["cache"] = named_shardings(c_spec, mesh)
    if cfg.is_encdec and shape.kind in ("train", "prefill"):
        out["frames"] = NamedSharding(
            mesh, batch_pspec(mesh, ndim=3, batch=shape.global_batch))
    return out
