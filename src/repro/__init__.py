"""repro: production-grade JAX framework reproducing
"Data-Free Quantization Through Weight Equalization and Bias Correction"
(Nagel et al., ICCV 2019) and extending it to modern LM architectures on TPU.
"""

__version__ = "1.0.0"
