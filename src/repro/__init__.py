"""repro: production-grade JAX framework reproducing
"Data-Free Quantization Through Weight Equalization and Bias Correction"
(Nagel et al., ICCV 2019) and extending it to modern LM architectures on TPU.

The public surface is the pipeline API plus its serving peer:

    import repro
    qm = repro.quantize("qwen2-0.5b-smoke", recipe="dfq-int8")
    repro.serve(repro.ServeConfig(arch="qwen2-0.5b", smoke=True, trace=20))
"""

__version__ = "1.1.0"


def __getattr__(name):
    # Lazy: `import repro` stays cheap; the pipeline (and jax) load on first
    # use of the public API.
    _exports = {
        "quantize", "QuantizedModel", "Recipe", "RecipeStep", "register_stage",
        "list_stages", "list_recipes", "resolve_recipe", "PipelineError",
        "RecipeError", "default_calibration",
    }
    if name in _exports:
        from . import pipeline

        return getattr(pipeline, name)
    if name in {"serve", "ServeConfig", "ServeConfigError"}:
        from .launch import serve as _serve

        return getattr(_serve, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
