from .synthetic import (  # noqa: F401
    calibration_tokens,
    synthetic_image_batch,
    token_batch,
    TokenStream,
)
