"""Deterministic synthetic data pipeline.

Everything is a pure function of (seed, step, shard) — reproducible across
restarts and elastic re-sharding (a shard's stream depends only on its global
shard index, not on world size), which the fault-tolerance tests rely on.

Tokens follow a Zipfian marginal with short-range Markov structure so models
have something learnable; images are class-conditional frequency patterns.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _fold(seed: int, *salts: int):
    key = jax.random.PRNGKey(seed)
    for s in salts:
        key = jax.random.fold_in(key, s)
    return key


def token_batch(seed: int, step: int, shard: int, batch: int, seq: int,
                vocab: int) -> dict:
    """One shard's {tokens, labels} for a step. Zipf marginal + repetition
    structure (every 2nd token repeats with p≈0.5 → learnable bigrams)."""
    key = _fold(seed, step, shard)
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.uniform(k1, (batch, seq + 1), minval=1e-6, maxval=1.0)
    zipf = jnp.clip((u ** (-1.0 / 1.1) - 1.0).astype(jnp.int32), 0, vocab - 1)
    rep = jax.random.bernoulli(k2, 0.5, (batch, seq + 1))
    toks = jnp.where(rep & (jnp.arange(seq + 1) % 2 == 1),
                     jnp.roll(zipf, 1, axis=1), zipf)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def calibration_tokens(seed: int, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Data-free calibration inputs for empirical bias correction (paper
    appendix D with a synthetic source — uniform random ids)."""
    return jax.random.randint(_fold(seed, 777), (batch, seq), 0, vocab)


def synthetic_image_batch(seed: int, step: int, batch: int, size: int,
                          channels: int, classes: int) -> dict:
    """Class-conditional 2-D frequency gratings + noise: a CNN reaches high
    accuracy in a few hundred CPU steps, giving the paper's Tables a real
    accuracy metric to move."""
    key = _fold(seed, step)
    k1, k2, k3 = jax.random.split(key, 3)
    y = jax.random.randint(k1, (batch,), 0, classes)
    xx, yy = jnp.meshgrid(jnp.arange(size), jnp.arange(size))
    freq = (y[:, None, None] + 1).astype(jnp.float32) * 0.5
    phase = jax.random.uniform(k3, (batch, 1, 1)) * 2 * jnp.pi
    base = jnp.sin(freq * xx[None] * 2 * jnp.pi / size + phase) * jnp.cos(
        freq * yy[None] * 2 * jnp.pi / size
    )
    x = base[..., None] + 0.3 * jax.random.normal(k2, (batch, size, size, channels))
    return {"x": x.astype(jnp.float32), "y": y}


@dataclasses.dataclass
class TokenStream:
    """Stateless per-shard stream facade used by the train driver."""

    seed: int
    shard: int
    n_shards: int
    batch_per_shard: int
    seq: int
    vocab: int

    def batch(self, step: int) -> dict:
        return token_batch(self.seed, step, self.shard, self.batch_per_shard,
                           self.seq, self.vocab)
