"""Fault-tolerant checkpointer: atomic, async, mesh-independent.

Layout per step:
    <dir>/step_<N>.tmp-<pid>/   (write)  →  atomic rename →  <dir>/step_<N>/
        manifest.json           pytree structure + shapes/dtypes
        arr_<i>.npy             one file per leaf (host np arrays)

Properties the runtime relies on:
  * **atomicity** — a crash mid-write leaves only a .tmp dir, which restore
    ignores and cleanup removes; a visible step_N dir is always complete,
  * **async** — save() snapshots leaves to host then writes on a worker
    thread; training continues (wait() joins before the next save),
  * **mesh independence** — leaves are stored unsharded; restore device_puts
    onto ANY target sharding, so an elastic restart on a different mesh/world
    size is just restore(new_shardings) (runtime/elastic.py).
  * **retention** — keep the most recent K checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._cleanup_tmp()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        spec = {
            "step": step,
            # restore() rebuilds structure from its target_tree, so only the
            # leaf inventory is persisted (proto treedefs reject NamedTuples)
            "n_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
        }
        # structure is also stored as a path skeleton for proto-less restore
        skeleton = jax.tree.map(lambda _: 0, tree)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp-{os.getpid()}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            for i, a in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(spec, f)
            with open(os.path.join(tmp, "skeleton.json"), "w") as f:
                json.dump(_skeleton_to_json(skeleton), f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and ".tmp" not in d
            and os.path.exists(os.path.join(self.dir, d, "manifest.json"))
        ]
        return max(steps) if steps else None

    def restore(
        self,
        target_tree: Any,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> tuple[Any, int]:
        """Restore into the structure of ``target_tree``. ``shardings`` (a
        matching pytree of jax.sharding.Sharding or None) re-shards each leaf
        onto the CURRENT mesh — the elastic-restart path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        leaves, treedef = _flatten(target_tree)
        with open(os.path.join(d, "manifest.json")) as f:
            spec = json.load(f)
        assert spec["n_leaves"] == len(leaves), (
            f"checkpoint has {spec['n_leaves']} leaves, target {len(leaves)}"
        )
        loaded = [np.load(os.path.join(d, f"arr_{i}.npy")) for i in range(len(leaves))]
        for a, ref in zip(loaded, leaves):
            assert tuple(a.shape) == tuple(ref.shape), (a.shape, ref.shape)
        if shardings is not None:
            shard_leaves = jax.tree.flatten(shardings)[0]
            loaded = [
                jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                for a, s in zip(loaded, shard_leaves)
            ]
        else:
            loaded = [jax.numpy.asarray(a) for a in loaded]
        return jax.tree.unflatten(treedef, loaded), step

    def restore_skeleton(self, step: Optional[int] = None) -> tuple[Any, int]:
        """Structure-less restore: rebuild the pytree from the persisted path
        skeleton — no ``target_tree`` needed. Only valid for checkpoints whose
        structure is plain dicts/lists of arrays (the skeleton.json format);
        custom pytree nodes must be encoded to dicts before save (see
        ``pipeline.artifact``)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "skeleton.json")) as f:
            skeleton = json.load(f)
        with open(os.path.join(d, "manifest.json")) as f:
            spec = json.load(f)
        leaves, treedef = _flatten(skeleton)
        assert spec["n_leaves"] == len(leaves), (
            f"checkpoint has {spec['n_leaves']} leaves, skeleton {len(leaves)}"
        )
        loaded = [
            jax.numpy.asarray(np.load(os.path.join(d, f"arr_{i}.npy")))
            for i in range(len(leaves))
        ]
        return jax.tree.unflatten(treedef, loaded), step

    # --------------------------------------------------------------- hygiene
    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and ".tmp" not in d
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    def _cleanup_tmp(self) -> None:
        for d in os.listdir(self.dir):
            if ".tmp" in d:
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)


def _skeleton_to_json(tree):
    if isinstance(tree, dict):
        return {k: _skeleton_to_json(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_skeleton_to_json(v) for v in tree]
    return 0
