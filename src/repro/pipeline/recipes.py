"""Declarative recipes: named stage sequences with per-stage options.

A recipe is data, not code — swapping the weight stage for a Hessian-based
one (SQuant) or inserting an activation-clipping stage (AACAB) is a new
``Recipe`` over the same runner. Built-ins cover the paper's Fig. 4 flow and
the serving deployments.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Any, Iterable, Mapping, Sequence, Union

from .registry import get_stage, list_stages
from .state import RecipeError


@dataclasses.dataclass(frozen=True)
class RecipeStep:
    stage: str
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Recipe:
    name: str
    steps: tuple[RecipeStep, ...]
    description: str = ""

    def validate(self) -> None:
        """Fail fast with an actionable error before any compute runs."""
        if not self.steps:
            raise RecipeError(f"recipe {self.name!r} has no stages")
        problems = []
        for i, step in enumerate(self.steps):
            if not isinstance(step, RecipeStep):
                problems.append(f"step {i} is {type(step).__name__}, not RecipeStep")
                continue
            try:
                stage = get_stage(step.stage)
            except RecipeError as e:
                problems.append(f"step {i}: {e}")
                continue
            if not isinstance(step.options, Mapping):
                problems.append(
                    f"step {i} ({step.stage!r}): options must be a mapping, "
                    f"got {type(step.options).__name__}"
                )
                continue
            unknown = set(step.options) - stage.allowed_options
            if unknown:
                problems.append(
                    f"step {i} ({step.stage!r}): unknown option(s) "
                    f"{sorted(unknown)}; allowed: "
                    f"{sorted(stage.allowed_options) or '(none)'}"
                )
        if problems:
            raise RecipeError(
                f"recipe {self.name!r} failed validation:\n  - "
                + "\n  - ".join(problems)
            )

    def with_options(self, overrides: Mapping[str, Mapping[str, Any]]) -> "Recipe":
        """Merge per-stage option overrides ({stage_name: {opt: val}})."""
        names = {s.stage for s in self.steps}
        unknown = set(overrides) - names
        if unknown:
            raise RecipeError(
                f"recipe {self.name!r} has no stage(s) {sorted(unknown)} to "
                f"override; stages: {sorted(names)}"
            )
        steps = tuple(
            RecipeStep(s.stage, {**dict(s.options), **dict(overrides.get(s.stage, {}))})
            for s in self.steps
        )
        return dataclasses.replace(self, steps=steps)

    def stage_names(self) -> list[str]:
        return [s.stage for s in self.steps]


def _r(name: str, description: str, *steps) -> Recipe:
    return Recipe(
        name,
        tuple(RecipeStep(s, {}) if isinstance(s, str) else RecipeStep(*s) for s in steps),
        description,
    )


BUILTIN_RECIPES: dict[str, Recipe] = {
    r.name: r
    for r in (
        _r(
            "dfq-int8",
            "The paper's Fig. 4 flow: fold → CLE → absorb → bias-correct → "
            "fake-quant INT8 (near-FP32 simulated inference)",
            "fold_norm", "cle", "bias_absorb",
            ("bias_correct", {"method": "empirical"}),
            "weight_quant",
        ),
        _r(
            "naive-int8",
            "Per-tensor INT8 round-to-nearest, no DFQ — the collapse baseline",
            "weight_quant",
        ),
        _r(
            "cle-only",
            "Equalization ablation: fold → CLE → fake-quant (no absorption, "
            "no bias correction)",
            "fold_norm", "cle", "weight_quant",
        ),
        _r(
            "serve-w8a16",
            "Deployment: fold → CLE → absorb → pack int8 weights "
            "(dequant-in-kernel matmul)",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a16"}),
        ),
        _r(
            "serve-w8a8",
            "Deployment: fold → CLE → absorb → pack int8 weights with dynamic "
            "int8 activations (MXU int8 matmul)",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a8"}),
        ),
        _r(
            "serve-w8a16-kv8",
            "serve-w8a16 plus an int8 KV cache (per-token/per-head scales; "
            "decode attends through the kv_attention kernel)",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a16"}),
            ("kv_cache", {"bits": 8}),
        ),
        _r(
            "serve-w8a8-kv8",
            "serve-w8a8 plus an int8 KV cache — the full int8 serving stack "
            "(weights, activations, KV stream)",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a8"}),
            ("kv_cache", {"bits": 8}),
        ),
        # every serve-* deployment has a -tp twin (same stages + shard[tp])
        # so --mesh never has to drop the topology from a saved artifact
        _r(
            "serve-w8a16-tp",
            "serve-w8a16 deployed tensor-parallel: int8 weights + scales "
            "co-sharded over the mesh's \"model\" axis, KV pool sharded "
            "slot-wise over \"data\"",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a16"}),
            ("shard", {"mode": "tp"}),
        ),
        _r(
            "serve-w8a8-tp",
            "serve-w8a8 deployed tensor-parallel across a device mesh",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a8"}),
            ("shard", {"mode": "tp"}),
        ),
        _r(
            "serve-w8a16-kv8-tp",
            "serve-w8a16-kv8 deployed tensor-parallel across a device mesh",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a16"}),
            ("kv_cache", {"bits": 8}), ("shard", {"mode": "tp"}),
        ),
        _r(
            "serve-w8a8-kv8-tp",
            "the full int8 serving stack (weights, activations, KV stream) "
            "deployed tensor-parallel across a device mesh",
            "fold_norm", "cle", "bias_absorb", ("pack", {"mode": "w8a8"}),
            ("kv_cache", {"bits": 8}), ("shard", {"mode": "tp"}),
        ),
    )
}


RecipeLike = Union[str, Recipe, Sequence]


def resolve_recipe(spec: RecipeLike) -> Recipe:
    """str → built-in; Recipe → itself; sequence of stage names /
    (name, options) pairs / RecipeSteps → anonymous recipe."""
    if isinstance(spec, Recipe):
        return spec
    if isinstance(spec, str):
        try:
            return BUILTIN_RECIPES[spec]
        except KeyError:
            hint = difflib.get_close_matches(spec, BUILTIN_RECIPES, n=1)
            suggest = f" — did you mean {hint[0]!r}?" if hint else ""
            raise RecipeError(
                f"unknown recipe {spec!r}{suggest} Built-ins: "
                f"{', '.join(sorted(BUILTIN_RECIPES))}. A custom recipe is a "
                "Recipe instance or a list of stage names from: "
                f"{', '.join(list_stages())}"
            ) from None
    if isinstance(spec, Iterable):
        steps = []
        for s in spec:
            if isinstance(s, RecipeStep):
                steps.append(s)
            elif isinstance(s, str):
                steps.append(RecipeStep(s, {}))
            elif isinstance(s, (tuple, list)) and len(s) == 2:
                steps.append(RecipeStep(s[0], dict(s[1])))
            else:
                raise RecipeError(
                    f"cannot interpret recipe step {s!r}; use a stage name, "
                    "a (name, options) pair, or a RecipeStep"
                )
        return Recipe("custom", tuple(steps), "ad-hoc recipe")
    raise RecipeError(
        f"cannot interpret recipe spec of type {type(spec).__name__}; "
        "pass a built-in name, a Recipe, or a list of stages"
    )


def list_recipes() -> list[str]:
    return sorted(BUILTIN_RECIPES)


def split_recipe_flags(name: str) -> tuple:
    """``"serve-w8a8-kv8-tp+paged"`` → ``("serve-w8a8-kv8-tp", ("paged",))``.

    Recipe *flags* (``+flag`` suffixes) select a serving-engine geometry
    variant — they are NOT pipeline stages, so the base name is what
    ``resolve_recipe`` sees. Known flags: ``paged`` (page-table KV pool).
    Unknown flags raise RecipeError so a typo can't silently lint the
    contiguous geometry under a paged contract stem."""
    base, _, rest = name.partition("+")
    flags = tuple(f for f in rest.split("+") if f) if rest else ()
    for f in flags:
        if f != "paged":
            raise RecipeError(
                f"unknown recipe flag {f!r} in {name!r} (known: 'paged')"
            )
    return base, flags


def lint_mesh_shape(recipe_name: str):
    """The mesh shape the graph linter checks a recipe under: the CI
    reference topology (2 data x 4 model — the tier1-multidevice job's 8
    virtual devices) for ``-tp`` recipes, single-device otherwise.
    Recipe flags (``+paged``) don't change the topology."""
    base, _ = split_recipe_flags(recipe_name)
    return (2, 4) if base.endswith("-tp") else None


def contract_stem(recipe_name: str, mesh_shape=None) -> str:
    """Filename stem of a recipe's lint contract:
    ``<recipe>`` single-device, ``<recipe>.<DxM>`` under a mesh — so the
    same recipe can pin contracts for several topologies side by side.
    Recipe flags come AFTER the mesh suffix (``serve-w8a8-kv8-tp.2x4+paged``)
    so a recipe's contract family sorts together."""
    base, flags = split_recipe_flags(recipe_name)
    resolve_recipe(base)  # fail fast (with did-you-mean) on typos
    stem = base
    if mesh_shape:
        stem = f"{base}.{'x'.join(str(int(s)) for s in mesh_shape)}"
    return stem + "".join(f"+{f}" for f in flags)
