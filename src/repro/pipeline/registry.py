"""Stage registry: named, pluggable pipeline transforms.

Built-in stages (``stages.py``) wrap the core DFQ transforms; external code
registers new ones — a Hessian weight stage (SQuant-style) or an
activation-clipping stage (AACAB-style) drops in without touching the
runner:

    @register_stage("my_stage", strength=1.0)
    def my_stage(state, ctx, *, strength):
        state.params = ...
        state.note(strength=strength)
        return state

Declared keyword defaults double as the stage's option schema: a recipe
passing an undeclared option fails validation with an actionable error.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Any, Callable, Mapping

from .state import PipelineError, RecipeError

_STAGES: dict[str, "Stage"] = {}


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    fn: Callable
    defaults: Mapping[str, Any]
    doc: str = ""

    @property
    def allowed_options(self) -> frozenset:
        return frozenset(self.defaults)

    def run(self, state, ctx, options: Mapping[str, Any]):
        unknown = set(options) - self.allowed_options
        if unknown:
            raise RecipeError(
                f"stage {self.name!r} got unknown option(s) {sorted(unknown)}; "
                f"allowed: {sorted(self.allowed_options) or '(none)'}"
            )
        merged = {**self.defaults, **options}
        return self.fn(state, ctx, **merged)


def register_stage(name: str, **defaults):
    """Decorator: register ``fn(state, ctx, **options)`` under ``name``.

    ``defaults`` declares every option the stage accepts, with its default.
    """

    def deco(fn):
        if name in _STAGES:
            raise PipelineError(
                f"stage {name!r} is already registered "
                f"(by {_STAGES[name].fn.__module__}.{_STAGES[name].fn.__qualname__}); "
                "unregister_stage() first to replace it"
            )
        _STAGES[name] = Stage(name, fn, dict(defaults), doc=(fn.__doc__ or "").strip())
        return fn

    return deco


def unregister_stage(name: str) -> None:
    _STAGES.pop(name, None)


def get_stage(name: str) -> Stage:
    try:
        return _STAGES[name]
    except KeyError:
        hint = difflib.get_close_matches(name, _STAGES, n=1)
        suggest = f" — did you mean {hint[0]!r}?" if hint else ""
        raise RecipeError(
            f"unknown stage {name!r}{suggest} "
            f"Registered stages: {', '.join(sorted(_STAGES))}"
        ) from None


def list_stages() -> list[str]:
    return sorted(_STAGES)
