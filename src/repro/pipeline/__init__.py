"""Unified quantization pipeline: stage registry + recipes + QuantizedModel.

Public surface:

    repro.quantize(arch_or_model, params=None, recipe="dfq-int8", ...)
        → QuantizedModel (deployable: .apply/.prefill/.decode_step,
          .serving_summary(), .save/.load, per-stage .report)

    Recipe / resolve_recipe / list_recipes — declarative stage sequences
    register_stage / list_stages — pluggable stage registry
    python -m repro.pipeline.cli — command-line front-end
"""

from .state import (  # noqa: F401
    PipelineContext,
    PipelineError,
    PipelineState,
    RecipeError,
    StageRecord,
)
from .registry import (  # noqa: F401
    Stage,
    get_stage,
    list_stages,
    register_stage,
    unregister_stage,
)
from . import stages  # noqa: F401  (registers the built-in stages)
from .recipes import (  # noqa: F401
    BUILTIN_RECIPES,
    Recipe,
    RecipeStep,
    list_recipes,
    resolve_recipe,
)
from .artifact import QuantizedModel  # noqa: F401
from .api import (  # noqa: F401
    default_calibration,
    quantize,
    run_recipe,
)
