"""The one-call quantization API the paper promises (§1).

    import repro
    qm = repro.quantize("qwen2-0.5b-smoke", recipe="dfq-int8")
    logits, _ = qm.apply(tokens)

``quantize`` resolves the architecture, runs the recipe's stages over a
``PipelineState``, and returns a deployable ``QuantizedModel``. The default
calibration hook is the synthetic-token one every caller used to hand-roll
(data-free: random token ids, frames for enc-dec), built once here.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Mapping, Optional, Union

import jax

from ..core.dfq import DFQConfig
from ..models.config import ModelConfig
from .artifact import QuantizedModel
from .recipes import Recipe, RecipeStep, resolve_recipe
from .registry import get_stage
from .state import PipelineContext, PipelineError, PipelineState


# weight_quant stage option → DFQConfig field. The quant spec must be ONE
# truth for the whole recipe: bias_correct computes ε = fq(W) − W from the
# config's spec, so a quantizer choice that stayed stage-local would make
# the correction target a quantizer that never runs.
_WEIGHT_SPEC_OPTS = {
    "bits": "weight_bits",
    "per_channel": "per_channel",
    "symmetric": "weight_symmetric",
}


def _fold_weight_spec_overrides(recipe: Recipe, config: DFQConfig) -> DFQConfig:
    import dataclasses

    repl = {}
    for step in recipe.steps:
        if step.stage == "weight_quant":
            for opt, field in _WEIGHT_SPEC_OPTS.items():
                if step.options.get(opt) is not None:
                    repl[field] = step.options[opt]
        elif step.stage == "pack":
            # quantize_param is symmetric int8 absmax (per-channel optional);
            # mirror that into the config spec so a bias_correct in the same
            # recipe computes ε against the quantizer that actually ships.
            repl["weight_bits"] = 8
            repl["weight_symmetric"] = True
            repl["per_channel"] = bool(step.options.get("per_channel", False))
    return dataclasses.replace(config, **repl) if repl else config


def run_recipe(recipe: Recipe, state: PipelineState, ctx: PipelineContext) -> PipelineState:
    """Validate then execute a recipe's stages, timing each into the report."""
    recipe.validate()
    state.config = _fold_weight_spec_overrides(recipe, state.config)
    from .state import StageRecord

    for step in recipe.steps:
        stage = get_stage(step.stage)
        t0 = time.perf_counter()
        state = stage.run(state, ctx, step.options)
        if not isinstance(state, PipelineState):
            raise PipelineError(
                f"stage {step.stage!r} returned {type(state).__name__}, "
                "not PipelineState — stages must return the (updated) state"
            )
        state.records.append(
            StageRecord(
                stage=step.stage,
                options=dict(step.options),
                seconds=time.perf_counter() - t0,
                metrics=state.pop_metrics(),
            )
        )
    return state


def default_calibration(
    model, cfg: ModelConfig, *, seed: int = 1, batch: int = 2, seq: int = 32
) -> Callable[[Mapping], Mapping]:
    """The standard data-free calibration hook: synthetic random tokens
    (plus random frames for enc-dec) through ``model.calibration_stats``."""
    from ..data import calibration_tokens

    def calibrate(params):
        toks = calibration_tokens(seed, batch, seq, cfg.vocab_size)
        if cfg.is_encdec:
            frames = jax.random.normal(
                jax.random.PRNGKey(seed), (batch, cfg.enc_seq, cfg.d_model)
            )
            return model.calibration_stats(params, toks, frames)
        return model.calibration_stats(params, toks)

    return calibrate


def _resolve_model(arch_or_model) -> tuple[Any, ModelConfig]:
    from ..models import build_model

    if isinstance(arch_or_model, str):
        from ..configs import get_config

        cfg = get_config(arch_or_model)
        return build_model(cfg), cfg
    if isinstance(arch_or_model, ModelConfig):
        return build_model(arch_or_model), arch_or_model
    cfg = getattr(arch_or_model, "cfg", None)
    if cfg is not None and hasattr(arch_or_model, "dfq_plan"):
        return arch_or_model, cfg
    raise PipelineError(
        f"cannot resolve a model from {type(arch_or_model).__name__}; pass an "
        "arch name (e.g. 'qwen2-0.5b-smoke'), a ModelConfig, or a model "
        "exposing .cfg and .dfq_plan()"
    )


def quantize(
    arch_or_model: Union[str, ModelConfig, Any],
    params: Optional[Mapping] = None,
    recipe: Union[str, Recipe, list] = "dfq-int8",
    *,
    config: Optional[DFQConfig] = None,
    calibration: Union[str, Callable, None] = "auto",
    stage_options: Optional[Mapping[str, Mapping]] = None,
    init_seed: int = 0,
    calib_seed: int = 1,
    calib_batch: int = 2,
    calib_seq: int = 32,
) -> QuantizedModel:
    """Quantize a model with a named (or custom) recipe — the single entry
    point for the whole repo.

    arch_or_model: arch name ("qwen2-0.5b", "-smoke" suffix honored), a
        ModelConfig, or a built model.
    params: existing parameters (e.g. trained); None → ``model.init``.
    recipe: built-in name, a ``Recipe``, or a list of stage names /
        (name, options) pairs.
    config: ``DFQConfig`` defaults for every stage (bits, n-sigma, ...).
    calibration: "auto" → synthetic-token hook (lazy — only invoked by
        stages that need E[x]); a callable ``params -> {stat_key: E[x]}``;
        or None to disable.
    stage_options: per-stage overrides, e.g. {"pack": {"per_channel": True}}.
    """
    model, cfg = _resolve_model(arch_or_model)
    r = resolve_recipe(recipe)
    if stage_options:
        r = r.with_options(stage_options)
    r.validate()

    if params is None:
        params = model.init(jax.random.PRNGKey(init_seed))
    plan = model.dfq_plan()

    if calibration == "auto":
        calibrate = default_calibration(
            model, cfg, seed=calib_seed, batch=calib_batch, seq=calib_seq
        )
    elif calibration is None:
        calibrate = None
    elif callable(calibration):
        calibrate = calibration
    else:
        raise PipelineError(
            f"calibration must be 'auto', a callable, or None; got "
            f"{calibration!r}"
        )

    state = PipelineState(params=params, plan=plan, config=config or DFQConfig())
    ctx = PipelineContext(model=model, cfg=cfg, calibrate=calibrate)
    state = run_recipe(r, state, ctx)
    if state.kv_bits is not None and state.kv_bits != cfg.kv_cache_bits:
        # the kv_cache stage is weight-free: fold the KV precision into the
        # artifact's config (and rebuild the model over it) so init_cache,
        # the serving engine, and save/load all see the recorded precision
        import dataclasses

        from ..models import build_model

        cfg = dataclasses.replace(cfg, kv_cache_bits=state.kv_bits)
        model = build_model(cfg)
    return QuantizedModel(
        model=model, cfg=cfg, params=state.params, recipe=r,
        report=state.report, act_qparams=state.act_qparams,
        sharding={"mode": state.shard_mode} if state.shard_mode else {},
    )


def run_legacy_dfq(params, plan, config: DFQConfig, input_means_fn) -> dict:
    """Backend of ``repro.core.dfq_quantize``: the "dfq-int8" recipe with the
    config's stage toggles applied, returning bare fake-quantized params."""
    steps = [RecipeStep("fold_norm", {})]
    if config.cle:
        steps.append(RecipeStep("cle", {}))
    if config.bias_absorb:
        steps.append(RecipeStep("bias_absorb", {}))
    if config.bias_correct != "none" and input_means_fn is not None:
        steps.append(RecipeStep("bias_correct", {"method": "empirical"}))
    steps.append(RecipeStep("weight_quant", {}))
    recipe = Recipe("dfq-int8/legacy", tuple(steps), "dfq_quantize compatibility")
    state = run_recipe(
        recipe,
        PipelineState(params=params, plan=plan, config=config),
        PipelineContext(calibrate=input_means_fn),
    )
    return state.params
