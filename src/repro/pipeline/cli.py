"""Command-line front-end for the quantization pipeline.

    python -m repro.pipeline.cli --arch qwen2-0.5b --smoke --recipe dfq-int8
    python -m repro.pipeline.cli --list-recipes
    python -m repro.pipeline.cli --arch qwen2-0.5b --smoke \
        --recipe serve-w8a16 --save /tmp/qwen_int8 --verbose
"""
from __future__ import annotations

import argparse


def _print_recipes():
    from .recipes import BUILTIN_RECIPES

    for name in sorted(BUILTIN_RECIPES):
        r = BUILTIN_RECIPES[name]
        print(f"{name:14s} {' → '.join(r.stage_names())}")
        print(f"{'':14s}   {r.description}")


def _print_stages():
    from .registry import _STAGES, list_stages

    for name in list_stages():
        s = _STAGES[name]
        opts = ", ".join(f"{k}={v!r}" for k, v in s.defaults.items()) or "-"
        head = (s.doc or "").splitlines()[0] if s.doc else ""
        print(f"{name:14s} options: {opts}")
        print(f"{'':14s}   {head}")


def print_site_sqnr(qm):
    """Per-site weight SQNR table (shared by this CLI and launch/serve)."""
    snr = qm.site_sqnr_db()
    if not snr:
        return
    print("per-site weight SQNR (dB):")
    for site, db in sorted(snr.items(), key=lambda kv: kv[1]):
        print(f"  {site:14s} {db:7.2f}")


def _print_report(qm, verbose: bool):
    for rec in qm.report:
        m = rec["metrics"]
        extras = []
        if "skipped" in m:
            extras.append(f"skipped ({m['skipped']})")
        if "sites" in m:
            extras.append(f"{m['sites']} sites")
        if "pairs" in m:
            extras.append(f"{m['pairs']} pairs x{m.get('iterations', 1)}")
        if "ops" in m:
            extras.append(f"{m['ops']} ops")
        if m.get("sqnr_min_db") is not None:
            extras.append(f"weight SQNR min {m['sqnr_min_db']:.1f} dB")
        if "compression" in m:
            extras.append(
                f"{m['int8_bytes'] / 1e6:.1f} MB ({m['compression']:.2f}x)"
            )
        print(f"  {rec['stage']:14s} {rec['seconds'] * 1e3:8.1f} ms  "
              + ("; ".join(extras)))
    if verbose:
        print_site_sqnr(qm)


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.pipeline.cli",
        description="Quantize an architecture with a pipeline recipe.",
    )
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--recipe", default="dfq-int8")
    ap.add_argument("--save", default=None, metavar="DIR",
                    help="persist the QuantizedModel artifact")
    ap.add_argument("--verbose", action="store_true",
                    help="print per-site weight SQNR diagnostics")
    ap.add_argument("--list-recipes", action="store_true")
    ap.add_argument("--list-stages", action="store_true")
    args = ap.parse_args(argv)

    if args.list_recipes:
        _print_recipes()
        return 0
    if args.list_stages:
        _print_stages()
        return 0

    from .api import quantize

    arch = args.arch + ("-smoke" if args.smoke and not args.arch.endswith("-smoke")
                        else "")
    qm = quantize(arch, recipe=args.recipe)
    print(f"{arch} · recipe {qm.recipe.name!r} "
          f"({' → '.join(qm.recipe.stage_names())})")
    _print_report(qm, args.verbose)
    if args.save:
        qm.save(args.save)
        print(f"saved QuantizedModel to {args.save}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
