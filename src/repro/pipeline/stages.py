"""Built-in pipeline stages wrapping the core DFQ transforms.

Stage order in a recipe follows the paper's Fig. 4: fold_norm → cle →
bias_absorb → bias_correct → weight_quant (fake-quant) or pack (true-int8
serving). ``bias_correct`` runs before weight quantization because the
correction term ε = W̃ − W is computed from the still-FP weights.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core.bias_correction import expected_input_analytic
from ..core.dfq import (
    bias_correct as core_bias_correct,
    quantize_weights as core_quantize_weights,
    run_plan_ops,
    weight_quant_snr,
)
from ..core.graph import (
    DensePairOp,
    HighBiasAbsorbOp,
    NormFoldOp,
    QKPairOp,
    VBiasAbsorbOp,
    VOPairOp,
)
from ..core.quantizer import qparams_from_range, sqnr_db
from ..core.tree import get_path
from .registry import register_stage
from .state import PipelineError

_CLE_KINDS = (DensePairOp, VOPairOp, QKPairOp)
_ABSORB_KINDS = (VBiasAbsorbOp, HighBiasAbsorbOp)


def _count_ops(plan, kinds) -> int:
    return sum(isinstance(op, kinds) for op in plan.ops)


@register_stage("fold_norm")
def fold_norm_stage(state, ctx):
    """Fold norm scale (and LayerNorm shift) into consuming linears."""
    state.params = run_plan_ops(
        state.params, state.plan, state.config, kinds=(NormFoldOp,), iterations=1
    )
    state.note(ops=_count_ops(state.plan, NormFoldOp))
    return state


@register_stage("cle", iterations=None, include_approx_pairs=None)
def cle_stage(state, ctx, *, iterations, include_approx_pairs):
    """Cross-layer equalization over the plan's exact pairs (paper §4.1)."""
    cfg = dataclasses.replace(
        state.config,
        cle=True,
        cle_include_approx_pairs=(
            state.config.cle_include_approx_pairs
            if include_approx_pairs is None
            else include_approx_pairs
        ),
    )
    it = iterations if iterations is not None else cfg.cle_iterations
    state.params = run_plan_ops(
        state.params, state.plan, cfg, kinds=_CLE_KINDS, iterations=it
    )
    state.note(pairs=_count_ops(state.plan, _CLE_KINDS), iterations=int(it))
    return state


@register_stage("bias_absorb")
def bias_absorb_stage(state, ctx):
    """High-bias absorption into the following layer (paper §4.1.3)."""
    cfg = dataclasses.replace(state.config, bias_absorb=True)
    state.params = run_plan_ops(
        state.params, state.plan, cfg, kinds=_ABSORB_KINDS, iterations=1
    )
    state.note(ops=_count_ops(state.plan, _ABSORB_KINDS))
    return state


@register_stage("bias_correct", method="empirical")
def bias_correct_stage(state, ctx, *, method):
    """Quantization-bias correction b ← b − εᵀE[x] (paper §4.2).

    method="empirical": E[x] from the context's calibration hook (synthetic
    tokens — still data-free). method="analytic": closed-form clipped-normal
    route; requires the model to expose ``analytic_input_stats()`` returning
    ``{stat_key: (beta, gamma, activation)}``.
    """
    if method == "none":
        state.note(skipped="method='none'")
        return state
    if method not in ("empirical", "analytic"):
        raise PipelineError(
            f"bias_correct: unknown method {method!r}; "
            "use 'empirical', 'analytic', or 'none'"
        )
    if method == "analytic":
        stats_fn = getattr(ctx.model, "analytic_input_stats", None)
        if stats_fn is None:
            raise PipelineError(
                "bias_correct(method='analytic') needs the model to expose "
                "analytic_input_stats() -> {stat_key: (beta, gamma, activation)} "
                f"but {type(ctx.model).__name__} does not; use "
                "method='empirical' (synthetic-calibration route) instead"
            )
        means = {
            k: expected_input_analytic(beta, gamma, activation)
            for k, (beta, gamma, activation) in stats_fn().items()
        }
    else:
        if ctx.calibrate is None:
            state.note(skipped="no calibration hook available")
            return state
        means = ctx.calibrate(state.params)
    if not means:
        state.note(skipped="calibration returned no statistics")
        return state
    state.input_means = means
    state.params = core_bias_correct(state.params, state.plan, state.config, means)
    corrected = [
        s.name for s in state.plan.sites
        if s.stat_key is not None and s.stat_key in means
    ]
    state.note(method=method, sites_corrected=corrected)
    return state


@register_stage("weight_quant", bits=None, per_channel=None, symmetric=None)
def weight_quant_stage(state, ctx, *, bits, per_channel, symmetric):
    """Fake-quantize every weight site (simulated INT-k inference).

    Records per-site SQNR (dB) of the quantized weights against the
    pre-quantization snapshot — the ``weight_quant_snr`` diagnostics.
    """
    repl = {}
    if bits is not None:
        repl["weight_bits"] = int(bits)
    if per_channel is not None:
        repl["per_channel"] = bool(per_channel)
    if symmetric is not None:
        repl["weight_symmetric"] = bool(symmetric)
    cfg = dataclasses.replace(state.config, **repl) if repl else state.config
    fp = state.params
    state.fp_params = fp
    state.params = core_quantize_weights(fp, state.plan, cfg)
    snr = weight_quant_snr(fp, state.params, state.plan)
    state.note(
        sites=len(state.plan.sites),
        bits=cfg.weight_bits,
        per_channel=cfg.per_channel,
        sqnr_db=snr,
        sqnr_min_db=min(snr.values()) if snr else None,
        sqnr_mean_db=(sum(snr.values()) / len(snr)) if snr else None,
    )
    return state


@register_stage("act_ranges", n_sigma=None)
def act_ranges_stage(state, ctx, *, n_sigma):
    """Data-free activation-range setting (paper §5: range = β ± nγ).

    LM route: the per-channel calibration means stand in for β; the spread
    across channels stands in for γ (documented approximation — the capture
    path records first moments only). Resulting QParams are stored on the
    state / artifact for static-activation backends; the shipped w8a8 kernel
    quantizes activations dynamically and does not consume them.
    """
    ns = float(n_sigma if n_sigma is not None else state.config.act_range_n_sigma)
    means = state.input_means
    if means is None and ctx.calibrate is not None:
        means = ctx.calibrate(state.params)
        state.input_means = means
    if not means:
        state.note(skipped="no calibration statistics available")
        return state
    spec = state.config.act_spec
    ranges = {}
    for key, m in means.items():
        if not hasattr(m, "shape"):
            continue
        v = jnp.asarray(m, jnp.float32).reshape(-1)
        sd = jnp.std(v)
        lo, hi = jnp.min(v) - ns * sd, jnp.max(v) + ns * sd
        state.act_qparams[key] = qparams_from_range(lo, hi, spec)
        ranges[key] = (float(lo), float(hi))
    state.note(n_sigma=ns, keys=sorted(ranges), ranges=ranges)
    return state


@register_stage("kv_cache", bits=8)
def kv_cache_stage(state, ctx, *, bits):
    """Record the serving KV-cache precision on the artifact.

    bits=8 applies the paper's symmetric per-token/per-head quantizer to the
    KV stream: caches built from the resulting QuantizedModel hold int8
    payload + fp32 scales, and decode attends through the int8 kv_attention
    op. A weight-free stage — ``repro.quantize`` folds ``state.kv_bits``
    into the artifact's config so save/load/serve round-trips carry it.
    """
    if bits not in (8, 16):
        raise PipelineError(
            f"kv_cache: bits must be 8 or 16, got {bits!r}"
        )
    state.kv_bits = int(bits)
    state.note(bits=int(bits))
    return state


@register_stage("shard", mode="tp")
def shard_stage(state, ctx, *, mode):
    """Record the serving parallelism plan on the artifact.

    mode="tp": serve the model tensor-parallel — weights placed under the
    serve-mode partition specs (Megatron TP over the mesh's "model" axis,
    int8 QTensor scales co-sharded with their payload columns, no FSDP
    factor) and the pooled KV cache sharded slot-wise over "data". A
    weight-free stage, like ``kv_cache``: the per-layer DFQ metadata (scales,
    corrected biases) shards with its tensor, so no re-quantization is
    needed — ``ServingEngine(mesh=...)`` applies the recorded plan at load.
    """
    if mode not in ("tp", "none"):
        raise PipelineError(
            f"shard: unknown mode {mode!r}; use 'tp' or 'none'"
        )
    state.shard_mode = None if mode == "none" else mode
    state.note(mode=mode)
    return state


@register_stage("pack", mode="w8a16", per_channel=False)
def pack_stage(state, ctx, *, mode, per_channel):
    """Pack weight sites into int8 QTensors for true-int8 serving.

    mode="w8a16": dequant-in-kernel matmul; mode="w8a8": dynamic activation
    quant + int8 MXU. Records the bytes summary and per-site SQNR of the
    packed (dequantized) weights vs their FP source.
    """
    if mode not in ("w8a16", "w8a8"):
        raise PipelineError(
            f"pack: unknown mode {mode!r}; use 'w8a16' or 'w8a8'"
        )
    from ..quantized.ptq import quantize_for_serving, serving_summary

    fp = state.params
    state.fp_params = fp
    packed = quantize_for_serving(
        fp, state.plan, mode=mode, per_channel=bool(per_channel)
    )
    snr = {
        site.name: float(
            sqnr_db(get_path(fp, site.w), get_path(packed, site.w).dequant())
        )
        for site in state.plan.sites
    }
    state.params = packed
    state.packed = True
    state.pack_mode = mode
    state.note(
        mode=mode,
        per_channel=bool(per_channel),
        sites=len(state.plan.sites),
        sqnr_db=snr,
        **serving_summary(packed),
    )
    return state
