"""Pipeline state: the value threaded through every stage.

A stage is a pure-ish function ``(state, ctx, **options) -> state`` over a
``PipelineState`` carrying the params pytree, the architecture's ``DFQPlan``,
the active ``DFQConfig``, and accumulated per-stage diagnostics. The
``PipelineContext`` carries everything stages may need but must not mutate:
the model (for calibration forward passes), its config, and the calibration
hook supplying E[x] per stat key.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

from ..core.dfq import DFQConfig
from ..core.graph import DFQPlan


class PipelineError(Exception):
    """A pipeline misuse with an actionable message."""


class RecipeError(PipelineError):
    """Recipe validation failure: unknown stage, bad option, malformed spec."""


@dataclasses.dataclass
class StageRecord:
    """Diagnostics for one executed stage (what `QuantizedModel.report` holds)."""

    stage: str
    options: dict
    seconds: float
    metrics: dict

    def to_dict(self) -> dict:
        return {
            "stage": self.stage,
            "options": dict(self.options),
            "seconds": float(self.seconds),
            "metrics": self.metrics,
        }


@dataclasses.dataclass
class PipelineContext:
    """Read-only context handed to every stage."""

    model: Any = None
    cfg: Any = None
    # calibrate(params) -> {stat_key: E[x]} — the model-side hook (synthetic
    # tokens keep the flow data-free); None when no calibration is available.
    calibrate: Optional[Callable[[Mapping], Mapping]] = None


@dataclasses.dataclass
class PipelineState:
    params: Any
    plan: DFQPlan
    config: DFQConfig = dataclasses.field(default_factory=DFQConfig)
    fp_params: Any = None          # pre-quantization snapshot (SQNR reference)
    input_means: Optional[Mapping] = None
    act_qparams: dict = dataclasses.field(default_factory=dict)
    packed: bool = False
    pack_mode: Optional[str] = None
    kv_bits: Optional[int] = None  # set by the kv_cache stage (8 → int8 KV)
    shard_mode: Optional[str] = None  # set by the shard stage ("tp")
    records: list = dataclasses.field(default_factory=list)
    _pending_metrics: dict = dataclasses.field(default_factory=dict)

    def note(self, **metrics) -> None:
        """Attach metrics to the currently-running stage's record."""
        self._pending_metrics.update(metrics)

    def pop_metrics(self) -> dict:
        m, self._pending_metrics = self._pending_metrics, {}
        return m

    @property
    def report(self) -> list[dict]:
        return [r.to_dict() for r in self.records]
