"""QuantizedModel: the deployable output of the quantization pipeline.

Bundles model + (possibly int8-packed) params + recipe provenance + the
per-stage diagnostics report, and serves through the same prefill/decode
path as FP32 (QTensor kernel dispatch). Persists via the fault-tolerant
checkpointer — QTensors are encoded to tagged dicts so the on-disk layout is
a plain array pytree restorable without a target structure.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

import jax

from ..models.config import ModelConfig
from .recipes import Recipe, RecipeStep
from .state import PipelineError

_META_FILE = "quantized_model.json"
_QT_PREFIX = "__qtensor_"


def _encode_qtensors(tree):
    """QTensor leaves → tagged plain dicts (mode encoded in the key)."""
    from ..quantized.qtensor import QTensor

    def enc(x):
        if isinstance(x, QTensor):
            return {f"{_QT_PREFIX}{x.mode}__": {"q": x.q, "scale": x.scale}}
        return x

    return jax.tree.map(enc, tree, is_leaf=lambda x: isinstance(x, QTensor))


def _decode_qtensors(tree):
    from ..quantized.qtensor import QTensor

    if isinstance(tree, dict):
        if len(tree) == 1:
            key = next(iter(tree))
            if key.startswith(_QT_PREFIX) and key.endswith("__"):
                mode = key[len(_QT_PREFIX):-2]
                inner = tree[key]
                return QTensor(inner["q"], inner["scale"], mode)
        return {k: _decode_qtensors(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_decode_qtensors(v) for v in tree]
    return tree


@dataclasses.dataclass
class QuantizedModel:
    """model + quantized params + recipe provenance + stage report."""

    model: Any
    cfg: ModelConfig
    params: Any
    recipe: Recipe
    report: list  # list[dict] — StageRecord.to_dict() per executed stage
    # {stat_key: QParams} from the act_ranges stage, for static-activation
    # backends. In-memory only — save() persists the float ranges in the
    # report, not these (the shipped w8a8 path quantizes dynamically).
    act_qparams: dict = dataclasses.field(default_factory=dict)
    # serving parallelism plan from the shard stage: {"mode": "tp"} plus,
    # once save(mesh=...) ran, the concrete mesh shape/axes and the per-leaf
    # serve-mode PartitionSpecs the engine will apply. Round-trips through
    # save/load so a deployment host serves the recorded topology.
    sharding: dict = dataclasses.field(default_factory=dict)

    # ----------------------------------------------------------- inference
    def apply(self, tokens, *args, **kwargs):
        return self.model.apply(self.params, tokens, *args, **kwargs)

    def loss(self, batch, **kwargs):
        return self.model.loss(self.params, batch, **kwargs)

    def init_cache(self, batch: int, seq_len: int, **kwargs):
        return self.model.init_cache(batch, seq_len, **kwargs)

    def warm_cache(self, frames, cache):
        return self.model.warm_cache(self.params, frames, cache)

    def prefill(self, tokens, cache, **kwargs):
        return self.model.prefill(self.params, tokens, cache, **kwargs)

    def decode_step(self, token, cache):
        return self.model.decode_step(self.params, token, cache)

    # --------------------------------------------------------- diagnostics
    def serving_summary(self) -> dict:
        """Bytes accounting: fp vs int8 parameter payload."""
        from ..quantized.ptq import serving_summary

        return serving_summary(self.params)

    def stage_record(self, stage: str) -> Optional[dict]:
        """Last report record for ``stage`` (None if the stage didn't run)."""
        for rec in reversed(self.report):
            if rec["stage"] == stage:
                return rec
        return None

    @property
    def shard_mode(self):
        """"tp" when the recipe carried a shard stage, else None."""
        return self.sharding.get("mode")

    def serve_pspecs(self, mesh) -> Any:
        """Serve-mode PartitionSpec pytree for this artifact's params over
        ``mesh`` (int8 payload + scale co-sharded on "model", no FSDP)."""
        import jax

        from ..sharding import params_pspecs

        shapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.params
        )
        heads = {"n_q": self.cfg.n_heads, "n_kv": self.cfg.n_kv_heads}
        return params_pspecs(shapes, mesh, heads, mode="serve")

    def site_sqnr_db(self) -> dict:
        """Per-site weight SQNR from the quantizing stage (weight_quant/pack)."""
        for name in ("pack", "weight_quant"):
            rec = self.stage_record(name)
            if rec and "sqnr_db" in rec.get("metrics", {}):
                return dict(rec["metrics"]["sqnr_db"])
        return {}

    # --------------------------------------------------------- persistence
    def save(self, directory: str, mesh=None) -> str:
        """Atomic save: array payload via the checkpointer + a JSON sidecar
        with config, recipe provenance, and the stage report. For a sharded
        artifact (shard stage in the recipe), passing the deployment ``mesh``
        additionally records the concrete serve-mode PartitionSpec per param
        leaf — the deployment topology ships WITH the weights."""
        from ..checkpoint.checkpointer import Checkpointer

        ck = Checkpointer(directory, keep=1)
        ck.save(0, _encode_qtensors(self.params), blocking=True)
        sharding = dict(self.sharding)
        if mesh is not None and self.shard_mode:
            from ..sharding.partition import spec_paths

            specs = self.serve_pspecs(mesh)
            sharding.update(
                mesh_shape=[int(mesh.shape[a]) for a in mesh.axis_names],
                mesh_axes=list(mesh.axis_names),
                specs={path: str(spec) for path, spec in spec_paths(specs)},
            )
            self.sharding = sharding
        meta = {
            "format_version": 1,
            "config": dataclasses.asdict(self.cfg),
            "recipe": {
                "name": self.recipe.name,
                "description": self.recipe.description,
                "steps": [
                    {"stage": s.stage, "options": dict(s.options)}
                    for s in self.recipe.steps
                ],
            },
            "sharding": sharding,
            "report": self.report,
        }
        tmp = os.path.join(directory, _META_FILE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=2, default=float)
        os.replace(tmp, os.path.join(directory, _META_FILE))
        return directory

    @classmethod
    def load(cls, directory: str) -> "QuantizedModel":
        from ..checkpoint.checkpointer import Checkpointer
        from ..models import build_model

        meta_path = os.path.join(directory, _META_FILE)
        if not os.path.exists(meta_path):
            raise PipelineError(
                f"{directory!r} is not a QuantizedModel directory "
                f"(missing {_META_FILE}); save one with QuantizedModel.save()"
            )
        with open(meta_path) as f:
            meta = json.load(f)
        cfg = ModelConfig(**meta["config"])
        model = build_model(cfg)
        tree, _ = Checkpointer(directory, keep=1).restore_skeleton(0)
        params = _decode_qtensors(tree)
        recipe = Recipe(
            meta["recipe"]["name"],
            tuple(
                RecipeStep(s["stage"], s["options"])
                for s in meta["recipe"]["steps"]
            ),
            meta["recipe"].get("description", ""),
        )
        return cls(
            model=model, cfg=cfg, params=params, recipe=recipe,
            report=meta.get("report", []),
            sharding=meta.get("sharding", {}),
        )
