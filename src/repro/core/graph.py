"""Equalization-graph descriptors: a declarative, model-agnostic encoding of
where DFQ's rewrites apply inside a parameter pytree.

Each model family (``repro.models.*``) emits a list of these ops from its
config; ``repro.core.dfq`` executes them functionally over the params pytree.
All paths address (possibly scan-stacked ``[L, ...]`` / expert-stacked
``[L, E, ...]``) weights — the core transforms broadcast over leading dims,
so one op equalizes all layers/experts of a kind at once.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .tree import Path


@dataclasses.dataclass(frozen=True)
class NormFoldOp:
    """Fold norm scale (and LayerNorm shift) into consuming linears."""

    norm_w: Path
    consumers: Sequence[Path]            # weight paths, [..., d_in, out]
    norm_b: Optional[Path] = None
    consumer_biases: Optional[Sequence[Optional[Path]]] = None


@dataclasses.dataclass(frozen=True)
class DensePairOp:
    """CLE over a ReLU / gated-MLP pair (exact). w1 [..., d, n], w2 [..., n, d]."""

    w1: Path
    w2: Path
    b1: Optional[Path] = None
    exact: bool = True                   # False → approximate (plain GELU MLP)


@dataclasses.dataclass(frozen=True)
class VOPairOp:
    """CLE value-proj ↔ output-proj through attention (exact, GQA-aware)."""

    wv: Path
    wo: Path
    bv: Optional[Path] = None
    n_q: int = 1
    n_kv: int = 1
    head_dim: int = 1


@dataclasses.dataclass(frozen=True)
class QKPairOp:
    """CLE query ↔ key (exact; RoPE rotation-pair and GQA-group constrained)."""

    wq: Path
    wk: Path
    bq: Optional[Path] = None
    bk: Optional[Path] = None
    n_q: int = 1
    n_kv: int = 1
    head_dim: int = 1
    rope: bool = True


@dataclasses.dataclass(frozen=True)
class VBiasAbsorbOp:
    """Absorb the value bias fully into the output-projection bias (exact)."""

    bv: Path
    wo: Path
    bo: Path
    n_q: int = 1
    n_kv: int = 1
    head_dim: int = 1


@dataclasses.dataclass(frozen=True)
class HighBiasAbsorbOp:
    """Paper §4.1.3: absorb c = max(0, β − 3γ) from b1 into (w2, b2).

    beta/gamma paths point at stored pre-activation statistics (from BN
    folding, or LayerNorm params, or calibration); dense layout.
    """

    b1: Path
    w2: Path
    b2: Path
    beta: Path
    gamma: Path


@dataclasses.dataclass(frozen=True)
class WeightSite:
    """One quantizable linear: used for weight quantization + bias correction.

    ``stat_key`` names the entry in the model's activation-stats pytree whose
    mean is E[input] for this site (bias correction); ``kind`` selects the
    correction formula.
    """

    name: str
    w: Path
    b: Optional[Path] = None
    kind: str = "dense"                  # dense | conv | depthwise
    stat_key: Optional[str] = None


PlanOp = (
    NormFoldOp
    | DensePairOp
    | VOPairOp
    | QKPairOp
    | VBiasAbsorbOp
    | HighBiasAbsorbOp
)


@dataclasses.dataclass(frozen=True)
class DFQPlan:
    """Everything DFQ needs to know about one architecture."""

    ops: Sequence[PlanOp]
    sites: Sequence[WeightSite]
    name: str = ""
