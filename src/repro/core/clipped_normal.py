"""Closed-form moments of the clipped normal distribution (paper appendix C).

Given X ~ N(μ, σ²) and a clipped-linear activation f(x) = clip(x, a, b),
computes E[f(X)] (eq. 38) and Var[f(X)] (eq. 44). These power the data-free
bias-correction path (paper §4.2.1): with batch normalization, pre-activations
are N(β, γ²), so the post-activation mean E[x] is available without data.

ReLU is the special case a = 0, b = ∞ (paper eq. 19); ReLU6 is a = 0, b = 6.

Also provides a Gauss–Hermite fallback ``gaussian_expect`` for activations
that are *not* clipped-linear (e.g. GELU in whisper) — the closed form does
not exist there, but E[f(X)] under the same Gaussian assumption is a 1-D
integral computed exactly to quadrature precision. This is our documented
extension for LayerNorm+GELU architectures (DESIGN.md §3.2).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np
from jax.scipy.stats import norm


def _phi(x):
    return norm.pdf(x)


def _Phi(x):
    return norm.cdf(x)


def clipped_normal_mean(
    mu: jnp.ndarray,
    sigma: jnp.ndarray,
    a: float | jnp.ndarray = 0.0,
    b: Optional[float | jnp.ndarray] = None,
) -> jnp.ndarray:
    """E[clip(X, a, b)], paper eq. 38. ``b=None`` means b = +∞."""
    sigma = jnp.maximum(sigma, 1e-12)
    alpha = (a - mu) / sigma
    if b is None:
        # b → ∞: Φ(β) → 1, φ(β) → 0, b·(1 − Φ(β)) → 0.
        return (
            sigma * _phi(alpha)
            + mu * (1.0 - _Phi(alpha))
            + a * _Phi(alpha)
        )
    beta = (b - mu) / sigma
    return (
        sigma * (_phi(alpha) - _phi(beta))
        + mu * (_Phi(beta) - _Phi(alpha))
        + a * _Phi(alpha)
        + b * (1.0 - _Phi(beta))
    )


def clipped_normal_var(
    mu: jnp.ndarray,
    sigma: jnp.ndarray,
    a: float | jnp.ndarray = 0.0,
    b: Optional[float | jnp.ndarray] = None,
) -> jnp.ndarray:
    """Var[clip(X, a, b)], paper eq. 44."""
    sigma = jnp.maximum(sigma, 1e-12)
    m = clipped_normal_mean(mu, sigma, a, b)
    alpha = (a - mu) / sigma
    if b is None:
        Z = 1.0 - _Phi(alpha)
        phi_a, phi_b = _phi(alpha), jnp.zeros_like(alpha)
        b_phi_b = jnp.zeros_like(alpha)  # lim b·φ(β) = 0
        tail_b = jnp.zeros_like(alpha)   # lim (b − m)²(1 − Φ(β)) = 0
        Phi_b = jnp.ones_like(alpha)
    else:
        beta = (b - mu) / sigma
        Z = _Phi(beta) - _Phi(alpha)
        phi_a, phi_b = _phi(alpha), _phi(beta)
        b_phi_b = b * phi_b
        tail_b = (b - m) ** 2 * (1.0 - _Phi(beta))
        Phi_b = _Phi(beta)
    del Phi_b
    var = (
        Z * (mu ** 2 + sigma ** 2 + m ** 2 - 2.0 * m * mu)
        + sigma * (a * phi_a - b_phi_b)
        + sigma * (mu - 2.0 * m) * (phi_a - phi_b)
        + (a - m) ** 2 * _Phi(alpha)
        + tail_b
    )
    return jnp.maximum(var, 0.0)


def relu_normal_mean(beta: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """Paper eq. 19: E[ReLU(X)] for X ~ N(β, γ²)."""
    gamma = jnp.maximum(jnp.abs(gamma), 1e-12)
    z = -beta / gamma
    return gamma * _phi(z) + beta * (1.0 - _Phi(z))


# ----------------------------------------------------------------------------
# Gauss–Hermite quadrature for non-clipped-linear activations (GELU, SiLU).
# ----------------------------------------------------------------------------

_GH_POINTS = 64
_GH_X, _GH_W = np.polynomial.hermite_e.hermegauss(_GH_POINTS)  # probabilists'
_GH_W = _GH_W / np.sqrt(2.0 * np.pi)


def gaussian_expect(
    fn: Callable[[jnp.ndarray], jnp.ndarray],
    mu: jnp.ndarray,
    sigma: jnp.ndarray,
) -> jnp.ndarray:
    """E[fn(X)] for X ~ N(μ, σ²) via 64-point Gauss–Hermite quadrature.

    Exact (to quadrature accuracy) for smooth activations; used where the
    paper's clipped-normal closed form does not apply.
    """
    x = mu[..., None] + sigma[..., None] * jnp.asarray(_GH_X, mu.dtype)
    return jnp.sum(fn(x) * jnp.asarray(_GH_W, mu.dtype), axis=-1)
