"""Core DFQ library — the paper's contribution as composable JAX transforms."""

from .quantizer import (  # noqa: F401
    QParams,
    QuantSpec,
    channel_precision,
    channel_ranges,
    compute_qparams,
    dequantize,
    fake_quant,
    fake_quant_with_qparams,
    qparams_from_range,
    quantize,
    sqnr_db,
    tensor_range,
)
from .clipped_normal import (  # noqa: F401
    clipped_normal_mean,
    clipped_normal_var,
    gaussian_expect,
    relu_normal_mean,
)
from .cle import (  # noqa: F401
    ConvLayer,
    equalization_scales,
    equalize_conv_chain,
    equalize_dense_pair,
    equalize_qk,
    equalize_vo,
    fold_norm,
)
from .bias_absorption import (  # noqa: F401
    absorb_conv,
    absorb_dense,
    absorb_v_bias,
    absorption_amount,
)
from .bias_correction import (  # noqa: F401
    bias_correction_conv,
    bias_correction_dense,
    empirical_bias_correction_sequential,
    expected_input_analytic,
    output_bias_error,
    weight_quant_error,
)
from .bn_folding import BNParams, FoldedLayer, fold_bn_conv  # noqa: F401
from .graph import (  # noqa: F401
    DFQPlan,
    DensePairOp,
    HighBiasAbsorbOp,
    NormFoldOp,
    QKPairOp,
    VBiasAbsorbOp,
    VOPairOp,
    WeightSite,
)
from .dfq import (  # noqa: F401
    DFQConfig,
    apply_dfq,
    bias_correct,
    dfq_quantize,
    quantize_weights,
    run_plan_ops,
    weight_quant_snr,
)
from .adversarial import hostile_rescale  # noqa: F401
