"""DFQ: the paper's method as one composable API call (its stated goal —
"accuracy improvement with a simple API call", §1).

Pipeline (paper Fig. 4):
    BN folding (model-side) → cross-layer equalization → high-bias absorption
    → weight quantization → bias correction → activation-range setting.

``apply_dfq(params, plan, config)`` executes the function-preserving rewrites
(CLE + absorption). ``quantize_weights`` / ``bias_correct`` implement the
quantization + correction stage. ``dfq_quantize`` chains everything.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional

import jax.numpy as jnp

from . import bias_absorption, bias_correction, cle
from .graph import (
    DFQPlan,
    DensePairOp,
    HighBiasAbsorbOp,
    NormFoldOp,
    QKPairOp,
    VBiasAbsorbOp,
    VOPairOp,
    WeightSite,
)
from .quantizer import (
    QuantSpec,
    compute_qparams,
    dequantize,
    fake_quant,
    quantize,
)
from .tree import get_path, has_path, set_path


@dataclasses.dataclass(frozen=True)
class DFQConfig:
    """Level-1 defaults: 8-bit asymmetric per-tensor, everything on (paper §5)."""

    weight_bits: int = 8
    act_bits: int = 8
    weight_symmetric: bool = False
    act_symmetric: bool = False
    per_channel: bool = False            # paper's per-channel baseline [18]
    cle: bool = True
    cle_iterations: int = 2              # pairs here are closed-form optimal;
                                         # >1 only matters for shared tensors
    bias_absorb: bool = True
    bias_correct: str = "empirical"      # "empirical" | "analytic" | "none"
    n_sigma_absorb: float = 3.0          # paper: 3γ ⇒ 99.865 %
    act_range_n_sigma: float = 6.0       # paper §5: β ± 6γ
    cle_include_approx_pairs: bool = False  # plain-GELU pairs (whisper MLP)

    @property
    def weight_spec(self) -> QuantSpec:
        return QuantSpec(
            bits=self.weight_bits,
            symmetric=self.weight_symmetric,
            per_channel_axis=-1 if self.per_channel else None,
        )

    @property
    def act_spec(self) -> QuantSpec:
        return QuantSpec(bits=self.act_bits, symmetric=self.act_symmetric)


def _maybe(params, path):
    return get_path(params, path) if path is not None and has_path(params, path) else None


def run_plan_ops(
    params: Mapping,
    plan: DFQPlan,
    config: DFQConfig,
    *,
    kinds: Optional[tuple] = None,
    iterations: int = 1,
) -> dict:
    """Execute (a filtered slice of) the plan's function-preserving rewrites.

    ``kinds`` restricts execution to the given op classes (None → all ops) —
    the pipeline's ``fold_norm`` / ``cle`` / ``bias_absorb`` stages each run
    one slice; ``apply_dfq`` runs everything interleaved. Plan order is
    preserved within a pass, so a filtered schedule composes to the same
    result as the interleaved one for the emitted LM plans (bias absorption
    commutes with the CLE rescales it follows).
    """
    for _ in range(max(1, iterations)):
        for op in plan.ops:
            if kinds is not None and not isinstance(op, kinds):
                continue
            if isinstance(op, NormFoldOp):
                consumers = [get_path(params, p) for p in op.consumers]
                cbias_paths = (
                    list(op.consumer_biases)
                    if op.consumer_biases is not None
                    else [None] * len(op.consumers)
                )
                cbias = [_maybe(params, p) for p in cbias_paths]
                norm_b = _maybe(params, op.norm_b)
                ones, zeros, new_ws, new_bs = cle.fold_norm(
                    get_path(params, op.norm_w), consumers, norm_b, cbias
                )
                params = set_path(params, op.norm_w, ones)
                if op.norm_b is not None and zeros is not None:
                    params = set_path(params, op.norm_b, zeros)
                for p, w in zip(op.consumers, new_ws):
                    params = set_path(params, p, w)
                for p, b in zip(cbias_paths, new_bs):
                    if p is not None and b is not None:
                        params = set_path(params, p, b)
            elif isinstance(op, DensePairOp):
                if not config.cle:
                    continue
                if not op.exact and not config.cle_include_approx_pairs:
                    continue
                res = cle.equalize_dense_pair(
                    get_path(params, op.w1), _maybe(params, op.b1), get_path(params, op.w2)
                )
                params = set_path(params, op.w1, res.w1)
                params = set_path(params, op.w2, res.w2)
                if op.b1 is not None and res.b1 is not None:
                    params = set_path(params, op.b1, res.b1)
            elif isinstance(op, VOPairOp):
                if not config.cle:
                    continue
                res = cle.equalize_vo(
                    get_path(params, op.wv),
                    _maybe(params, op.bv),
                    get_path(params, op.wo),
                    n_q=op.n_q,
                    n_kv=op.n_kv,
                    head_dim=op.head_dim,
                )
                params = set_path(params, op.wv, res.w1)
                params = set_path(params, op.wo, res.w2)
                if op.bv is not None and res.b1 is not None:
                    params = set_path(params, op.bv, res.b1)
            elif isinstance(op, QKPairOp):
                if not config.cle:
                    continue
                res = cle.equalize_qk(
                    get_path(params, op.wq),
                    _maybe(params, op.bq),
                    get_path(params, op.wk),
                    _maybe(params, op.bk),
                    n_q=op.n_q,
                    n_kv=op.n_kv,
                    head_dim=op.head_dim,
                    rope=op.rope,
                )
                params = set_path(params, op.wq, res.wq)
                params = set_path(params, op.wk, res.wk)
                if op.bq is not None and res.bq is not None:
                    params = set_path(params, op.bq, res.bq)
                if op.bk is not None and res.bk is not None:
                    params = set_path(params, op.bk, res.bk)
            elif isinstance(op, VBiasAbsorbOp):
                if not config.bias_absorb:
                    continue
                res = bias_absorption.absorb_v_bias(
                    get_path(params, op.bv),
                    get_path(params, op.wo),
                    _maybe(params, op.bo),
                    n_q=op.n_q,
                    n_kv=op.n_kv,
                    head_dim=op.head_dim,
                )
                params = set_path(params, op.bv, res.b1)
                params = set_path(params, op.bo, res.b2)
            elif isinstance(op, HighBiasAbsorbOp):
                if not config.bias_absorb:
                    continue
                c = bias_absorption.absorption_amount(
                    get_path(params, op.beta),
                    get_path(params, op.gamma),
                    config.n_sigma_absorb,
                )
                res = bias_absorption.absorb_dense(
                    get_path(params, op.b1),
                    get_path(params, op.w2),
                    _maybe(params, op.b2),
                    c,
                )
                params = set_path(params, op.b1, res.b1)
                params = set_path(params, op.b2, res.b2)
            else:
                raise TypeError(f"unknown plan op {op!r}")
    return params


def apply_dfq(params: Mapping, plan: DFQPlan, config: DFQConfig) -> dict:
    """Function-preserving stage: norm folding, CLE, bias absorption.

    Returns a new params pytree computing the SAME FP32 function (exactly,
    except ops flagged non-exact) with per-channel ranges equalized. Thin
    wrapper over ``run_plan_ops`` (the original interleaved Fig. 4 schedule).
    """
    return run_plan_ops(params, plan, config, iterations=config.cle_iterations)


def quantize_weights(params: Mapping, plan: DFQPlan, config: DFQConfig) -> dict:
    """Fake-quantize every weight site (simulated INT-k inference).

    True int8 storage for the serving path lives in ``repro.quantized``.
    """
    spec = config.weight_spec
    for site in plan.sites:
        w = get_path(params, site.w)
        params = set_path(params, site.w, fake_quant(w, spec))
    return params


def bias_correct(
    params: Mapping,
    plan: DFQPlan,
    config: DFQConfig,
    input_means: Mapping[str, jnp.ndarray],
) -> dict:
    """Paper §4.2: subtract ε·E[x] from each site's bias.

    ``input_means[stat_key]`` is E[x] for the site's input — computed either
    analytically (BN/LN route) or empirically (synthetic calibration run).
    Sites without a bias get one created — the correction IS the bias.
    """
    spec = config.weight_spec
    for site in plan.sites:
        if site.stat_key is None or site.stat_key not in input_means:
            continue
        e_x = input_means[site.stat_key]
        w = get_path(params, site.w)
        b = _maybe(params, site.b)
        if site.kind == "dense":
            b_new = bias_correction.bias_correction_dense(w, b, e_x, spec)
        else:
            b_new = bias_correction.bias_correction_conv(
                w, b, e_x, spec, depthwise=(site.kind == "depthwise")
            )
        if site.b is None:
            raise ValueError(f"site {site.name} has no bias path for correction")
        # bias-less linears get the slot CREATED — the correction IS the bias
        # (models read biases via .get, so a new entry is consumed directly)
        params = set_path(params, site.b, b_new)
    return params


def dfq_quantize(
    params: Mapping,
    plan: DFQPlan,
    config: DFQConfig = DFQConfig(),
    input_means_fn: Optional[Callable[[Mapping], Mapping[str, jnp.ndarray]]] = None,
) -> dict:
    """The paper's end-to-end flow (Fig. 4) as one call.

    ``input_means_fn(params_equalized)`` supplies E[x] per stat_key — the
    model-side hook that runs synthetic calibration or evaluates the
    analytic clipped-normal route. Returns fake-quantized params.

    Thin wrapper over the pipeline's ``"dfq-int8"`` recipe (honoring the
    config's stage toggles); prefer ``repro.quantize`` for new code — it
    also returns the deployable ``QuantizedModel`` with stage diagnostics.
    """
    from ..pipeline.api import run_legacy_dfq  # deferred: core must not
    # import the pipeline at module load (pipeline stages wrap this module)

    return run_legacy_dfq(params, plan, config, input_means_fn)


def weight_quant_snr(params_fp: Mapping, params_q: Mapping, plan: DFQPlan):
    """Per-site SQNR diagnostics (dB)."""
    from .quantizer import sqnr_db

    out = {}
    for site in plan.sites:
        out[site.name] = float(
            sqnr_db(get_path(params_fp, site.w), get_path(params_q, site.w))
        )
    return out
