"""High-bias absorption (paper §4.1.3) + exact value-bias absorption.

After CLE, channels with s_i < 1 get inflated biases b⁽¹⁾, which inflates the
*activation* quantization range. The paper absorbs c = max(0, β − 3γ) from
layer 1 into layer 2:

    b⁽¹⁾ ← b⁽¹⁾ − c,     b⁽²⁾ ← b⁽²⁾ + W⁽²⁾ c

exact for inputs where W⁽¹⁾x + b⁽¹⁾ > c (99.865 % under the Gaussian
assumption with BN statistics β, γ).

Transformer extension (DESIGN §3.1): the value-projection bias passes through
attention *exactly* (softmax rows sum to 1), so b_v can be absorbed fully into
the o-projection bias with **zero** approximation — c = b_v, no 3σ rule needed.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp


def absorption_amount(
    beta: jnp.ndarray, gamma: jnp.ndarray, n_sigma: float = 3.0
) -> jnp.ndarray:
    """c = max(0, β − n·γ) (paper §4.1.3; n = 3 ⇒ exact on 99.865 % of x)."""
    return jnp.maximum(0.0, beta - n_sigma * jnp.abs(gamma))


class AbsorbResult(NamedTuple):
    b1: jnp.ndarray
    b2: jnp.ndarray
    c: jnp.ndarray


def absorb_dense(
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: Optional[jnp.ndarray],
    c: jnp.ndarray,
) -> AbsorbResult:
    """Absorb c from a dense layer's bias into the next dense layer.
    w2: [..., n, d_out]; b1, c: [..., n]."""
    b1_new = b1 - c
    shift = jnp.einsum("...n,...no->...o", c, w2)
    b2_new = shift if b2 is None else b2 + shift
    return AbsorbResult(b1_new, b2_new, c)


def absorb_conv(
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: Optional[jnp.ndarray],
    c: jnp.ndarray,
    depthwise: bool = False,
) -> AbsorbResult:
    """Conv variant: the absorbed constant is spatially uniform, so it folds
    through the kernel's spatial sum (exact away from padding borders — same
    approximation the paper makes). w2 HWIO."""
    b1_new = b1 - c
    if depthwise:
        shift = c * jnp.sum(w2[..., 0, :], axis=(0, 1))
    else:
        shift = jnp.einsum("i,hwio->o", c, w2)
    b2_new = shift if b2 is None else b2 + shift
    return AbsorbResult(b1_new, b2_new, c)


def absorb_v_bias(
    bv: jnp.ndarray,
    wo: jnp.ndarray,
    bo: Optional[jnp.ndarray],
    *,
    n_q: int,
    n_kv: int,
    head_dim: int,
) -> AbsorbResult:
    """Fully absorb the value bias through attention into the output bias.

    attn_out_h = Σ_t softmax(...)_t · (v_t + b_v) = (Σ softmax · v_t) + b_v
    because attention weights sum to one — the shift is exact for every input.
    With GQA, b_v broadcasts over the query heads of each group.

    bv: [..., n_kv·hd]; wo: [..., n_q·hd, d_model].
    """
    group = n_q // n_kv
    lead = wo.shape[:-2]
    d_model = wo.shape[-1]
    c_g = bv.reshape(*lead, n_kv, head_dim)
    c_full = jnp.broadcast_to(
        c_g[..., :, None, :], (*lead, n_kv, group, head_dim)
    ).reshape(*lead, n_q * head_dim)
    shift = jnp.einsum("...n,...no->...o", c_full, wo)
    bo_new = shift if bo is None else bo + shift
    return AbsorbResult(jnp.zeros_like(bv), bo_new, bv)
