"""Tiny pytree-path utilities used by the DFQ plan executor.

Paths are tuples of dict keys. All transforms are functional: ``set_path``
returns a new nested dict sharing unmodified subtrees.
"""
from __future__ import annotations

from typing import Any, Mapping

Path = tuple


def get_path(tree: Mapping, path: Path) -> Any:
    node = tree
    for key in path:
        node = node[key]
    return node


def has_path(tree: Mapping, path: Path) -> bool:
    node = tree
    for key in path:
        if not isinstance(node, Mapping) or key not in node:
            return False
        node = node[key]
    return True


def set_path(tree: Mapping, path: Path, value: Any) -> dict:
    """Functionally set ``tree[path] = value`` (copy-on-write along the path)."""
    if not path:
        raise ValueError("empty path")
    new = dict(tree)
    key = path[0]
    if len(path) == 1:
        new[key] = value
    else:
        new[key] = set_path(new.get(key, {}), path[1:], value)
    return new


def update_paths(tree: Mapping, updates: Mapping[Path, Any]) -> dict:
    for path, value in updates.items():
        tree = set_path(tree, path, value)
    return tree


def leaf_paths(tree: Mapping, prefix: Path = ()) -> list[Path]:
    out = []
    for key, val in tree.items():
        if isinstance(val, Mapping):
            out.extend(leaf_paths(val, prefix + (key,)))
        else:
            out.append(prefix + (key,))
    return out
