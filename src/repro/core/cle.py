"""Cross-layer range equalization (paper §4.1, appendix A).

For two weight tensors connected through a positive-scaling-equivariant map,
the optimal diagonal rescaling S (maximizing the joint per-channel precision,
paper eq. 9) is the closed form of eq. 11:

    s_i = (1 / r_i^(2)) * sqrt(r_i^(1) * r_i^(2))

after which r_i^(1) = r_i^(2) for every channel i. The FP32 function is
exactly preserved: W1 ← S⁻¹ W1, b1 ← S⁻¹ b1, W2 ← W2 S.

This module provides:
  * ``equalization_scales``     — eq. 11 with dead-channel guards,
  * ``equalize_dense_pair``     — ReLU / gated-MLP pair (exact; DESIGN §3.1),
  * ``equalize_vo``             — value/output projection pair through
                                  attention (exact: attn output is linear in V;
                                  handles GQA head grouping),
  * ``equalize_qk``             — query/key pair (exact with RoPE when scales
                                  are shared within each rotation 2-D pair and
                                  across the GQA group),
  * ``fold_norm``               — RMSNorm/LayerNorm scale folded into the
                                  consuming linears (analogue of BN folding),
  * ``equalize_conv_chain``     — the paper's CNN case: iterate adjacent
                                  (conv, depthwise, conv) pairs to convergence.

Weight layout conventions: dense weights are ``[..., d_in, d_out]`` (applied
as ``y = x @ W + b``); conv kernels are HWIO. Leading batch dims (stacked
scan layers ``[L, ...]`` or experts ``[L, E, ...]``) broadcast through every
function, so a whole stacked transformer equalizes in one vectorized call.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp

_EPS = 1e-12


def equalization_scales(r1: jnp.ndarray, r2: jnp.ndarray) -> jnp.ndarray:
    """Paper eq. 11. Dead channels (r1·r2 ≈ 0) get s = 1 (no-op) — they carry
    no signal and the paper notes they can be pruned (§5.1.1)."""
    s = jnp.sqrt(jnp.maximum(r1, _EPS) * jnp.maximum(r2, _EPS)) / jnp.maximum(
        r2, _EPS
    )
    return jnp.where(r1 * r2 > _EPS, s, 1.0)


class PairResult(NamedTuple):
    w1: jnp.ndarray
    b1: Optional[jnp.ndarray]
    w2: jnp.ndarray
    scales: jnp.ndarray


def equalize_dense_pair(
    w1: jnp.ndarray,
    b1: Optional[jnp.ndarray],
    w2: jnp.ndarray,
) -> PairResult:
    """Equalize ``y = f(x @ W1 + b1) @ W2`` where f is ReLU/PReLU (paper
    eq. 5–7) or the up→down path of a gated MLP (exactly linear in W1's
    output — DESIGN §3.1). W1: [..., d_in, n], W2: [..., n, d_out]."""
    r1 = jnp.max(jnp.abs(w1), axis=-2)               # [..., n] over d_in only
    r2 = jnp.max(jnp.abs(w2), axis=-1)               # [..., n]
    s = equalization_scales(r1, r2)
    w1_new = w1 / s[..., None, :]
    b1_new = None if b1 is None else b1 / s
    w2_new = w2 * s[..., :, None]
    return PairResult(w1_new, b1_new, w2_new, s)


def equalize_vo(
    wv: jnp.ndarray,
    bv: Optional[jnp.ndarray],
    wo: jnp.ndarray,
    *,
    n_q: int,
    n_kv: int,
    head_dim: int,
) -> PairResult:
    """Equalize value-projection output channels against the output
    projection's input channels through attention.

    Exact: ``attn_out = softmax(QKᵀ)·V`` is linear in V, so a per-channel
    scale on V commutes to O's input. With GQA, V channel (kv, d) feeds the
    o-proj rows of every query head in kv's group.

    wv: [..., d_model, n_kv·head_dim], wo: [..., n_q·head_dim, d_model].
    """
    group = n_q // n_kv
    lead_o = wo.shape[:-2]
    d_model_out = wo.shape[-1]
    r1 = jnp.max(jnp.abs(wv), axis=-2)               # [..., n_kv*hd]
    wo_g = wo.reshape(*lead_o, n_kv, group, head_dim, d_model_out)
    r2 = jnp.max(jnp.abs(wo_g), axis=(-3, -1))       # [..., n_kv, hd]
    r2 = r2.reshape(*lead_o, n_kv * head_dim)
    s = equalization_scales(r1, r2)                  # [..., n_kv*hd]
    wv_new = wv / s[..., None, :]
    bv_new = None if bv is None else bv / s
    s_g = s.reshape(*lead_o, n_kv, 1, head_dim, 1)
    wo_new = (wo_g * s_g).reshape(wo.shape)
    return PairResult(wv_new, bv_new, wo_new, s)


class QKResult(NamedTuple):
    wq: jnp.ndarray
    bq: Optional[jnp.ndarray]
    wk: jnp.ndarray
    bk: Optional[jnp.ndarray]
    scales: jnp.ndarray


def equalize_qk(
    wq: jnp.ndarray,
    bq: Optional[jnp.ndarray],
    wk: jnp.ndarray,
    bk: Optional[jnp.ndarray],
    *,
    n_q: int,
    n_kv: int,
    head_dim: int,
    rope: bool = True,
) -> QKResult:
    """Equalize Q against K. Logits ⟨q_h, k_g(h)⟩ are preserved when Q channel
    (h, d) is scaled by s and K channel (g(h), d) by 1/s. Constraints:

      * GQA: all query heads in a group share the K head → s is indexed by
        (kv_head, d) and broadcast over the group,
      * RoPE (rotate-half convention: dims d and d + head_dim/2 form one
        rotation pair) mixes the pair, so s must be shared within it.

    wq: [..., d_model, n_q·head_dim], wk: [..., d_model, n_kv·head_dim].
    """
    group = n_q // n_kv
    lead = wq.shape[:-2]
    d_model = wq.shape[-2]
    half = head_dim // 2

    wq_g = wq.reshape(*lead, d_model, n_kv, group, head_dim)
    wk_g = wk.reshape(*lead, d_model, n_kv, head_dim)
    rq = jnp.max(jnp.abs(wq_g), axis=(-4, -2))       # [..., n_kv, hd]
    rk = jnp.max(jnp.abs(wk_g), axis=-3)             # [..., n_kv, hd]
    if rope:
        # share within rotation pairs (d, d+half): take pairwise max
        def pair_max(r):
            a, b = r[..., :half], r[..., half:]
            m = jnp.maximum(a, b)
            return jnp.concatenate([m, m], axis=-1)

        rq, rk = pair_max(rq), pair_max(rk)
    s = equalization_scales(rq, rk)
    if rope:
        s = jnp.concatenate([s[..., :half], s[..., :half]], axis=-1)

    # Q ← Q / s ; K ← K · s (per grouped channel) — logits invariant, and
    # r_q' = r_k' = sqrt(r_q · r_k) per eq. 11.
    wk_new = (wk_g * s[..., None, :, :]).reshape(wk.shape)
    sq = s[..., None, :, None, :]
    wq_new = (wq_g / sq).reshape(wq.shape)
    bq_new = None
    bk_new = None
    if bq is not None:
        bq_new = (bq.reshape(*lead, n_kv, group, head_dim) / s[..., :, None, :]).reshape(bq.shape)
    if bk is not None:
        bk_new = (bk.reshape(*lead, n_kv, head_dim) * s).reshape(bk.shape)
    s_flat = s.reshape(*lead, n_kv * head_dim)
    return QKResult(wq_new, bq_new, wk_new, bk_new, s_flat)


def fold_norm(
    norm_w: jnp.ndarray,
    consumers: Sequence[jnp.ndarray],
    norm_b: Optional[jnp.ndarray] = None,
    consumer_biases: Optional[Sequence[Optional[jnp.ndarray]]] = None,
):
    """Fold a norm's elementwise scale γ (and shift β, if LayerNorm) into the
    linears consuming its output — the transformer analogue of the paper's
    BatchNorm folding (§5):  W·(γ⊙x̂ + β) = (W·diag(γ))·x̂ + W·β.

    norm_w: [..., d]; consumers: list of [..., d, out]. Returns
    (ones_like(norm_w), zeros β, new consumers, new biases).
    """
    new_ws, new_bs = [], []
    if consumer_biases is None:
        consumer_biases = [None] * len(consumers)
    for w, b in zip(consumers, consumer_biases):
        w_new = w * norm_w[..., :, None]
        if norm_b is not None:
            shift = jnp.einsum("...d,...do->...o", norm_b * jnp.ones_like(norm_w), w)
            b_new = shift if b is None else b + shift
        else:
            b_new = b
        new_ws.append(w_new)
        new_bs.append(b_new)
    ones = jnp.ones_like(norm_w)
    zeros = None if norm_b is None else jnp.zeros_like(norm_b)
    return ones, zeros, new_ws, new_bs


# ----------------------------------------------------------------------------
# CNN chain equalization (the paper's own experimental setting).
# ----------------------------------------------------------------------------

class ConvLayer(NamedTuple):
    """HWIO conv kernel + bias + structural kind.

    kind: "conv" (dense conv / 1x1), "depthwise" ([kh,kw,1,C], groups = C),
    or "dense" ([in,out]).
    """

    w: jnp.ndarray
    b: Optional[jnp.ndarray]
    kind: str = "conv"


def _out_ranges(layer: ConvLayer) -> jnp.ndarray:
    if layer.kind == "dense":
        return jnp.max(jnp.abs(layer.w), axis=-2)
    return jnp.max(jnp.abs(layer.w), axis=(0, 1, 2))  # HWIO → per O


def _in_ranges(layer: ConvLayer) -> jnp.ndarray:
    if layer.kind == "dense":
        return jnp.max(jnp.abs(layer.w), axis=-1)
    if layer.kind == "depthwise":
        return jnp.max(jnp.abs(layer.w), axis=(0, 1, 2))  # channel == O axis
    return jnp.max(jnp.abs(layer.w), axis=(0, 1, 3))      # per I


def _scale_out(layer: ConvLayer, s: jnp.ndarray) -> ConvLayer:
    """Divide output channels by s (and bias)."""
    if layer.kind == "dense":
        w = layer.w / s[None, :]
    else:
        w = layer.w / s[None, None, None, :]
    b = None if layer.b is None else layer.b / s
    return layer._replace(w=w, b=b)


def _scale_in(layer: ConvLayer, s: jnp.ndarray) -> ConvLayer:
    """Multiply input channels by s (compensating an upstream 1/s)."""
    if layer.kind == "dense":
        w = layer.w * s[:, None]
    elif layer.kind == "depthwise":
        w = layer.w * s[None, None, None, :]
    else:
        w = layer.w * s[None, None, :, None]
    return layer._replace(w=w)


def equalize_conv_chain(
    layers: Sequence[ConvLayer],
    iterations: int = 20,
    tol: float = 1e-4,
) -> tuple[list[ConvLayer], jnp.ndarray]:
    """Iterate pairwise equalization over a chain of layers connected without
    splits (paper §4.1.2: "we iterate this process for pairs of layers ...
    until convergence"). Returns new layers and the cumulative per-interface
    scales (product over iterations) for diagnostics.
    """
    layers = list(layers)
    n_if = len(layers) - 1
    cum = [jnp.ones_like(_out_ranges(layers[i])) for i in range(n_if)]
    for _ in range(iterations):
        max_log_change = 0.0
        for i in range(n_if):
            r1 = _out_ranges(layers[i])
            r2 = _in_ranges(layers[i + 1])
            s = equalization_scales(r1, r2)
            layers[i] = _scale_out(layers[i], s)
            layers[i + 1] = _scale_in(layers[i + 1], s)
            cum[i] = cum[i] * s
            max_log_change = jnp.maximum(
                max_log_change, jnp.max(jnp.abs(jnp.log(s)))
            )
        if float(max_log_change) < tol:
            break
    return layers, cum
