"""Adversarial channel rescaling — the inverse of CLE.

Uses the SAME positive-scaling equivariance DFQ exploits to inject random
per-channel scales into a model's exact equalization pairs: the FP32
function is unchanged (bit-for-bit up to fp rounding) but per-tensor INT8
collapses. This reproduces the paper's hard-to-quantize MobileNetV2 starting
point for models we train/initialize ourselves, making the recovery
experiments honest: DFQ must undo arbitrary hostile scalings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import DFQPlan, DensePairOp, VOPairOp
from .tree import get_path, set_path


def hostile_rescale(params, plan: DFQPlan, *, seed: int = 0,
                    decades: float = 1.5):
    """Randomly rescale every exact DensePair (up↔down) in the plan.
    log-normal scales spanning ~`decades` orders of magnitude."""
    key = jax.random.PRNGKey(seed)
    for op in plan.ops:
        if isinstance(op, DensePairOp) and op.exact:
            w1 = get_path(params, op.w1)
            w2 = get_path(params, op.w2)
            key, k = jax.random.split(key)
            s = jnp.exp(jax.random.normal(k, w1.shape[:-2] + w1.shape[-1:]) * decades)
            params = set_path(params, op.w1, w1 * s[..., None, :])
            params = set_path(params, op.w2, w2 / s[..., :, None])
    return params
