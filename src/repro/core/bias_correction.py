"""Quantization bias correction (paper §4.2, appendices B–D).

Weight quantization error ε = W̃ − W shifts a layer's output mean:
E[ỹ] = E[y] + ε·E[x]. Correct it by subtracting the expected error from the
layer's bias:

    b ← b − εᵀ E[x]          (dense; our layout is y = x @ W + b)
    b_c ← b_c − Σ_{ci} E[x_ci] Σ_{mn} ε_{c,ci,mn}     (conv, appendix B)

Three sources for E[x]:

  * **analytic** (paper §4.2.1): previous layer has BN with (β, γ); push the
    N(β, γ²) pre-activation through the clipped-linear activation with the
    clipped-normal closed form (appendix C). Data-free, level 1.
  * **analytic-quadrature** (ours, DESIGN §3.2): same Gaussian assumption but
    with non-clipped activations (GELU), via Gauss–Hermite quadrature. Covers
    LayerNorm architectures (whisper).
  * **empirical** (appendix D): E[x] measured by running calibration inputs.
    For the LM archs the calibration source is *synthetic random tokens*, so
    the method stays data-free. The exact sequential procedure (correct layer
    L only after all layers feeding it are corrected) is implemented for the
    chain-structured CNN; a one-shot variant (all corrections from FP32
    statistics) is used at transformer scale.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax.numpy as jnp

from .clipped_normal import clipped_normal_mean, gaussian_expect
from .quantizer import QParams, QuantSpec, compute_qparams, dequantize, quantize


def weight_quant_error(w: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """ε = W̃ − W for a min/max-calibrated quantizer."""
    qp = compute_qparams(w, spec)
    w_q = dequantize(quantize(w, qp), qp)
    return w_q - w


def expected_input_analytic(
    beta: jnp.ndarray,
    gamma: jnp.ndarray,
    activation: str = "relu",
    clip_max: Optional[float] = None,
) -> jnp.ndarray:
    """E[x] for x = act(N(β, γ²)) — paper eq. 18/19 and appendix C.

    activation: "relu" | "relu6" | "identity" | "gelu" | "silu".
    """
    gamma = jnp.abs(gamma)
    if activation == "identity":
        return beta
    if activation == "relu":
        return clipped_normal_mean(beta, gamma, a=0.0, b=clip_max)
    if activation == "relu6":
        return clipped_normal_mean(beta, gamma, a=0.0, b=6.0)
    if activation == "gelu":
        import jax

        return gaussian_expect(jax.nn.gelu, beta, gamma)
    if activation == "silu":
        import jax

        return gaussian_expect(jax.nn.silu, beta, gamma)
    raise ValueError(f"unknown activation {activation!r}")


def bias_correction_dense(
    w: jnp.ndarray,
    b: Optional[jnp.ndarray],
    e_x: jnp.ndarray,
    spec: QuantSpec,
) -> jnp.ndarray:
    """Corrected bias for a dense layer y = x @ W + b.

    w: [..., d_in, d_out], e_x: [..., d_in] → correction [..., d_out].
    """
    eps = weight_quant_error(w, spec)
    corr = jnp.einsum("...i,...io->...o", e_x, eps)
    if b is None:
        return -corr
    return b - corr


def bias_correction_conv(
    w: jnp.ndarray,
    b: Optional[jnp.ndarray],
    e_x: jnp.ndarray,
    spec: QuantSpec,
    depthwise: bool = False,
) -> jnp.ndarray:
    """Appendix B: E[ε * x] = ε * E[x]; with spatially-uniform E[x] the
    correction collapses to the kernel's spatial sum. w: HWIO."""
    eps = weight_quant_error(w, spec)
    if depthwise:
        corr = e_x * jnp.sum(eps[..., 0, :], axis=(0, 1))
    else:
        corr = jnp.einsum("i,hwio->o", e_x, eps)
    if b is None:
        return -corr
    return b - corr


class EmpiricalBC(NamedTuple):
    """Result of the appendix-D sequential procedure."""

    biases: list
    residual_bias: list  # E[ỹ] − E[y] after correction (diagnostic, → 0)


def empirical_bias_correction_sequential(
    layer_apply: Callable[[int, jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    weights: list,
    biases: list,
    x0: jnp.ndarray,
    quantize_w: Callable[[jnp.ndarray], jnp.ndarray],
    reduce_axes: tuple = (0,),
) -> EmpiricalBC:
    """Appendix D, exact sequential form, for chain networks.

    ``layer_apply(i, x, w, b)`` computes layer i's **pre-activation** output;
    a separate ``post`` step is the caller's activation. We run the FP32 chain
    and the quantized chain side by side; after computing layer i in both, we
    fold E[ỹ_i] − E[y_i] into b̃_i so the quantized chain's mean matches before
    moving on ("we bias correct a layer only after all the layers feeding into
    it have been bias-corrected").

    Here layer_apply must apply the *full* layer including activation of the
    previous layer — i.e. x inputs are post-activation. To keep this generic
    we take pre-activation outputs and let the caller's chain include the
    activation inside ``layer_apply`` of the *next* layer.
    """
    x_fp = x0
    x_q = x0
    new_biases = []
    residuals = []
    for i, (w, b) in enumerate(zip(weights, biases)):
        y_fp = layer_apply(i, x_fp, w, b)
        w_q = quantize_w(w)
        y_q = layer_apply(i, x_q, w_q, b)
        err = jnp.mean(y_q - y_fp, axis=reduce_axes)
        b_new = (b if b is not None else 0.0) - err
        y_q = layer_apply(i, x_q, w_q, b_new)
        residuals.append(jnp.mean(y_q - y_fp, axis=reduce_axes))
        new_biases.append(b_new)
        x_fp, x_q = y_fp, y_q
    return EmpiricalBC(new_biases, residuals)


def output_bias_error(
    y_fp: jnp.ndarray, y_q: jnp.ndarray, channel_axis: int = -1
) -> jnp.ndarray:
    """Paper eq. 1: per-channel E[ỹ − y] (the quantity Fig. 3 plots)."""
    axes = tuple(a for a in range(y_fp.ndim) if a != channel_axis % y_fp.ndim)
    return jnp.mean(y_q - y_fp, axis=axes)
