"""BatchNorm folding (paper §5: "Batch normalization is folded in the
adjacent layer before quantization").

For y = BN(conv(x; W, b)) with BN statistics (μ, σ²) and affine (γ, β):

    W' = W · γ/√(σ²+ε)   (per output channel)
    b' = (b − μ) · γ/√(σ²+ε) + β

After folding, the layer's *pre-activation* distribution still has the BN
moments: mean β and std |γ| — which is exactly what the data-free bias
absorption (§4.1.3) and bias correction (§4.2.1) consume downstream. We
therefore return those moments alongside the folded parameters.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp


class BNParams(NamedTuple):
    gamma: jnp.ndarray
    beta: jnp.ndarray
    mean: jnp.ndarray
    var: jnp.ndarray
    eps: float = 1e-5


class FoldedLayer(NamedTuple):
    w: jnp.ndarray
    b: jnp.ndarray
    # data-free pre-activation moments for downstream DFQ stages:
    act_mean: jnp.ndarray   # = β
    act_std: jnp.ndarray    # = |γ|


def fold_bn_conv(w: jnp.ndarray, b: Optional[jnp.ndarray], bn: BNParams) -> FoldedLayer:
    """w: HWIO conv kernel (or [in, out] dense — last axis is the channel)."""
    inv_std = bn.gamma / jnp.sqrt(bn.var + bn.eps)
    w_new = w * inv_std  # broadcasts over the trailing output-channel axis
    b0 = jnp.zeros_like(bn.beta) if b is None else b
    b_new = (b0 - bn.mean) * inv_std + bn.beta
    return FoldedLayer(w_new, b_new, bn.beta, jnp.abs(bn.gamma))
