"""Fixed-point quantizers (paper §1, §5 experimental setup).

Implements hardware-style affine quantization:

    q = clamp(round(x / scale) + zero_point, qmin, qmax)
    x̂ = (q - zero_point) * scale

Supports:
  * symmetric (zero_point = 0) and asymmetric schemes (paper Table 7),
  * per-tensor (the paper's main, hardware-friendly setting) and
    per-channel [Krishnamoorthi 2018] granularity (paper baseline, Table 8),
  * arbitrary bit widths (paper evaluates INT8 and INT6; Fig. 1 sweeps 4..16).

Ranges for weights are "the min and max of the weight tensor" (paper §5).
Activation ranges are set data-free from normalization statistics as
``β ± n·γ`` with n = 6 (paper §5), clipped at 0 after ReLU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantizer."""

    bits: int = 8
    symmetric: bool = False          # paper default: asymmetric (§5)
    per_channel_axis: Optional[int] = None  # None → per-tensor
    # Signed integer grid for symmetric, unsigned+zero-point for asymmetric
    # (matches common fixed-point inference HW, e.g. [16, 18]).

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.symmetric else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.symmetric else 2 ** self.bits - 1

    @property
    def dtype(self):
        if self.bits <= 8:
            return jnp.int8 if self.symmetric else jnp.uint8
        return jnp.int16 if self.symmetric else jnp.uint16


@dataclasses.dataclass
class QParams:
    """Scale/zero-point pair. Arrays broadcast against the tensor they quantize."""

    scale: jnp.ndarray
    zero_point: jnp.ndarray
    spec: QuantSpec


def _reduce_axes(x: jnp.ndarray, channel_axis: Optional[int]):
    if channel_axis is None:
        return tuple(range(x.ndim))
    channel_axis = channel_axis % x.ndim
    return tuple(a for a in range(x.ndim) if a != channel_axis)


def _keepdims_shape(x: jnp.ndarray, channel_axis: Optional[int]):
    if channel_axis is None:
        return ()
    channel_axis = channel_axis % x.ndim
    return tuple(x.shape[a] if a == channel_axis else 1 for a in range(x.ndim))


def compute_qparams(
    x: jnp.ndarray, spec: QuantSpec, eps: float = 1e-8
) -> QParams:
    """Min/max-derived quantization parameters (paper §5: ranges are tensor
    min/max; per-channel reduces over all non-channel axes)."""
    axes = _reduce_axes(x, spec.per_channel_axis)
    if spec.symmetric:
        amax = jnp.max(jnp.abs(x), axis=axes)
        scale = jnp.maximum(amax, eps) / spec.qmax
        zp = jnp.zeros_like(scale)
    else:
        xmin = jnp.minimum(jnp.min(x, axis=axes), 0.0)  # grid must contain 0
        xmax = jnp.maximum(jnp.max(x, axis=axes), 0.0)
        scale = jnp.maximum(xmax - xmin, eps) / (spec.qmax - spec.qmin)
        zp = jnp.round(spec.qmin - xmin / scale)
        zp = jnp.clip(zp, spec.qmin, spec.qmax)
    shape = _keepdims_shape(x, spec.per_channel_axis)
    return QParams(scale.reshape(shape), zp.reshape(shape), spec)


def qparams_from_range(
    xmin: jnp.ndarray, xmax: jnp.ndarray, spec: QuantSpec, eps: float = 1e-8
) -> QParams:
    """Quantizer from externally supplied ranges — the data-free activation
    path (paper §5: range = β ± 6γ from batch-norm statistics)."""
    if spec.symmetric:
        amax = jnp.maximum(jnp.abs(xmin), jnp.abs(xmax))
        scale = jnp.maximum(amax, eps) / spec.qmax
        zp = jnp.zeros_like(scale)
    else:
        xmin = jnp.minimum(xmin, 0.0)
        xmax = jnp.maximum(xmax, 0.0)
        scale = jnp.maximum(xmax - xmin, eps) / (spec.qmax - spec.qmin)
        zp = jnp.clip(jnp.round(spec.qmin - xmin / scale), spec.qmin, spec.qmax)
    return QParams(scale, zp, spec)


def quantize(x: jnp.ndarray, qp: QParams) -> jnp.ndarray:
    q = jnp.round(x / qp.scale) + qp.zero_point
    return jnp.clip(q, qp.spec.qmin, qp.spec.qmax).astype(qp.spec.dtype)


def dequantize(q: jnp.ndarray, qp: QParams) -> jnp.ndarray:
    return (q.astype(jnp.float32) - qp.zero_point) * qp.scale


def fake_quant(x: jnp.ndarray, spec: QuantSpec, eps: float = 1e-8) -> jnp.ndarray:
    """Quantize-dequantize in one step (simulated fixed-point)."""
    qp = compute_qparams(x, spec, eps)
    return dequantize(quantize(x, qp), qp).astype(x.dtype)


def fake_quant_with_qparams(x: jnp.ndarray, qp: QParams) -> jnp.ndarray:
    return dequantize(quantize(x, qp), qp).astype(x.dtype)


# ----------------------------------------------------------------------------
# Range helpers used by cross-layer equalization (paper §4.1.2 / appendix A).
# ----------------------------------------------------------------------------

def channel_ranges(w: jnp.ndarray, channel_axis: int) -> jnp.ndarray:
    """Symmetric per-channel range r_i = max_j |W_ij| (the factor 2 in the
    paper cancels in every ratio CLE takes; appendix A eq. 20)."""
    axes = _reduce_axes(w, channel_axis)
    return jnp.max(jnp.abs(w), axis=axes)


def tensor_range(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(w))


def channel_precision(w: jnp.ndarray, channel_axis: int) -> jnp.ndarray:
    """Per-channel precision p_i = r_i / R (paper eq. 8)."""
    r = channel_ranges(w, channel_axis)
    return r / jnp.maximum(tensor_range(w), 1e-12)


def sqnr_db(x: jnp.ndarray, x_hat: jnp.ndarray) -> jnp.ndarray:
    """Signal-to-quantization-noise ratio in dB — scalar quality metric used
    throughout tests/benchmarks where the paper reports top-1 accuracy."""
    num = jnp.sum(jnp.square(x))
    den = jnp.sum(jnp.square(x - x_hat)) + 1e-30
    return 10.0 * jnp.log10(num / den)


def np_dtype_for_bits(bits: int, symmetric: bool):
    if bits <= 8:
        return np.int8 if symmetric else np.uint8
    return np.int16 if symmetric else np.uint16
