"""Post-training quantization → serving parameters.

``quantize_for_serving`` is DFQ's deployment output: after the
function-preserving rewrites (CLE + absorption) and bias correction, every
WeightSite's fp weight is replaced by an int8 QTensor; the model then serves
through the Pallas INT8 kernels with no code change (qtensor dispatch).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from ..core.graph import DFQPlan
from ..core.tree import get_path, set_path
from .qtensor import QTensor, quantize_param


def quantize_for_serving(
    params: Mapping,
    plan: DFQPlan,
    *,
    mode: str = "w8a16",
    per_channel: bool = False,
) -> dict:
    """Replace each site's weight with an int8 QTensor (per-tensor scale by
    default — the paper's hardware-friendly setting)."""
    for site in plan.sites:
        w = get_path(params, site.w)
        params = set_path(params, site.w, quantize_param(
            w, per_channel=per_channel, mode=mode))
    return params


def quantize_shapes(params_shape: Mapping, plan: DFQPlan, *,
                    mode: str = "w8a16", per_channel: bool = False) -> dict:
    """Shape-level mirror of ``quantize_for_serving`` for the dry-run: every
    site weight ShapeDtypeStruct becomes a QTensor of (int8 payload, fp32
    scale) ShapeDtypeStructs — lowerable with zero allocation."""
    import jax

    for site in plan.sites:
        w = get_path(params_shape, site.w)
        scale_shape = w.shape[:-2] + ((w.shape[-1],) if per_channel
                                      else (1,))
        qt = QTensor(
            jax.ShapeDtypeStruct(w.shape, jnp.int8),
            jax.ShapeDtypeStruct(scale_shape, jnp.float32),
            mode,
        )
        params_shape = set_path(params_shape, site.w, qt)
    return params_shape


def dequantize_params(params: Mapping) -> dict:
    """Undo for validation: QTensor → fp32 (the fake-quant image)."""
    def deq(x):
        return x.dequant() if isinstance(x, QTensor) else x

    return jax.tree.map(deq, params, is_leaf=lambda x: isinstance(x, QTensor))


def serving_summary(params) -> dict:
    """Bytes accounting: fp vs int8 parameter payload (the deployment win)."""
    fp_bytes = 0
    q_bytes = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            q_bytes += leaf.q.size + leaf.scale.size * 4
            fp_bytes += leaf.q.size * 4
        else:
            fp_bytes += leaf.size * leaf.dtype.itemsize
            q_bytes += leaf.size * leaf.dtype.itemsize
    return {"fp32_bytes": int(fp_bytes), "int8_bytes": int(q_bytes),
            "compression": fp_bytes / max(q_bytes, 1)}
