"""QTensor: int8 weight container that drops into the model unchanged.

Registered as a pytree node, so scan-stacked quantized weights slice per
layer like ordinary arrays, the sharding planner sees q/scale as leaves, and
``models.layers.linear`` dispatches on the type:

    y = x @ W          (jnp.ndarray)
    y = w8a16(x, W)    (QTensor, mode="w8a16": dequant-in-kernel)
    y = w8a8(q(x), W)  (QTensor, mode="w8a8":  dynamic act quant + int8 MXU)

so the SAME transformer code serves fp and INT8 — the paper's "simple API
call" deployment story.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    q: jnp.ndarray                 # int8 payload [..., K, N]
    scale: jnp.ndarray             # [..., N] or [..., 1] fp32 (symmetric)
    mode: str = "w8a16"            # w8a16 | w8a8

    def tree_flatten(self):
        return (self.q, self.scale), (self.mode,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def dtype(self):
        return self.q.dtype

    def astype(self, dtype):  # models cast params wholesale; int8 stays int8
        return self

    def dequant(self, dtype=jnp.float32):
        return self.q.astype(jnp.float32) * self.scale[..., None, :]


def quantize_param(w: jnp.ndarray, *, per_channel: bool = True,
                   mode: str = "w8a16") -> QTensor:
    """Symmetric int8 quantization of a [..., K, N] weight (per-out-channel
    or per-tensor scale). CLE makes symmetric ≈ asymmetric (paper Table 7)."""
    if per_channel:
        amax = jnp.max(jnp.abs(w), axis=-2)            # [..., N]
    else:
        amax = jnp.max(jnp.abs(w), axis=(-2, -1), keepdims=True)[..., 0]
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w / scale[..., None, :]), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32), mode)


def quantize_input(x: jnp.ndarray):
    """Dynamic-quantize an activation once for SHARED use across every W8A8
    projection reading it (the qkv trio, the GLU gate/up pair): returns
    (x_q int8 [M, K], x_scale fp32 [M], lead shape). One quantize dispatch
    replaces one-per-consumer — the values are bitwise what each consumer's
    own ``quantize_act`` would have produced, since per-row quantization
    depends only on the row."""
    from ..kernels.dispatch import serving_backend
    from ..kernels.quantize_act.ops import quantize_act

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    a_q, a_s = quantize_act(x2, backend=serving_backend())
    return a_q, a_s, lead


def qtensor_matmul(x: jnp.ndarray, w: QTensor, bias: Optional[jnp.ndarray]):
    """Route an activation through a quantized weight. x: [..., K]."""
    from ..kernels.dispatch import serving_backend
    from ..kernels.qmatmul_w8a16.ops import qmatmul_w8a16
    from ..kernels.quantize_act.ops import quantize_act

    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.q.shape[-1]
    x2 = x.reshape(-1, K)
    assert w.q.ndim == 2, "stacked QTensors must be sliced (scan) before use"
    backend = serving_backend()
    if w.mode == "w8a8":
        a_q, a_s = quantize_act(x2, backend=backend)
        return qtensor_matmul_prequant(a_q, a_s, w, bias, lead,
                                       out_dtype=x.dtype)
    y = qmatmul_w8a16(x2, w.q, w.scale, bias, backend=backend,
                      out_dtype=x.dtype)
    return y.reshape(*lead, N)


def qtensor_matmul_prequant(a_q: jnp.ndarray, a_s: jnp.ndarray, w: QTensor,
                            bias: Optional[jnp.ndarray], lead: tuple,
                            *, out_dtype=jnp.float32):
    """W8A8 matmul over an already-quantized activation (from
    ``quantize_input`` or a kernel's quantize-out epilogue). a_q [M, K]
    int8, a_s [M] fp32; returns [*lead, N] in ``out_dtype``."""
    from ..kernels.dispatch import serving_backend
    from ..kernels.qmatmul_w8a8.ops import qmatmul_w8a8

    assert w.mode == "w8a8", "prequantized inputs feed W8A8 weights"
    N = w.q.shape[-1]
    y = qmatmul_w8a8(a_q, w.q, a_s, w.scale, bias,
                     backend=serving_backend(), out_dtype=out_dtype)
    return y.reshape(*lead, N)
