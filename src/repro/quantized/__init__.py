from .qtensor import QTensor, quantize_param  # noqa: F401
from .ptq import (  # noqa: F401
    dequantize_params,
    quantize_for_serving,
    quantize_shapes,
    serving_summary,
)
