"""Open-loop Poisson load generation and SLO accounting for the async server.

**Open loop** is the part that matters: arrival times come from the trace
alone (Poisson, rate ``qps`` in requests per engine tick) and NEVER wait on
completions. A closed-loop driver (submit, wait, submit) self-throttles
under overload and hides the latency cliff; an open-loop one keeps offering
load the way a fleet of independent users does, which is what exposes the
knee in the goodput curve and drives the shedding/breaker machinery the
server exists for.

``summarize`` turns the client outcomes into the SLO view: TTFT and
per-token latency percentiles over ok requests, plus **goodput** — the rate
of requests that both finished ok AND met the SLO (TTFT and per-token
bounds). Goodput vs offered QPS is the fleet metric: throughput keeps
rising past saturation while goodput flattens and then falls.

All times are engine ticks (one decode step == one tick).
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .client import AsyncClient, ClientOutcome
from .scheduler import Request
from .server import AsyncServer
from .trace import synthetic_trace


def open_loop_trace(seed: int, n: int, qps: float, *, vocab_size: int,
                    prompt_lens: tuple = (4, 32), gen_lens: tuple = (4, 32),
                    deadline_slack: tuple = (0.0, 0.0),
                    priority_levels: int = 1) -> List[Request]:
    """A Poisson arrival trace offered at ``qps`` requests per engine tick
    (``mean_interarrival = 1/qps``). Thin wrapper over ``synthetic_trace``
    so benches sweep a rate, not an inter-arrival gap."""
    if qps <= 0:
        raise ValueError(f"qps must be > 0, got {qps}")
    return synthetic_trace(
        seed, n, vocab_size=vocab_size, prompt_lens=prompt_lens,
        gen_lens=gen_lens, mean_interarrival=1.0 / qps,
        deadline_slack=deadline_slack, priority_levels=priority_levels)


async def run_open_loop(server: AsyncServer, client: AsyncClient,
                        trace: Sequence[Request], *,
                        timeout: Optional[float] = None,
                        close: bool = True) -> List[ClientOutcome]:
    """Drive the trace through the server open-loop: one client coroutine
    per request, each sleeping until its own arrival tick regardless of how
    the others fare. Returns outcomes in rid order. ``close=False`` leaves
    the server running (caller composes more load afterwards)."""
    if server._task is None:
        server.start()
    tasks = [asyncio.ensure_future(client.run(req, timeout=timeout))
             for req in sorted(trace, key=lambda r: (r.arrival, r.rid))]
    outcomes = list(await asyncio.gather(*tasks))
    if close:
        await server.aclose()
    return sorted(outcomes, key=lambda o: o.rid)


@dataclasses.dataclass
class SLO:
    """A request meets the SLO iff it finished ok, its TTFT is within
    ``ttft`` ticks of arrival, and its mean per-token gap is at most
    ``per_token`` ticks."""

    ttft: float = 32.0
    per_token: float = 4.0

    def met(self, o: ClientOutcome) -> bool:
        if not o.ok or o.ttft is None:
            return False
        if o.ttft > self.ttft:
            return False
        if len(o.token_ticks) > 1:
            gaps = np.diff(o.token_ticks)
            if float(np.mean(gaps)) > self.per_token:
                return False
        return True


def _pct(values: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(values, dtype=np.float64), q)) \
        if len(values) else float("nan")


def summarize(outcomes: Sequence[ClientOutcome], *, slo: SLO,
              span_ticks: Optional[float] = None) -> dict:
    """SLO roll-up of one open-loop run.

    ``span_ticks`` (default: last arrival − first arrival, min 1) is the
    offered-load window, so ``offered_qps`` reflects the trace's actual
    realized rate rather than the nominal one. Completion rates
    (``completed_qps`` / ``goodput_qps``) divide by the SERVE span (first
    arrival → last completion) instead: past saturation a burst of arrivals
    is served over a much longer window than it was offered in, and that
    stretch is exactly the degradation the knee plot must show.
    """
    n = len(outcomes)
    ok = [o for o in outcomes if o.ok]
    met = [o for o in ok if slo.met(o)]
    arrivals = [o.arrival for o in outcomes]
    if span_ticks is None:
        span_ticks = max(1.0, max(arrivals) - min(arrivals)) if arrivals else 1.0
    finishes = [o.finished_tick for o in outcomes
                if o.finished_tick is not None]
    serve_span = max(1.0, span_ticks)
    if arrivals and finishes:
        serve_span = max(serve_span, max(finishes) - min(arrivals))
    ttfts = [o.ttft for o in ok if o.ttft is not None]
    gaps: List[float] = []
    for o in ok:
        if len(o.token_ticks) > 1:
            gaps.extend(float(g) for g in np.diff(o.token_ticks))
    statuses: dict = {}
    for o in outcomes:
        statuses[o.status] = statuses.get(o.status, 0) + 1
    return {
        "n_requests": n,
        "n_ok": len(ok),
        "n_slo_met": len(met),
        "statuses": statuses,
        "offered_span_ticks": span_ticks,
        "serve_span_ticks": serve_span,
        "offered_qps": n / span_ticks,
        "completed_qps": len(ok) / serve_span,
        "goodput_qps": len(met) / serve_span,
        "goodput_fraction": (len(met) / n) if n else 0.0,
        "ttft_p50": _pct(ttfts, 50),
        "ttft_p99": _pct(ttfts, 99),
        "per_token_p50": _pct(gaps, 50),
        "per_token_p99": _pct(gaps, 99),
        "mean_attempts": float(np.mean([o.attempts for o in outcomes]))
        if outcomes else 0.0,
    }
