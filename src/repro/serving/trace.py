"""Synthetic arrival schedules for trace replay (--trace) and benchmarks.

Lengths are drawn log-uniform so traces are realistically skewed (many short
requests, a few long ones — the regime where continuous batching beats the
static whole-batch loop), and arrivals are exponential with a configurable
mean inter-arrival gap (0 → closed system, everything queued at t=0).
"""
from __future__ import annotations

import math

import numpy as np

from .scheduler import Request


def synthetic_trace(
    seed: int,
    n: int,
    *,
    vocab_size: int,
    prompt_lens: tuple[int, int] = (4, 32),
    gen_lens: tuple[int, int] = (4, 32),
    mean_interarrival: float = 0.0,
    deadline_slack: tuple[float, float] = (0.0, 0.0),
    priority_levels: int = 1,
) -> list[Request]:
    """n requests with log-uniform prompt/gen lengths in the given inclusive
    ranges and Poisson arrivals (engine-step clock).

    ``deadline_slack=(lo, hi)`` with hi > 0 gives every request a deadline
    drawn uniformly from ``arrival + [lo, hi]`` engine ticks (lo must be
    > 0 when used — a deadline must land after the arrival); the default
    (0, 0) leaves deadlines off. ``priority_levels > 1`` assigns uniform
    random priorities in ``[0, priority_levels)`` — the preemption-victim
    classes."""
    rng = np.random.RandomState(seed)

    def log_uniform(lo: int, hi: int) -> int:
        u = rng.uniform(math.log(lo), math.log(hi + 1))
        return min(hi, max(lo, int(math.exp(u))))

    t = 0.0
    out = []
    for i in range(n):
        if mean_interarrival > 0:
            t += float(rng.exponential(mean_interarrival))
        P = log_uniform(*prompt_lens)
        G = log_uniform(*gen_lens)
        prompt = rng.randint(0, vocab_size, size=P).astype(np.int32)
        deadline = None
        if deadline_slack[1] > 0:
            deadline = t + float(rng.uniform(*deadline_slack))
        priority = int(rng.randint(0, priority_levels)) \
            if priority_levels > 1 else 0
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=G, arrival=t,
                           deadline=deadline, priority=priority))
    return out
