"""Synthetic arrival schedules for trace replay (--trace) and benchmarks.

Lengths are drawn log-uniform so traces are realistically skewed (many short
requests, a few long ones — the regime where continuous batching beats the
static whole-batch loop), and arrivals are exponential with a configurable
mean inter-arrival gap (0 → closed system, everything queued at t=0).
"""
from __future__ import annotations

import math

import numpy as np

from .scheduler import Request


def synthetic_trace(
    seed: int,
    n: int,
    *,
    vocab_size: int,
    prompt_lens: tuple[int, int] = (4, 32),
    gen_lens: tuple[int, int] = (4, 32),
    mean_interarrival: float = 0.0,
) -> list[Request]:
    """n requests with log-uniform prompt/gen lengths in the given inclusive
    ranges and Poisson arrivals (engine-step clock)."""
    rng = np.random.RandomState(seed)

    def log_uniform(lo: int, hi: int) -> int:
        u = rng.uniform(math.log(lo), math.log(hi + 1))
        return min(hi, max(lo, int(math.exp(u))))

    t = 0.0
    out = []
    for i in range(n):
        if mean_interarrival > 0:
            t += float(rng.exponential(mean_interarrival))
        P = log_uniform(*prompt_lens)
        G = log_uniform(*gen_lens)
        prompt = rng.randint(0, vocab_size, size=P).astype(np.int32)
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=G, arrival=t))
    return out
