"""Continuous-batching serving for QuantizedModel artifacts.

    engine = ServingEngine.from_quantized(qm, num_slots=8, max_len=128)
    results = engine.run(synthetic_trace(0, 20, vocab_size=qm.cfg.vocab_size))

See engine.py for the step loop, cache_pool.py for the slot lifecycle.
"""
from .cache_pool import CachePool, PoolExhausted
from .engine import RequestResult, ServingEngine, required_cache_len
from .scheduler import FIFOScheduler, PrefixIndex, Request
from .trace import synthetic_trace

__all__ = [
    "CachePool",
    "FIFOScheduler",
    "PoolExhausted",
    "PrefixIndex",
    "Request",
    "RequestResult",
    "ServingEngine",
    "required_cache_len",
    "synthetic_trace",
]
