"""Continuous-batching serving for QuantizedModel artifacts.

    engine = ServingEngine.from_quantized(qm, num_slots=8, max_len=128)
    results = engine.run(synthetic_trace(0, 20, vocab_size=qm.cfg.vocab_size))

Or stream per request through the overload-safe async front-end:

    server = AsyncServer(engine)
    client = AsyncClient(server, RetryPolicy(), seed=0)
    outcomes = asyncio.run(run_open_loop(server, client, trace))

See engine.py for the step loop, cache_pool.py for the slot lifecycle,
errors.py for the typed admission taxonomy, server.py/client.py/loadgen.py
for the async front-end (circuit breaker, shedding ladder, retry policy,
open-loop Poisson load), and chaos.py for the deterministic fault-injection
harness.
"""
from .cache_pool import CachePool, PoolExhausted
from .chaos import (
    ChaosReport,
    FaultInjector,
    FaultPlan,
    assert_unfaulted_parity,
    count_leaked_pages,
    run_chaos,
)
from .client import AsyncClient, ClientOutcome, RetryPolicy
from .engine import RequestResult, ServingEngine, required_cache_len
from .errors import (
    CircuitOpen,
    DeadlineExceeded,
    QueueFull,
    RequestCancelled,
    RequestTooLarge,
    ServerOverloaded,
    ServingError,
    taxonomy,
)
from .loadgen import SLO, open_loop_trace, run_open_loop, summarize
from .scheduler import FIFOScheduler, PrefixIndex, Request
from .server import AsyncServer, CircuitBreaker, RequestStream, ShedPolicy
from .trace import synthetic_trace

__all__ = [
    "AsyncClient",
    "AsyncServer",
    "CachePool",
    "ChaosReport",
    "CircuitBreaker",
    "CircuitOpen",
    "ClientOutcome",
    "DeadlineExceeded",
    "FIFOScheduler",
    "FaultInjector",
    "FaultPlan",
    "PoolExhausted",
    "PrefixIndex",
    "QueueFull",
    "Request",
    "RequestCancelled",
    "RequestResult",
    "RequestStream",
    "RequestTooLarge",
    "RetryPolicy",
    "SLO",
    "ServerOverloaded",
    "ServingEngine",
    "ServingError",
    "ShedPolicy",
    "assert_unfaulted_parity",
    "count_leaked_pages",
    "open_loop_trace",
    "required_cache_len",
    "run_chaos",
    "run_open_loop",
    "summarize",
    "synthetic_trace",
    "taxonomy",
]
