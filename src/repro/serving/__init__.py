"""Continuous-batching serving for QuantizedModel artifacts.

    engine = ServingEngine.from_quantized(qm, num_slots=8, max_len=128)
    results = engine.run(synthetic_trace(0, 20, vocab_size=qm.cfg.vocab_size))

See engine.py for the step loop, cache_pool.py for the slot lifecycle,
errors.py for the typed admission taxonomy, and chaos.py for the
deterministic fault-injection harness.
"""
from .cache_pool import CachePool, PoolExhausted
from .chaos import ChaosReport, FaultPlan, run_chaos
from .engine import RequestResult, ServingEngine, required_cache_len
from .errors import (
    DeadlineExceeded,
    QueueFull,
    RequestCancelled,
    RequestTooLarge,
    ServingError,
)
from .scheduler import FIFOScheduler, PrefixIndex, Request
from .trace import synthetic_trace

__all__ = [
    "CachePool",
    "ChaosReport",
    "DeadlineExceeded",
    "FIFOScheduler",
    "FaultPlan",
    "PoolExhausted",
    "PrefixIndex",
    "QueueFull",
    "Request",
    "RequestCancelled",
    "RequestResult",
    "RequestTooLarge",
    "ServingEngine",
    "ServingError",
    "required_cache_len",
    "run_chaos",
    "synthetic_trace",
]
