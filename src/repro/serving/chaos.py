"""Deterministic fault-injection chaos harness for the serving engine.

A ``FaultPlan`` is a seeded, fully host-side schedule of faults keyed by
engine-step index (the number of ``step()`` calls — deterministic for a
fixed engine config and trace):

  * **pool exhaustion** — ``CachePool.reserve_pages`` withholds free pages
    for a window of steps, forcing admission up the exhaustion ladder
    (LRU eviction → preemption → head-of-line blocking);
  * **arrival bursts** — extra requests injected mid-run (arrival = the
    clock at injection), spiking queue depth and page demand;
  * **cancellations** — ``engine.cancel(rid)`` at a chosen step;
  * **non-finite logits** — ``engine.inject_bad(rid)`` marks one row bad at
    its next host sync, exercising the NaN-quarantine path without
    poisoning real device state (a real NaN e2e is a separate test: the
    device-side detector is the same code path).

``run_chaos`` steps the engine manually, applies due faults before each
step, runs ``engine.check_invariants()`` (refcount conservation, free-list
consistency, no slot maps a freed page) after EVERY step, and returns a
``ChaosReport``. The core serving invariant under test: every request the
plan did NOT fault — including preempted-then-resumed ones — finishes with
tokens bit-identical to a fault-free run (``assert_unfaulted_parity``).

CLI (the CI ``chaos-smoke`` job)::

    PYTHONPATH=src python -m repro.serving.chaos --smoke --summary out.md
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .errors import ServingError
from .scheduler import Request


@dataclasses.dataclass
class FaultPlan:
    """Faults keyed by engine-step index. Build explicitly, or draw a mixed
    plan from a seed with ``FaultPlan.seeded``."""

    # (step, n_pages, hold_steps): reserve up to n_pages free pages at
    # `step`, return them hold_steps steps later
    exhaust: list = dataclasses.field(default_factory=list)
    # (step, rid): client cancellation issued before `step`
    cancels: list = dataclasses.field(default_factory=list)
    # (step, rid): non-finite logits injected for rid's row
    nans: list = dataclasses.field(default_factory=list)
    # (step, [Request, ...]): extra arrivals submitted before `step`
    bursts: list = dataclasses.field(default_factory=list)

    def faulted_rids(self) -> set:
        """Rids whose own tokens the plan corrupts or truncates (cancels +
        NaN injections). Exhaustion and bursts reshuffle scheduling only —
        requests they touch must STILL match the fault-free run."""
        return ({rid for _, rid in self.cancels}
                | {rid for _, rid in self.nans})

    @classmethod
    def seeded(cls, seed: int, rids: Sequence[int], n_steps: int, *,
               n_exhaust: int = 2, exhaust_pages: int = 4,
               exhaust_hold: int = 8, n_cancels: int = 2,
               n_nans: int = 2) -> "FaultPlan":
        """A mixed plan drawn deterministically from ``seed``: exhaustion
        windows at random steps, plus cancellations and NaN injections over
        disjoint random victims from ``rids`` (disjoint so each outcome is
        attributable to exactly one fault)."""
        rng = np.random.RandomState(seed)
        rids = list(rids)
        n_victims = min(len(rids), n_cancels + n_nans)
        victims = [rids[i] for i in
                   rng.choice(len(rids), size=n_victims, replace=False)]
        plan = cls()
        for _ in range(n_exhaust):
            plan.exhaust.append((int(rng.randint(0, max(1, n_steps))),
                                 exhaust_pages, exhaust_hold))
        for rid in victims[:n_cancels]:
            plan.cancels.append((int(rng.randint(0, max(1, n_steps))),
                                 int(rid)))
        for rid in victims[n_cancels:]:
            plan.nans.append((int(rng.randint(0, max(1, n_steps))),
                              int(rid)))
        return plan


@dataclasses.dataclass
class ChaosReport:
    results: dict            # rid → RequestResult (everything that finished)
    outcomes: dict           # rid → status string ("ok", "expired", ...)
    counts: dict             # status → count, plus engine fault counters
    steps: int               # engine steps driven
    leaked_pages: int        # pages neither free nor prefix-index-pinned
    shed_rids: list          # rids rejected at submit (QueueFull)

    def table(self) -> str:
        """Markdown fault-outcome table (the chaos-smoke step summary)."""
        lines = ["| outcome | count |", "|---|---|"]
        for key in ("ok", "preempted", "resumed", "shed", "cancelled",
                    "expired", "quarantined", "leaked_pages"):
            lines.append(f"| {key} | {self.counts.get(key, 0)} |")
        return "\n".join(lines)


class FaultInjector:
    """Replays one ``FaultPlan`` against one engine, keyed by step index.

    The harness half of the plan, factored out so two drivers share it
    bit-for-bit: ``run_chaos`` (manual step loop, below) and the async
    server's chaos-under-load scenario (``benchmarks/serve_slo.py``), which
    wires ``apply_due`` / ``release_due`` into ``AsyncServer``'s
    ``pre_step`` / ``post_step`` hooks. Call ``apply_due(step)`` BEFORE the
    engine step with that index, ``release_due(step)`` after; ``drain()``
    returns any still-held pages once the run is over (a plan whose last
    hold outlives the work must not count as a leak)."""

    def __init__(self, engine, plan: FaultPlan):
        self.engine = engine
        self._exhaust = sorted(plan.exhaust)
        self._cancels = sorted(plan.cancels)
        self._nans = sorted(plan.nans)
        self._bursts = sorted(plan.bursts, key=lambda e: e[0])
        self._holds: list = []    # (release_step, reserved_pages)
        self.shed_rids: list = []  # burst requests rejected at submit

    @staticmethod
    def _due(events: list, now: int) -> list:
        out = []
        while events and events[0][0] <= now:
            out.append(events.pop(0))
        return out

    def pending(self) -> bool:
        """Whether any fault has yet to fire or any hold to release."""
        return bool(self._exhaust or self._cancels or self._nans
                    or self._bursts or self._holds)

    def holds_active(self) -> bool:
        return bool(self._holds)

    def apply_due(self, step: int) -> None:
        """Fire every fault scheduled at or before ``step`` (pre-step)."""
        engine = self.engine
        for _, n_pages, hold in self._due(self._exhaust, step):
            if engine.paged:
                self._holds.append((step + hold,
                                    engine.pool.reserve_pages(n_pages)))
        for _, rid in self._due(self._cancels, step):
            engine.cancel(rid)
        for _, rid in self._due(self._nans, step):
            engine.inject_bad(rid)
        for _, reqs in self._due(self._bursts, step):
            for r in reqs:
                try:
                    # re-stamping arrival can push it past the request's
                    # deadline — __post_init__ raises ValueError then
                    engine.submit(dataclasses.replace(
                        r, arrival=engine.clock))
                except (ServingError, ValueError):
                    self.shed_rids.append(r.rid)

    def release_due(self, step: int) -> None:
        """Return reserved pages whose hold window ended (post-step)."""
        for release_step, pages in [h for h in self._holds
                                    if h[0] <= step]:
            self.engine.pool.release_reserved(pages)
            self._holds.remove((release_step, pages))

    def drain(self) -> None:
        """Release every remaining hold unconditionally (end of run)."""
        for _, pages in self._holds:
            self.engine.pool.release_reserved(pages)
        self._holds.clear()


def count_leaked_pages(engine) -> int:
    """Pages still referenced but neither slot-mapped-and-live nor pinned by
    the ``PrefixIndex`` after a drain — must be zero; anything else is a
    refcount leak. Contiguous (non-paged) engines trivially report 0."""
    if not engine.paged:
        return 0
    pinned = (set(engine.prefix_index.pages())
              if engine.prefix_index is not None else set())
    leaked = 0
    for p in range(engine.pool.num_pages):
        if engine.pool.page_ref(p) > 0 and p not in pinned:
            leaked += 1
    return leaked


def run_chaos(engine, requests: Sequence[Request], plan: FaultPlan, *,
              max_steps: int = 100_000) -> ChaosReport:
    """Serve ``requests`` under ``plan``, checking pool invariants after
    every step. Raises AssertionError the moment bookkeeping is violated;
    returns the report once the engine drains and all holds are released."""
    shed_rids: list = []
    for r in requests:
        try:
            engine.submit(r)
        except ServingError:
            shed_rids.append(r.rid)

    injector = FaultInjector(engine, plan)
    results: dict = {}
    step = 0
    base = dict(engine.stats)

    while (engine._inflight or engine._parked
           or engine.scheduler.pending() or injector.pending()):
        assert step < max_steps, (
            f"chaos run did not drain within {max_steps} steps"
        )
        injector.apply_due(step)
        engine.step()
        step += 1
        injector.release_due(step)
        engine.check_invariants()
        results.update(engine.results)
        engine.results = {}
    shed_rids.extend(injector.shed_rids)

    leaked = count_leaked_pages(engine)
    outcomes = {rid: res.status for rid, res in results.items()}
    for rid in shed_rids:
        outcomes[rid] = "shed"
    counts: dict = {}
    for status in outcomes.values():
        counts[status] = counts.get(status, 0) + 1
    for key in ("preempted", "resumed", "shed", "cancelled", "expired",
                "quarantined", "straggler_steps"):
        counts[key] = engine.stats[key] - base[key]
    counts["leaked_pages"] = leaked
    return ChaosReport(results=results, outcomes=outcomes, counts=counts,
                       steps=step, leaked_pages=leaked, shed_rids=shed_rids)


def assert_unfaulted_parity(report: ChaosReport, clean_results: dict,
                            faulted_rids: set) -> int:
    """Every request the plan did not fault must have finished ok with
    tokens bit-identical to the fault-free run — preempted-then-resumed
    requests included (resume re-prefills through the prefix-reuse path and
    must reproduce the identical continuation). Returns the number of
    requests compared."""
    compared = 0
    for rid, clean in clean_results.items():
        if rid in faulted_rids or rid in report.shed_rids:
            continue
        got = report.results.get(rid)
        assert got is not None, f"unfaulted request {rid} never finished"
        assert got.status == "ok", (
            f"unfaulted request {rid} finished with status {got.status!r}"
        )
        assert list(got.tokens) == list(clean.tokens), (
            f"unfaulted request {rid} diverged from the fault-free run:\n"
            f"  chaos: {got.tokens}\n  clean: {clean.tokens}"
        )
        compared += 1
    return compared


# ----------------------------------------------------------------- CLI
def _main(argv: Optional[Sequence[str]] = None) -> int:
    """Seeded chaos smoke over a real (smoke-dims) quantized model: mixed
    FaultPlan on a deliberately small paged pool, invariants checked every
    step, unfaulted parity asserted against a fault-free twin. Writes the
    fault-outcome markdown table to --summary (the CI step summary)."""
    import argparse
    import dataclasses as dc
    import pathlib

    import jax

    from ..configs import get_config
    from ..models import build_model
    from .engine import ServingEngine
    from .trace import synthetic_trace

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n", type=int, default=24, help="trace length")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dims (the CI chaos-smoke job)")
    ap.add_argument("--summary", type=pathlib.Path, default=None,
                    help="append the fault-outcome table to this file")
    ap.add_argument("--mesh", default=None,
                    help="mesh shape 'D,M' (needs D*M visible devices)")
    args = ap.parse_args(argv)

    cfg = dc.replace(get_config("qwen2-0.5b", smoke=True),
                     name="qwen2-chaos-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = None
    if args.mesh:
        from ..launch.mesh import make_production_mesh

        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_production_mesh(shape=shape)

    kw = dict(num_slots=4, max_len=48, prefill_chunk=8, decode_horizon=4,
              page_size=8, mesh=mesh)
    trace = synthetic_trace(args.seed, args.n, vocab_size=cfg.vocab_size,
                            prompt_lens=(4, 16), gen_lens=(4, 16),
                            mean_interarrival=1.0, priority_levels=2)
    # fault-free twin first (full page pool, no faults)
    clean = ServingEngine(model, params, cfg, **kw).run(
        [dc.replace(r) for r in trace])

    # chaos engine: starved page pool (2 slots' worth for 4 slots) so the
    # plan's reservations actually push admission up the ladder
    engine = ServingEngine(model, params, cfg,
                           num_pages=2 * (48 // 8), **kw)
    plan = FaultPlan.seeded(args.seed, [r.rid for r in trace], n_steps=40)
    report = run_chaos(engine, [dc.replace(r) for r in trace], plan)
    compared = assert_unfaulted_parity(report, clean, plan.faulted_rids())
    assert report.leaked_pages == 0, (
        f"{report.leaked_pages} pages leaked at drain"
    )

    table = report.table()
    print(f"chaos: {report.steps} steps, {compared} unfaulted requests "
          f"bit-identical, 0 leaked pages")
    print(table)
    if args.summary is not None:
        with open(args.summary, "a") as f:
            f.write("## chaos-smoke fault outcomes\n\n" + table + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
