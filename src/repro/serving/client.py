"""Async client for ``AsyncServer``: bounded retry with backoff + jitter.

The client is the other half of the ``errors.py`` contract: every rejection
the server raises carries a ``retryable`` flag, and the client branches on
NOTHING else — retryable errors (``QueueFull``, ``PoolExhausted``,
``CircuitOpen``, ``ServerOverloaded``) are retried with exponential backoff
and full jitter up to ``max_attempts``; non-retryable ones
(``RequestTooLarge``, ``RequestCancelled``, ``DeadlineExceeded``) fail
fast on the first raise. A request that is ADMITTED but expires inside the
engine is terminal too (the deadline doesn't reset), so an "expired" result
is never resubmitted.

Backoff sleeps ride ``server.wait_ticks`` — engine-tick time, not wall
clock — and the jitter RNG is seeded per ``(seed, rid)``, so a retry
schedule depends only on the trace and the seed, never on coroutine
interleaving. That determinism is what the chaos-under-load bench leans on.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .errors import ServingError
from .scheduler import Request
from .server import AsyncServer


@dataclasses.dataclass
class RetryPolicy:
    """Exponential backoff with full jitter, in engine ticks.

    Attempt ``k`` (0-based) failing retryably sleeps
    ``uniform(0, min(base * mult**k, max_backoff))`` ticks before attempt
    ``k+1`` — "full jitter" (AWS-style): the whole interval is randomized,
    which decorrelates a thundering herd far better than +/-epsilon jitter.
    """

    max_attempts: int = 4
    base_backoff: float = 4.0    # ticks
    multiplier: float = 2.0
    max_backoff: float = 64.0    # ticks, cap per sleep

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_backoff <= 0 or self.multiplier < 1 or self.max_backoff <= 0:
            raise ValueError("backoff parameters must be positive "
                             "(multiplier >= 1)")

    def backoff(self, attempt: int, rng: np.random.RandomState) -> float:
        cap = min(self.base_backoff * self.multiplier ** attempt,
                  self.max_backoff)
        return float(rng.uniform(0.0, cap))


@dataclasses.dataclass
class ClientOutcome:
    """What one request's full client-side lifecycle amounted to."""

    rid: int
    status: str                  # ok | expired | cancelled | quarantined |
    #                              shed (retries exhausted) | rejected
    #                              (non-retryable admission error)
    tokens: List[int]
    attempts: int                # submission attempts made (>= 1)
    arrival: float               # trace arrival tick
    first_token_tick: Optional[float]   # engine tick of token 0 (TTFT base)
    finished_tick: Optional[float]      # engine tick at terminal result
    token_ticks: List[float]     # engine tick per streamed token
    error: Optional[str] = None  # terminal error class name, if any

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, in ticks from arrival."""
        if self.first_token_tick is None:
            return None
        return self.first_token_tick - self.arrival


class AsyncClient:
    """Per-request retry loop over one ``AsyncServer``.

    ``run(request)`` waits for the request's arrival tick (open-loop: the
    arrival never depends on other requests' completions), then attempts
    admission under the ``RetryPolicy``, streaming tokens once admitted.
    """

    def __init__(self, server: AsyncServer,
                 policy: Optional[RetryPolicy] = None, *,
                 seed: int = 0):
        self.server = server
        self.policy = policy if policy is not None else RetryPolicy()
        self.seed = seed

    def _rng(self, rid: int) -> np.random.RandomState:
        # per-rid stream: jitter is independent of which coroutine runs first
        return np.random.RandomState((self.seed * 1000003 + rid) % 2**31)

    async def run(self, request: Request, *,
                  timeout: Optional[float] = None) -> ClientOutcome:
        await self.server.wait_until(request.arrival)
        rng = self._rng(request.rid)
        attempts = 0
        last_error: Optional[ServingError] = None
        while attempts < self.policy.max_attempts:
            # resubmission happens at the current clock, which may be past
            # the trace arrival — reflect that or engine admission
            # (arrival <= clock) would hold the request forever
            req = request
            if self.server.clock > req.arrival:
                new_arrival = self.server.clock
                deadline = req.deadline
                if deadline is not None and deadline <= new_arrival:
                    # the original deadline already passed while backing off;
                    # submitting would be rejected at validation — give up
                    break
                req = dataclasses.replace(req, arrival=new_arrival)
            try:
                stream = self.server.submit(req, timeout=timeout)
            except ServingError as e:
                attempts += 1
                last_error = e
                if not e.retryable or attempts >= self.policy.max_attempts:
                    break
                await self.server.wait_ticks(
                    self.policy.backoff(attempts - 1, rng))
                continue
            attempts += 1
            tokens: List[int] = []
            ticks: List[float] = []
            async for tick, tok in stream:
                tokens.append(tok)
                ticks.append(tick)
            result = stream.result
            return ClientOutcome(
                rid=request.rid, status=result.status, tokens=tokens,
                attempts=attempts, arrival=request.arrival,
                first_token_tick=ticks[0] if ticks else None,
                finished_tick=result.finished_at,
                token_ticks=ticks,
            )
        status = ("shed" if last_error is not None and last_error.retryable
                  else "rejected")
        return ClientOutcome(
            rid=request.rid, status=status, tokens=[], attempts=attempts,
            arrival=request.arrival, first_token_tick=None,
            finished_tick=self.server.clock, token_ticks=[],
            error=type(last_error).__name__ if last_error else None,
        )
