"""Request model + FIFO admission scheduler for the serving engine.

Admission is strictly first-come-first-served: a request is admitted only
when it is at the head of the queue, its arrival time has passed, and a
cache slot is free. Head-of-line order is the property the scheduler tests
pin down — later requests never jump an earlier one, even when the earlier
one needs a slot (or, paged, enough free pages) and they would fit
elsewhere. Page-aware admission peeks the head (``peek_ready``), sizes its
page demand against the pool, and only then pops — so a head blocked on
pages blocks the line exactly like a head blocked on a slot.

``PrefixIndex`` is the shared-prefix half of the paged cache: a radix-style
index (flattened trie — one entry per page-aligned token prefix) from
prompt prefixes to resident, refcounted pages. Prefill publishes each fully
covered prompt page; admission walks the index page by page and maps every
hit into the new slot's page table instead of recomputing it. Entries are
evicted LRU when admission runs short of fresh pages.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence

from .errors import QueueFull


@dataclasses.dataclass
class Request:
    """One generation request.

    prompt: token ids (any int sequence / 1-D array), length >= 1.
    max_new_tokens: number of tokens to generate (>= 1); the first one comes
        from the final prefill logits, the rest from decode steps.
    arrival: engine-clock timestamp (steps) before which the request is
        invisible to admission.
    deadline: engine-clock timestamp at/after which the request is expired —
        shed from the queue, or cut short in flight at the next step
        boundary (partial tokens are returned with status "expired").
        None (default) = no deadline.
    priority: preemption class (higher = more important; default 0). FIFO
        admission order is NOT priority-aware — priority only selects
        preemption victims: when the pool can't cover the FIFO head, a
        strictly-lower-priority in-flight request may be preempted (pages
        released, request parked host-side) to make room.
    """

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival: float = 0.0
    deadline: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")
        if self.deadline is not None and self.deadline <= self.arrival:
            raise ValueError(
                f"request {self.rid}: deadline {self.deadline} is not after "
                f"arrival {self.arrival}"
            )


class FIFOScheduler:
    def __init__(self, max_queue: Optional[int] = None):
        """``max_queue`` bounds the admission queue: ``submit`` beyond it
        raises the retryable ``QueueFull`` (back-pressure) instead of
        growing host memory without limit. None (default) = unbounded."""
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self._queue: collections.deque[Request] = collections.deque()
        # admission diagnostics (FIFO-order test anchor) — bounded so a
        # long-lived engine doesn't grow memory with every request served
        self.admitted_order: collections.deque[int] = collections.deque(
            maxlen=4096
        )

    def submit(self, request: Request) -> None:
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue):
            raise QueueFull(
                f"request {request.rid}: queue is at max_queue="
                f"{self.max_queue} — retry after the engine drains"
            )
        self._queue.append(request)

    def pending(self) -> int:
        return len(self._queue)

    def peek_arrival(self) -> Optional[float]:
        """Arrival time of the queue head (None when empty)."""
        return self._queue[0].arrival if self._queue else None

    def peek_ready(self, now: float) -> Optional[Request]:
        """The head request iff it has arrived, WITHOUT admitting it — the
        paged engine peeks first to size the head's page demand against the
        pool, then commits with ``pop_ready``. FIFO means nothing behind a
        not-yet-arrived (or not-yet-fitting) head is considered."""
        if self._queue and self._queue[0].arrival <= now:
            return self._queue[0]
        return None

    def pop_ready(self, now: float) -> Optional[Request]:
        """Admit the head request iff it has arrived; FIFO means nothing
        behind a not-yet-arrived head is considered."""
        if self._queue and self._queue[0].arrival <= now:
            req = self._queue.popleft()
            self.admitted_order.append(req.rid)
            return req
        return None

    def drop_head(self) -> Optional[Request]:
        """Remove the head WITHOUT recording an admission — the engine sheds
        an expired or cancelled head here (it never ran)."""
        return self._queue.popleft() if self._queue else None

    def remove(self, rid: int) -> Optional[Request]:
        """Remove a queued request by id (client cancellation before
        admission). O(queue) scan — runs at cancel time, not per step."""
        for req in self._queue:
            if req.rid == rid:
                self._queue.remove(req)
                return req
        return None


class PrefixIndex:
    """Radix-style prompt-prefix → page index for copy-on-write prefix reuse.

    A flattened trie: the key for depth ``i`` is the FULL token prefix
    through page boundary ``i+1`` (``tuple(prompt[: (i + 1) * page_size])``),
    so a page's KV content is a pure function of its key (K/V at position j
    depend on every token <= j — keying by the whole prefix, not the page's
    own tokens, is what makes cross-request reuse sound). ``publish`` pins
    each indexed page with a pool refcount, so index entries stay resident
    until evicted; ``lookup`` walks hits page by page and stops at the first
    miss. Eviction is LRU over lookups/publishes, skipping pages the current
    admission is about to share.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = int(page_size)
        self._map: collections.OrderedDict[tuple, int] = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._map)

    def pages(self) -> list:
        """Page ids currently pinned by the index (one per entry; a page
        indexed under several keys appears once per key) — the external-pin
        census ``ServingEngine.check_invariants`` audits refcounts against."""
        return list(self._map.values())

    def lookup(self, prompt: Sequence[int]) -> list:
        """Resident pages covering the longest indexed page-aligned prefix
        of ``prompt`` (possibly empty). Touches every hit for LRU."""
        pg = self.page_size
        toks = tuple(int(t) for t in prompt)
        pages: list = []
        while (len(pages) + 1) * pg <= len(toks):
            key = toks[: (len(pages) + 1) * pg]
            page = self._map.get(key)
            if page is None:
                break
            self._map.move_to_end(key)
            pages.append(page)
        if pages:
            self.hits += 1
        else:
            self.misses += 1
        return pages

    def publish(self, prompt: Sequence[int], pool, slot: int) -> int:
        """Index every prompt page of ``slot`` that the prompt covers
        completely (partial last pages are not shareable — their tail will
        be/was written by this request). Called at prefill completion, so
        concurrent requests behind the donor can already share; published
        pages are never written again by their owner (pad and decode writes
        both land at positions >= len(prompt)). Pages already indexed under
        the same key are skipped (first donor wins). Returns the number of
        newly indexed pages."""
        pg = self.page_size
        toks = tuple(int(t) for t in prompt)
        added = 0
        for i in range(len(toks) // pg):
            key = toks[: (i + 1) * pg]
            if key in self._map:
                self._map.move_to_end(key)
                continue
            page = pool.slot_page(slot, i)
            pool.ref_page(page)
            self._map[key] = page
            added += 1
        return added

    def evict_lru(self, pool, protect=()) -> bool:
        """Drop the least-recently-used entry whose page is not in
        ``protect`` (pages the in-flight admission is mapping) and release
        its pool reference. Returns False when nothing is evictable."""
        protect = set(protect)
        for key, page in self._map.items():
            if page in protect:
                continue
            del self._map[key]
            pool.deref_page(page)
            self.evictions += 1
            return True
        return False

    def clear(self, pool) -> None:
        """Drop every entry and release its page reference."""
        while self._map:
            _, page = self._map.popitem(last=False)
            pool.deref_page(page)
