"""Request model + FIFO admission scheduler for the serving engine.

Admission is strictly first-come-first-served: a request is admitted only
when it is at the head of the queue, its arrival time has passed, and a
cache slot is free. Head-of-line order is the property the scheduler tests
pin down — later requests never jump an earlier one, even when the earlier
one needs a slot and they would fit elsewhere.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass
class Request:
    """One generation request.

    prompt: token ids (any int sequence / 1-D array), length >= 1.
    max_new_tokens: number of tokens to generate (>= 1); the first one comes
        from the final prefill logits, the rest from decode steps.
    arrival: engine-clock timestamp (steps) before which the request is
        invisible to admission.
    """

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival: float = 0.0

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")


class FIFOScheduler:
    def __init__(self):
        self._queue: collections.deque[Request] = collections.deque()
        # admission diagnostics (FIFO-order test anchor) — bounded so a
        # long-lived engine doesn't grow memory with every request served
        self.admitted_order: collections.deque[int] = collections.deque(
            maxlen=4096
        )

    def submit(self, request: Request) -> None:
        self._queue.append(request)

    def pending(self) -> int:
        return len(self._queue)

    def peek_arrival(self) -> Optional[float]:
        """Arrival time of the queue head (None when empty)."""
        return self._queue[0].arrival if self._queue else None

    def pop_ready(self, now: float) -> Optional[Request]:
        """Admit the head request iff it has arrived; FIFO means nothing
        behind a not-yet-arrived head is considered."""
        if self._queue and self._queue[0].arrival <= now:
            req = self._queue.popleft()
            self.admitted_order.append(req.rid)
            return req
        return None
