"""Slot-based KV-cache pool for continuous batching.

The pool owns ONE batched per-slot cache (``models.LMModel.init_cache`` with
``per_slot=True``): each batch row is a serving slot with its own write
offset (``pos[i]``) and absolute slot positions (``kpos[i]``). Allocation
hands out the lowest free slot (deterministic — batch composition, and hence
the parity tests, don't depend on dict ordering) and resets only the slot's
*bookkeeping* (kpos → -1, pos → 0): stale K/V payload is left in place
because every masked key contributes an exact 0 after the NEG_INF softmax,
so recycled slots are bit-identical to fresh ones.
"""
from __future__ import annotations


class PoolExhausted(RuntimeError):
    """allocate() called with no free slot."""


class CachePool:
    def __init__(self, model, num_slots: int, max_len: int, dtype=None):
        import jax.numpy as jnp

        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = num_slots
        self.cache: dict = model.init_cache(
            num_slots, max_len, dtype=(jnp.float32 if dtype is None else dtype),
            per_slot=True,
        )
        # the model may shrink the ring below the requested length (sliding-
        # window attention: S = min(max_len, window)); capacity checks must
        # see the REAL ring size or padded prefill chunks could wrap and
        # clobber keys that are still inside the attention window
        self.max_len = int(self.cache["kpos"].shape[-1])
        self._free = set(range(num_slots))
        self._allocated: set = set()

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def is_allocated(self, slot: int) -> bool:
        return slot in self._allocated

    def all_free(self) -> bool:
        return not self._allocated and len(self._free) == self.num_slots

    # ----------------------------------------------------------- lifecycle
    def allocate(self, reset: bool = True) -> int:
        """Claim the lowest free slot and reset its bookkeeping.

        ``reset=False`` skips the two eager ``.at[].set`` dispatches and
        leaves the slot's stale kpos/pos in place; the caller then owns the
        reset (the engine's fast path folds it into the first jitted prefill
        chunk via a ``fresh`` row mask, so admission costs zero dispatches).
        Until that reset commits, the slot must only ride along as a masked
        inactive row.
        """
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} slots allocated — admit after release()"
            )
        slot = min(self._free)
        self._free.remove(slot)
        self._allocated.add(slot)
        if reset:
            self.cache = {
                **self.cache,
                "kpos": self.cache["kpos"].at[slot].set(-1),
                "pos": self.cache["pos"].at[slot].set(0),
            }
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._allocated:
            raise ValueError(
                f"slot {slot} is not allocated (double free, or never claimed)"
            )
        self._allocated.remove(slot)
        self._free.add(slot)
