"""Slot-based KV-cache pool for continuous batching — contiguous or paged.

**Contiguous mode** (``page_size=None``): the pool owns ONE batched per-slot
cache (``models.LMModel.init_cache`` with ``per_slot=True``): each batch row
is a serving slot with its own write offset (``pos[i]``) and absolute slot
positions (``kpos[i]``). Allocation hands out the lowest free slot
(deterministic — batch composition, and hence the parity tests, don't depend
on dict ordering) and resets only the slot's *bookkeeping* (kpos → -1,
pos → 0): stale K/V payload is left in place because every masked key
contributes an exact 0 after the NEG_INF softmax, so recycled slots are
bit-identical to fresh ones.

**Paged mode** (``page_size=pg``): every KV payload leaf is re-laid-out as a
fixed page pool ``[L, num_pages, pg, ...]`` (int8 payload, its scales, and
the §4.2 ``v_err`` correction leaves page *together* — one page id covers a
position's whole quantized state), plus a per-slot ``page_table [B, S/pg]``
that maps ring positions onto physical pages. Slots allocate and release in
page units, so a short request stops paying for ``max_len`` positions, and
pages are **refcounted**: the scheduler's prefix index can pin a retired
prompt's pages and hand them to later requests (shared-prefix reuse). A
shared page (refcount > 1) is copied before its new owner writes into it
(copy-on-write, ``ensure_writable``) — reads are free, writes pay one page
copy. The ``kpos``/``pos`` bookkeeping stays dense (it is the validity
oracle for BOTH layouts: a gathered page position is live iff its kpos
entry is >= 0, which is exactly the masking the attention path already
applies).

Bookkeeping writes at admission are fused into ONE dispatch (``_reset_fn`` /
``_admit_fn`` below) instead of one eager ``.at[].set`` per leaf.
"""
from __future__ import annotations

import heapq
from typing import Optional, Sequence

# re-exported here for back-compat (PoolExhausted predates the taxonomy and
# was born in this module); it now lives in the serving error taxonomy with
# a ``retryable`` flag
from .errors import PoolExhausted


# bookkeeping leaves excluded from the payload byte accounting; anything
# else integer-typed (except the int8 payload itself) is an unrecognized
# bookkeeping leaf and must be added here explicitly
KNOWN_BOOKKEEPING = frozenset({"kpos", "pos", "page_table"})


def _reset_impl(kpos, pos, slot, reuse):
    """Fused slot-bookkeeping reset: kpos[slot] = [0..reuse) then -1, and
    pos[slot] = reuse, in ONE dispatch (reuse=0 is the plain fresh reset;
    reuse=R seeds a slot whose first R positions arrive via shared pages)."""
    import jax.numpy as jnp

    S = kpos.shape[-1]
    idx = jnp.arange(S, dtype=jnp.int32)
    row = jnp.where(idx < reuse, idx, -1)
    return kpos.at[slot].set(row), pos.at[slot].set(reuse)


def _admit_impl(kpos, pos, table, slot, reuse, row):
    """Paged admission: the fused reset PLUS the slot's page-table row, still
    one dispatch."""
    kpos, pos = _reset_impl(kpos, pos, slot, reuse)
    return kpos, pos, table.at[slot].set(row)


def _cow_impl(payload: dict, table, src, dst, slot, idx):
    """Copy page ``src`` → ``dst`` across every payload leaf (int8 + scales
    + v_err page together) and point ``table[slot, idx]`` at the copy — the
    copy-on-write step, fused into one dispatch."""
    import jax

    out = {
        k: v.at[:, dst].set(
            jax.lax.dynamic_index_in_dim(v, src, 1, keepdims=False)
        )
        for k, v in payload.items()
    }
    return out, table.at[slot, idx].set(dst)


class CachePool:
    def __init__(self, model, num_slots: int, max_len: int, dtype=None,
                 kv_bits=None, mesh=None, page_size: Optional[int] = None,
                 num_pages: Optional[int] = None):
        """``dtype`` defaults to the model's activation compute dtype (halves
        cache bytes for bf16 models vs the old fp32 default); pass an explicit
        dtype to override. ``kv_bits=8`` selects the int8 pooled cache (int8
        payload + per-token/per-head scales), ``kv_bits=16`` forces fp, None
        follows ``model.cfg.kv_cache_bits``. ``mesh`` places the pool on a
        device mesh under the serve-mode cache specs — ``self.shardings``
        then holds the per-leaf NamedShardings the engine pins as jit
        out_shardings so the pool stays sharded across steps.

        ``page_size`` switches the pool to the paged layout (see module
        docstring); ``num_pages`` sizes the page pool (default: full
        capacity, ``num_slots * ceil(ring / page_size)`` — every slot can
        map a complete ring; smaller pools trade worst-case capacity for
        memory and rely on prefix sharing to admit more slots)."""
        import jax
        import jax.numpy as jnp

        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = num_slots
        if dtype is None:
            cfg = getattr(model, "cfg", None)
            dtype = jnp.dtype(cfg.dtype) if cfg is not None else jnp.float32
        kw = {} if kv_bits is None else {"kv_bits": kv_bits}
        self.cache: dict = model.init_cache(
            num_slots, max_len, dtype=dtype, per_slot=True, **kw
        )
        self.kv_bits = 8 if "k_scale" in self.cache else 16
        # the model may shrink the ring below the requested length (sliding-
        # window attention: S = min(max_len, window)); capacity checks must
        # see the REAL ring size or padded prefill chunks could wrap and
        # clobber keys that are still inside the attention window
        self.max_len = int(self.cache["kpos"].shape[-1])

        self.page_size = None if page_size is None else int(page_size)
        self.num_pages = 0
        self.pages_per_slot = 0
        if self.page_size is not None:
            pg = self.page_size
            if not 1 <= pg <= self.max_len:
                raise ValueError(
                    f"page_size must be in [1, ring={self.max_len}], got {pg}"
                )
            self.pages_per_slot = -(-self.max_len // pg)
            self.num_pages = (num_slots * self.pages_per_slot
                              if num_pages is None else int(num_pages))
            if self.num_pages < 1:
                raise ValueError(f"num_pages must be >= 1, got {self.num_pages}")
            paged = {}
            for name, leaf in self.cache.items():
                if name in ("kpos", "pos"):       # dense bookkeeping
                    paged[name] = leaf
                else:                             # [L, B, S, ...] → [L, NP, pg, ...]
                    paged[name] = jnp.zeros(
                        (leaf.shape[0], self.num_pages, pg) + leaf.shape[3:],
                        leaf.dtype,
                    )
            paged["page_table"] = jnp.full(
                (num_slots, self.pages_per_slot), -1, jnp.int32
            )
            self.cache = paged
            self._free_pages: list = list(range(self.num_pages))
            heapq.heapify(self._free_pages)
            self._page_ref = [0] * self.num_pages
            self._slot_pages: dict[int, list] = {}
            # pages withheld from allocation (chaos fault injection): ref 0,
            # not in the free heap, owned by the reserver
            self._reserved: set = set()
            self.cow_copies = 0

        self.mesh = mesh
        self.shardings = None
        if mesh is not None:
            from ..sharding import named_shardings, serve_cache_pspecs

            specs = serve_cache_pspecs(
                jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.cache
                ),
                mesh,
            )
            self.shardings = named_shardings(specs, mesh)
            self.cache = jax.device_put(self.cache, self.shardings)

        self._free = set(range(num_slots))
        self._allocated: set = set()
        # slots whose bookkeeping reset was deferred (allocate(reset=False))
        # and has not been committed by the engine's first prefill yet — a
        # release before that commit must not leak stale kpos/pos to the
        # next claimant (the slot-lifecycle bugfix sweep)
        self._pending_reset: set = set()
        # fused bookkeeping updates: instance attributes so tests can shim
        # them with counting wrappers (the PR-3 dispatch-count idiom)
        self._reset_fn = jax.jit(_reset_impl, donate_argnums=(0, 1))
        self._admit_fn = jax.jit(_admit_impl, donate_argnums=(0, 1, 2))
        self._cow_fn = jax.jit(_cow_impl, donate_argnums=(0, 1))

    # ------------------------------------------------------------- queries
    @property
    def paged(self) -> bool:
        return self.page_size is not None

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages) if self.paged else 0

    def is_allocated(self, slot: int) -> bool:
        return slot in self._allocated

    def page_ref(self, page: int) -> int:
        return self._page_ref[page]

    def slot_page(self, slot: int, idx: int) -> int:
        """Physical page backing ring positions [idx*pg, (idx+1)*pg)."""
        return self._slot_pages[slot][idx]

    def slot_pages(self, slot: int) -> list:
        return list(self._slot_pages.get(slot, ()))

    def _payload_items(self):
        for name, leaf in self.cache.items():
            if name in KNOWN_BOOKKEEPING:
                continue
            yield name, leaf

    def cache_bytes(self) -> int:
        """Resident payload bytes of the whole pool (bookkeeping excluded) —
        the number the capacity benchmarks hold equal across layouts."""
        import jax.numpy as jnp

        total = 0
        for name, leaf in self._payload_items():
            if (jnp.issubdtype(leaf.dtype, jnp.integer)
                    and leaf.dtype != jnp.int8):
                raise ValueError(
                    f"cache leaf {name!r} has bookkeeping-like dtype "
                    f"{leaf.dtype} but is not a recognized bookkeeping leaf "
                    f"({sorted(KNOWN_BOOKKEEPING)}); add it to "
                    f"KNOWN_BOOKKEEPING or give it a payload dtype"
                )
            total += leaf.size * leaf.dtype.itemsize
        return total

    def bytes_per_slot(self) -> int:
        """KV bytes one full-length slot owns (payload + scales + correction
        leaves; bookkeeping — 4 B/position either way — is excluded): the
        roofline's cache-stream term per request. Counts EVERY non-bookkeeping
        leaf, so new slot state (SSM columns, enc-dec cross caches) is never
        silently undercounted; an integer leaf that is neither int8 payload
        nor known bookkeeping raises instead of miscounting. In paged mode
        this is the worst case (a slot mapping its complete ring); requests
        shorter than the ring pay proportionally fewer pages."""
        total = self.cache_bytes()
        if self.paged:
            return (total // self.num_pages) * self.pages_per_slot
        return total // self.num_slots

    def all_free(self) -> bool:
        return not self._allocated and len(self._free) == self.num_slots

    def pages_needed(self, need: int, reuse_len: int = 0) -> int:
        """Fresh pages an admission must find for a request spanning ``need``
        ring positions with its first ``reuse_len`` arriving via shared
        pages: the unshared span, plus one spare when ``reuse_len`` splits a
        page (that shared page is copied-on-write before the slot's first
        prefill chunk writes into it)."""
        pg = self.page_size
        n_pages = -(-need // pg)
        n_shared = -(-reuse_len // pg)
        return n_pages - n_shared + (1 if reuse_len % pg else 0)

    # ----------------------------------------------------------- lifecycle
    def allocate(self, reset: bool = True) -> int:
        """Claim the lowest free slot and reset its bookkeeping (one fused
        dispatch — kpos and pos update together).

        ``reset=False`` skips the dispatch and leaves the slot's stale
        kpos/pos in place; the caller then owns the reset (the engine's fast
        path folds it into the first jitted prefill chunk via a ``fresh``
        row mask, so admission costs zero dispatches). Until that reset
        commits, the slot must only ride along as a masked inactive row —
        the pool tracks the pending reset and repairs it on release, so a
        slot released early never hands stale bookkeeping to its next
        claimant."""
        if self.paged:
            raise RuntimeError(
                "paged pools allocate in page units — use allocate_pages()"
            )
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} slots allocated — admit after release()"
            )
        slot = min(self._free)
        self._free.remove(slot)
        self._allocated.add(slot)
        if reset:
            self._reset_slot(slot)
        else:
            self._pending_reset.add(slot)
        return slot

    def _reset_slot(self, slot: int, reuse: int = 0) -> None:
        import jax.numpy as jnp

        kpos, pos = self._reset_fn(
            self.cache["kpos"], self.cache["pos"],
            jnp.int32(slot), jnp.int32(reuse),
        )
        self.cache = {**self.cache, "kpos": kpos, "pos": pos}
        self._pending_reset.discard(slot)

    def note_reset_committed(self, slot: int) -> None:
        """The engine committed a deferred (fresh-mask) reset inside a jitted
        prefill — the slot's bookkeeping is clean from here on."""
        self._pending_reset.discard(slot)

    def allocate_pages(self, need: int, shared: Sequence[int] = (),
                       reuse_len: int = 0) -> int:
        """Paged admission: claim the lowest free slot, map ``shared`` pages
        (refcounted — they carry the request's first ``reuse_len`` positions)
        followed by fresh pages up to ``ceil(need / page_size)``, install the
        page-table row + the kpos/pos seed in ONE fused dispatch, and
        copy-on-write the boundary page when ``reuse_len`` splits it. The
        whole admission is atomic: a ``PoolExhausted`` (no slot / not enough
        fresh pages) leaves the pool untouched."""
        import jax.numpy as jnp
        import numpy as np

        if not self.paged:
            raise RuntimeError("allocate_pages() needs a paged pool "
                               "(construct with page_size=...)")
        pg = self.page_size
        if not 0 <= reuse_len < need:
            raise ValueError(f"reuse_len must be in [0, need={need}), "
                             f"got {reuse_len}")
        n_pages = -(-need // pg)
        n_shared = -(-reuse_len // pg)
        if len(shared) != n_shared:
            raise ValueError(
                f"reuse_len={reuse_len} (page_size {pg}) maps {n_shared} "
                f"shared pages but {len(shared)} were given"
            )
        if n_pages > self.pages_per_slot:
            raise ValueError(
                f"request needs {n_pages} pages but a slot table holds "
                f"{self.pages_per_slot} (ring {self.max_len}, page {pg})"
            )
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} slots allocated — admit after release()"
            )
        fresh_needed = self.pages_needed(need, reuse_len)
        if fresh_needed > len(self._free_pages):
            raise PoolExhausted(
                f"need {fresh_needed} fresh pages but only "
                f"{len(self._free_pages)} of {self.num_pages} are free — "
                f"release slots or evict prefix-index pages first"
            )
        slot = min(self._free)
        self._free.remove(slot)
        self._allocated.add(slot)
        pages = list(shared)
        for p in pages:
            self._page_ref[p] += 1
        for _ in range(n_pages - n_shared):
            p = heapq.heappop(self._free_pages)
            self._page_ref[p] = 1
            pages.append(p)
        self._slot_pages[slot] = pages
        row = np.full((self.pages_per_slot,), -1, np.int32)
        row[:n_pages] = pages
        kpos, pos, table = self._admit_fn(
            self.cache["kpos"], self.cache["pos"], self.cache["page_table"],
            jnp.int32(slot), jnp.int32(reuse_len), jnp.asarray(row),
        )
        self.cache = {**self.cache, "kpos": kpos, "pos": pos,
                      "page_table": table}
        self._pending_reset.discard(slot)
        if reuse_len % pg:
            # the slot's first prefill chunk starts at reuse_len, inside the
            # last shared page — copy it now (the reserved spare above)
            self.ensure_writable(slot, reuse_len, reuse_len + 1)
        return slot

    def ensure_writable(self, slot: int, start: int, stop: int) -> int:
        """Copy-on-write: any page of ``slot`` overlapping ring positions
        [start, stop) that is shared (refcount > 1) is copied into a fresh
        page — payload, scales, and ``v_err`` together, one fused dispatch
        per page — and the slot's table entry is repointed. Returns the
        number of pages copied. After admission the engine's writes only
        ever touch exclusively-owned pages (the boundary page is copied at
        admission), so this is a no-op on the serving hot path."""
        import jax.numpy as jnp

        pg = self.page_size
        pages = self._slot_pages[slot]
        copied = 0
        for idx in range(start // pg, min(-(-stop // pg), len(pages))):
            src = pages[idx]
            if self._page_ref[src] <= 1:
                continue
            if not self._free_pages:
                raise PoolExhausted(
                    f"copy-on-write of slot {slot} page {idx} needs a free "
                    f"page but all {self.num_pages} are in use"
                )
            dst = heapq.heappop(self._free_pages)
            payload = dict(self._payload_items())
            payload, table = self._cow_fn(
                payload, self.cache["page_table"],
                jnp.int32(src), jnp.int32(dst),
                jnp.int32(slot), jnp.int32(idx),
            )
            self.cache = {**self.cache, **payload, "page_table": table}
            self._page_ref[src] -= 1
            self._page_ref[dst] = 1
            pages[idx] = dst
            self.cow_copies += 1
            copied += 1
        return copied

    def ref_page(self, page: int) -> None:
        """Take a reference on a live page (the prefix index pinning a
        published prompt page)."""
        if self._page_ref[page] < 1:
            raise ValueError(f"page {page} is free — cannot pin it")
        self._page_ref[page] += 1

    def deref_page(self, page: int) -> None:
        self._page_ref[page] -= 1
        if self._page_ref[page] < 0:
            raise ValueError(f"page {page} over-released")
        if self._page_ref[page] == 0:
            heapq.heappush(self._free_pages, page)

    def release(self, slot: int) -> None:
        if slot not in self._allocated:
            raise ValueError(
                f"slot {slot} is not allocated (double free, or never claimed)"
            )
        if slot in self._pending_reset:
            # released before its deferred fresh-mask reset committed: the
            # slot still carries the PREVIOUS occupant's kpos/pos. Repair it
            # here so the next claimant (even another reset=False admission)
            # starts from clean bookkeeping.
            self._reset_slot(slot)
        self._allocated.remove(slot)
        self._free.add(slot)
        if self.paged:
            for p in self._slot_pages.pop(slot, ()):
                self.deref_page(p)

    # --------------------------------------------- fault injection support
    def reserve_pages(self, n: int) -> list:
        """Withhold up to ``n`` free pages from allocation (the chaos
        harness's pool-exhaustion fault: the pages vanish from the free heap
        without any slot or refcount owning them). Returns the reserved page
        ids — hand them back via ``release_reserved``. Reserving fewer than
        ``n`` (even zero) is not an error: exhaustion injection takes what
        it can get."""
        if not self.paged:
            raise RuntimeError("reserve_pages() needs a paged pool")
        got = []
        while self._free_pages and len(got) < n:
            p = heapq.heappop(self._free_pages)
            self._reserved.add(p)
            got.append(p)
        return got

    def release_reserved(self, pages: Sequence[int]) -> None:
        """Return pages taken by ``reserve_pages`` to the free heap."""
        for p in pages:
            if p not in self._reserved:
                raise ValueError(f"page {p} is not reserved")
            self._reserved.remove(p)
            heapq.heappush(self._free_pages, p)

    # ------------------------------------------------------------ auditing
    def check_invariants(self, external_refs=None) -> None:
        """Audit the pool's host bookkeeping; raises AssertionError on the
        first violation. Cheap (pure host state — no device sync), so the
        chaos harness runs it after EVERY engine step, and
        ``REPRO_POOL_CHECK=1`` turns it on per-step in any test run.

        Checked:
          * slot partition — ``_free`` and ``_allocated`` partition the slot
            range; ``_pending_reset`` only tracks allocated slots;
          * page partition (paged) — every page is exactly one of free
            (ref 0, in the free heap once), reserved (ref 0, chaos-held),
            or live (ref >= 1);
          * refcount conservation (paged) — a live page's refcount equals
            the number of slot-table mappings plus its external pins
            (``external_refs``: page → pin count, e.g. the engine's
            prefix-index entries plus any chaos reservations); no slot maps
            a freed page.
        """
        n = self.num_slots
        assert self._free | self._allocated == set(range(n)), (
            f"slots leaked: free={sorted(self._free)} "
            f"allocated={sorted(self._allocated)} don't cover 0..{n - 1}"
        )
        assert not (self._free & self._allocated), (
            f"slots both free and allocated: "
            f"{sorted(self._free & self._allocated)}"
        )
        assert self._pending_reset <= self._allocated, (
            f"pending resets on non-allocated slots: "
            f"{sorted(self._pending_reset - self._allocated)}"
        )
        if not self.paged:
            return
        assert set(self._slot_pages) == self._allocated, (
            f"slot-page tables {sorted(self._slot_pages)} != allocated "
            f"slots {sorted(self._allocated)}"
        )
        free_counts: dict[int, int] = {}
        for p in self._free_pages:
            free_counts[p] = free_counts.get(p, 0) + 1
        expected = dict(external_refs or {})
        for pages in self._slot_pages.values():
            for p in pages:
                expected[p] = expected.get(p, 0) + 1
        for p in range(self.num_pages):
            ref = self._page_ref[p]
            in_free = free_counts.get(p, 0)
            if p in self._reserved:
                assert ref == 0 and in_free == 0, (
                    f"reserved page {p} has ref {ref}, "
                    f"free-heap count {in_free}"
                )
                assert expected.get(p, 0) == 0, (
                    f"reserved page {p} is mapped/pinned "
                    f"({expected[p]} holders)"
                )
            elif ref == 0:
                assert in_free == 1, (
                    f"page {p} has ref 0 but appears {in_free} times in the "
                    f"free heap (want exactly 1)"
                )
                assert expected.get(p, 0) == 0, (
                    f"freed page {p} is still mapped/pinned "
                    f"({expected[p]} holders)"
                )
            else:
                assert in_free == 0, (
                    f"live page {p} (ref {ref}) is in the free heap"
                )
                assert ref == expected.get(p, 0), (
                    f"page {p} refcount {ref} != {expected.get(p, 0)} "
                    f"(slot mappings + external pins) — refcount leak"
                )
