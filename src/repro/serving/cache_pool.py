"""Slot-based KV-cache pool for continuous batching.

The pool owns ONE batched per-slot cache (``models.LMModel.init_cache`` with
``per_slot=True``): each batch row is a serving slot with its own write
offset (``pos[i]``) and absolute slot positions (``kpos[i]``). Allocation
hands out the lowest free slot (deterministic — batch composition, and hence
the parity tests, don't depend on dict ordering) and resets only the slot's
*bookkeeping* (kpos → -1, pos → 0): stale K/V payload is left in place
because every masked key contributes an exact 0 after the NEG_INF softmax,
so recycled slots are bit-identical to fresh ones.
"""
from __future__ import annotations


class PoolExhausted(RuntimeError):
    """allocate() called with no free slot."""


class CachePool:
    def __init__(self, model, num_slots: int, max_len: int, dtype=None,
                 kv_bits=None, mesh=None):
        """``dtype`` defaults to the model's activation compute dtype (halves
        cache bytes for bf16 models vs the old fp32 default); pass an explicit
        dtype to override. ``kv_bits=8`` selects the int8 pooled cache (int8
        payload + per-token/per-head scales), ``kv_bits=16`` forces fp, None
        follows ``model.cfg.kv_cache_bits``. ``mesh`` places the pool on a
        device mesh under the serve-mode cache specs (slots over "data", KV
        heads over "model", scale/v_err leaves following their payload) —
        ``self.shardings`` then holds the per-leaf NamedShardings the engine
        pins as jit out_shardings so the pool stays sharded across steps."""
        import jax
        import jax.numpy as jnp

        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = num_slots
        if dtype is None:
            cfg = getattr(model, "cfg", None)
            dtype = jnp.dtype(cfg.dtype) if cfg is not None else jnp.float32
        kw = {} if kv_bits is None else {"kv_bits": kv_bits}
        self.cache: dict = model.init_cache(
            num_slots, max_len, dtype=dtype, per_slot=True, **kw
        )
        self.kv_bits = 8 if "k_scale" in self.cache else 16
        self.mesh = mesh
        self.shardings = None
        if mesh is not None:
            from ..sharding import named_shardings, serve_cache_pspecs

            specs = serve_cache_pspecs(
                jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.cache
                ),
                mesh,
            )
            self.shardings = named_shardings(specs, mesh)
            self.cache = jax.device_put(self.cache, self.shardings)
        # the model may shrink the ring below the requested length (sliding-
        # window attention: S = min(max_len, window)); capacity checks must
        # see the REAL ring size or padded prefill chunks could wrap and
        # clobber keys that are still inside the attention window
        self.max_len = int(self.cache["kpos"].shape[-1])
        self._free = set(range(num_slots))
        self._allocated: set = set()

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def is_allocated(self, slot: int) -> bool:
        return slot in self._allocated

    def bytes_per_slot(self) -> int:
        """KV bytes one slot owns (payload + scales + correction leaves;
        the kpos/pos bookkeeping, 4 B/position either way, is excluded) —
        the roofline's cache-stream term per request."""
        kv = ("k", "v", "k_scale", "v_scale", "v_err")
        total = sum(v.size * v.dtype.itemsize
                    for k, v in self.cache.items() if k in kv)
        return total // self.num_slots

    def all_free(self) -> bool:
        return not self._allocated and len(self._free) == self.num_slots

    # ----------------------------------------------------------- lifecycle
    def allocate(self, reset: bool = True) -> int:
        """Claim the lowest free slot and reset its bookkeeping.

        ``reset=False`` skips the two eager ``.at[].set`` dispatches and
        leaves the slot's stale kpos/pos in place; the caller then owns the
        reset (the engine's fast path folds it into the first jitted prefill
        chunk via a ``fresh`` row mask, so admission costs zero dispatches).
        Until that reset commits, the slot must only ride along as a masked
        inactive row.
        """
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} slots allocated — admit after release()"
            )
        slot = min(self._free)
        self._free.remove(slot)
        self._allocated.add(slot)
        if reset:
            self.cache = {
                **self.cache,
                "kpos": self.cache["kpos"].at[slot].set(-1),
                "pos": self.cache["pos"].at[slot].set(0),
            }
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._allocated:
            raise ValueError(
                f"slot {slot} is not allocated (double free, or never claimed)"
            )
        self._allocated.remove(slot)
        self._free.add(slot)
