"""Typed error taxonomy for serving admission and fault handling.

Every error the serving layer raises at its public surface derives from
``ServingError`` and carries a ``retryable`` flag — the contract a client
(or ``launch/serve.py``) branches on: a retryable rejection (queue full,
pool momentarily exhausted) is back-pressure and should be retried after a
delay; a non-retryable one (request can never fit) is a hard client error.

Back-compat is deliberate: the pre-taxonomy engine raised bare
``ValueError`` / ``RuntimeError``, and tests (plus any external caller)
match on those — so ``RequestTooLarge`` IS-A ``ValueError`` and
``PoolExhausted`` IS-A ``RuntimeError``. ``except ServingError`` catches
the whole taxonomy; the old handlers keep working unchanged.
"""
from __future__ import annotations


class ServingError(Exception):
    """Base of the serving taxonomy. ``retryable`` tells the client whether
    the same request can succeed later without modification."""

    retryable = False


class RequestTooLarge(ServingError, ValueError):
    """The request can NEVER be admitted: its ring/page demand exceeds the
    engine's capacity outright. Not retryable — shrink the request or build
    a bigger engine."""


class QueueFull(ServingError, RuntimeError):
    """The bounded scheduler queue is at ``max_queue`` — admission
    back-pressure. Retryable: resubmit after the queue drains."""

    retryable = True


class PoolExhausted(ServingError, RuntimeError):
    """No free slot (or, paged, not enough free pages) right now — the
    transient end of the exhaustion ladder. Retryable by nature, though the
    engine normally absorbs this internally (head-of-line blocking,
    LRU eviction, preemption) rather than surfacing it."""

    retryable = True


class RequestCancelled(ServingError):
    """The client cancelled the request (``engine.cancel``); it was removed
    at the next step boundary. Not retryable — it was asked to stop."""


class DeadlineExceeded(ServingError):
    """The request's ``deadline`` passed before it completed; the engine
    shed it (queued) or cut it short (in flight). Retryable only with a new
    deadline, so ``retryable`` stays False."""


class CircuitOpen(ServingError, RuntimeError):
    """The async front-end's circuit breaker is open: recent admissions
    mostly failed, so the server sheds at its own door instead of hammering
    the engine queue. Retryable — the breaker half-opens after its cooldown
    and closes again once a probe admission succeeds."""

    retryable = True


class ServerOverloaded(ServingError, RuntimeError):
    """The async front-end's priority-aware load shedder rejected the
    request: queue pressure crossed a shedding rung for this priority class
    (low-priority classes shed first; at the highest rung every new request
    is refused). Retryable: resubmit after backoff — pressure is measured
    per admission attempt."""

    retryable = True


def taxonomy() -> dict:
    """{class name: retryable flag} for every error in the serving taxonomy
    (all transitive ``ServingError`` subclasses). The contract test pins
    this mapping EXACTLY, so a future error class cannot be added — or an
    existing one change its ``retryable`` flag — without the pin failing
    loudly and being updated deliberately."""
    out = {}
    stack = [ServingError]
    while stack:
        cls = stack.pop()
        out[cls.__name__] = bool(cls.retryable)
        stack.extend(cls.__subclasses__())
    return out
