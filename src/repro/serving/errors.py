"""Typed error taxonomy for serving admission and fault handling.

Every error the serving layer raises at its public surface derives from
``ServingError`` and carries a ``retryable`` flag — the contract a client
(or ``launch/serve.py``) branches on: a retryable rejection (queue full,
pool momentarily exhausted) is back-pressure and should be retried after a
delay; a non-retryable one (request can never fit) is a hard client error.

Back-compat is deliberate: the pre-taxonomy engine raised bare
``ValueError`` / ``RuntimeError``, and tests (plus any external caller)
match on those — so ``RequestTooLarge`` IS-A ``ValueError`` and
``PoolExhausted`` IS-A ``RuntimeError``. ``except ServingError`` catches
the whole taxonomy; the old handlers keep working unchanged.
"""
from __future__ import annotations


class ServingError(Exception):
    """Base of the serving taxonomy. ``retryable`` tells the client whether
    the same request can succeed later without modification."""

    retryable = False


class RequestTooLarge(ServingError, ValueError):
    """The request can NEVER be admitted: its ring/page demand exceeds the
    engine's capacity outright. Not retryable — shrink the request or build
    a bigger engine."""


class QueueFull(ServingError, RuntimeError):
    """The bounded scheduler queue is at ``max_queue`` — admission
    back-pressure. Retryable: resubmit after the queue drains."""

    retryable = True


class PoolExhausted(ServingError, RuntimeError):
    """No free slot (or, paged, not enough free pages) right now — the
    transient end of the exhaustion ladder. Retryable by nature, though the
    engine normally absorbs this internally (head-of-line blocking,
    LRU eviction, preemption) rather than surfacing it."""

    retryable = True


class RequestCancelled(ServingError):
    """The client cancelled the request (``engine.cancel``); it was removed
    at the next step boundary. Not retryable — it was asked to stop."""


class DeadlineExceeded(ServingError):
    """The request's ``deadline`` passed before it completed; the engine
    shed it (queued) or cut it short (in flight). Retryable only with a new
    deadline, so ``retryable`` stays False."""
