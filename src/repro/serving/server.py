"""Overload-safe asyncio streaming front-end over ``ServingEngine``.

``AsyncServer`` owns the engine's step loop inside an asyncio event loop and
streams tokens per request as the engine produces them (the engine's
``set_stream_callbacks`` surface — callbacks fire at host syncs the engine
performs anyway, so streaming costs zero extra round trips). Admission is
wrapped in a real resilience stack, applied in a **documented degradation
order** per submission:

  1. **circuit breaker** (``CircuitBreaker``) — a sliding window over recent
     engine admissions; when the failure fraction crosses the threshold the
     breaker OPENS and the server sheds at its own door (``CircuitOpen``,
     retryable) instead of hammering the engine queue. After a cooldown it
     half-opens: the next submission is a probe whose outcome closes or
     re-opens it. The breaker sheds BEFORE the queue does — that is its job.
  2. **priority-aware load shedding** (``ShedPolicy``) — queue pressure
     (queue depth / bound) climbs three rungs:
     ``shed_pressure``: reject the lowest priority class
     (``priority < shed_priority_below``) with the retryable
     ``ServerOverloaded``; ``tighten_pressure``: still admit, but shrink the
     accepted deadline to at most ``tightened_slack`` ticks (expired work is
     cut early instead of occupying slots past its usefulness);
     ``refuse_pressure``: refuse EVERY new request (retryable — pressure is
     re-measured per attempt). Shutdown reuses the engine's
     ``request_drain()`` (the SIGTERM contract): admission closes for good,
     in-flight and parked requests finish.
  3. **engine back-pressure** — whatever survives the rungs reaches
     ``engine.submit``, whose bounded queue raises the retryable
     ``QueueFull``; those rejections (and rung-3 refusals) feed the
     breaker's window.

Per-request **timeouts** are wired to the engine's own ``deadline``
enforcement: ``submit(request, timeout=T)`` caps the deadline at
``max(clock, arrival) + T``, and the engine reaps it tick-exactly on both
serve paths — the server never needs a second timer.

**Determinism.** The server uses NO wall-clock timers: time is the engine
tick (``engine.clock``), client sleeps (`wait_until`/`wait_ticks`) are
released by the step loop in ``(tick, submission order)`` order, and the
step loop advances the engine even when only sleepers remain (an idle step
costs one no-op dispatch and moves the clock 1 tick). Given a seeded trace
and seeded retry jitter, a full open-loop run — retries, breaker state,
shed decisions, streamed tokens and their ticks — is bit-reproducible,
which is what lets the SLO bench assert chaos-under-load parity.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import heapq
import itertools
import math
from typing import Callable, Optional, Sequence

from .engine import RequestResult, ServingEngine
from .errors import CircuitOpen, ServerOverloaded, ServingError
from .scheduler import Request


class CircuitBreaker:
    """Sliding-window circuit breaker over engine admission outcomes.

    closed → (failure fraction over the last ``window`` admissions >=
    ``failure_threshold``, with at least ``min_volume`` samples) → open →
    (``cooldown`` ticks pass) → half_open → one probe admission: success
    closes, failure re-opens. Opening clears the window so a recovered
    engine starts from a clean slate.
    """

    def __init__(self, window: int = 32, failure_threshold: float = 0.5,
                 min_volume: int = 8, cooldown: float = 16.0):
        if not 0.0 < failure_threshold <= 1.0:
            raise ValueError(
                f"failure_threshold must be in (0, 1], got {failure_threshold}")
        if window < 1 or min_volume < 1 or cooldown <= 0:
            raise ValueError("window/min_volume must be >= 1, cooldown > 0")
        self.window = window
        self.failure_threshold = failure_threshold
        self.min_volume = min_volume
        self.cooldown = cooldown
        self.state = "closed"                    # closed | open | half_open
        self.opens = 0
        self._events: collections.deque = collections.deque(maxlen=window)
        self._opened_at = 0.0

    def allow(self, now: float) -> bool:
        """Whether a submission may proceed at tick ``now``. In the open
        state this transitions to half_open once the cooldown has elapsed —
        the allowed submission is the probe."""
        if self.state == "open":
            if now - self._opened_at >= self.cooldown:
                self.state = "half_open"
                return True
            return False
        return True

    def record(self, ok: bool, now: float) -> None:
        """Feed an admission outcome. Must follow a permitted ``allow``."""
        if self.state == "half_open":
            if ok:
                self.state = "closed"
                self._events.clear()
            else:
                self._open(now)
            return
        self._events.append(ok)
        if (self.state == "closed"
                and len(self._events) >= self.min_volume):
            failures = sum(1 for e in self._events if not e)
            if failures / len(self._events) >= self.failure_threshold:
                self._open(now)

    def _open(self, now: float) -> None:
        self.state = "open"
        self._opened_at = now
        self.opens += 1
        self._events.clear()


@dataclasses.dataclass
class ShedPolicy:
    """Priority-aware load-shedding rungs, keyed on queue pressure =
    queue depth / bound (the engine's ``max_queue`` when set, else
    ``soft_queue``, else ``4 * num_slots``). The rungs degrade in order:
    shed the lowest priority class, then tighten accepted deadlines, then
    refuse everything — each retryable, so clients back off and the system
    recovers instead of collapsing."""

    shed_pressure: float = 0.5       # rung 1 trigger
    shed_priority_below: int = 1     # rung 1 victim classes (priority < this)
    tighten_pressure: float = 0.75   # rung 2 trigger
    tightened_slack: float = 64.0    # rung 2 deadline cap (ticks from now)
    refuse_pressure: float = 1.0     # rung 3 trigger
    soft_queue: Optional[int] = None  # pressure bound for unbounded queues

    def __post_init__(self):
        if not (0.0 < self.shed_pressure <= self.tighten_pressure
                <= self.refuse_pressure):
            raise ValueError(
                "shed rungs must satisfy 0 < shed <= tighten <= refuse "
                f"(got {self.shed_pressure}/{self.tighten_pressure}/"
                f"{self.refuse_pressure})")
        if self.tightened_slack <= 0:
            raise ValueError("tightened_slack must be > 0 ticks")


class RequestStream:
    """Async iterator over one request's generated tokens.

    Yields ``(tick, token)`` pairs as the engine materializes them;
    iteration ends when the request reaches a terminal status, after which
    ``.result`` holds its ``RequestResult`` (any status — ok / expired /
    cancelled / quarantined). Tokens already streamed are always a prefix
    of ``result.tokens``.
    """

    def __init__(self, rid: int):
        self.rid = rid
        self.result: Optional[RequestResult] = None
        self._pending: collections.deque = collections.deque()
        self._wake = asyncio.Event()

    def _push(self, tick: float, token: int) -> None:
        self._pending.append((tick, token))
        self._wake.set()

    def _finish(self, result: RequestResult) -> None:
        self.result = result
        self._wake.set()

    def __aiter__(self) -> "RequestStream":
        return self

    async def __anext__(self):
        while True:
            if self._pending:
                return self._pending.popleft()
            if self.result is not None:
                raise StopAsyncIteration
            self._wake.clear()
            await self._wake.wait()

    async def drain(self) -> RequestResult:
        """Consume the remaining tokens and return the terminal result."""
        async for _ in self:
            pass
        return self.result


class AsyncServer:
    """Asyncio front-end over one ``ServingEngine`` (module docstring).

    Lifecycle::

        server = AsyncServer(engine)
        server.start()                 # spawns the step-loop task
        stream = server.submit(req, timeout=64.0)
        async for tick, tok in stream: ...
        await server.aclose()          # request_drain + finish in flight

    ``pre_step`` / ``post_step`` hooks receive the step index (number of
    ``engine.step()`` calls) and run inside the loop — the chaos harness
    injects faults and audits pool invariants through them.
    """

    def __init__(self, engine: ServingEngine, *,
                 breaker: Optional[CircuitBreaker] = None,
                 shed: Optional[ShedPolicy] = None,
                 pre_step: Sequence[Callable[[int], None]] = (),
                 post_step: Sequence[Callable[[int], None]] = ()):
        self.engine = engine
        self.breaker = CircuitBreaker() if breaker is None else breaker
        self.shed = ShedPolicy() if shed is None else shed
        self.pre_step = list(pre_step)
        self.post_step = list(post_step)
        self.steps = 0
        self.stats = {
            "submitted": 0,           # submission attempts seen
            "accepted": 0,            # reached the engine queue
            "shed_breaker": 0,        # rejected while the breaker was open
            "shed_priority": 0,       # rung 1: lowest-class shed
            "shed_refused": 0,        # rung 3: refuse-all shed
            "shed_queue": 0,          # engine-level retryable rejections
            "deadlines_tightened": 0,  # rung 2 applications
            "results": collections.Counter(),  # terminal status → count
        }
        self._streams: dict[int, RequestStream] = {}
        self._waiters: list = []      # heap of (tick, seq, future)
        self._seq = itertools.count()
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._closed = False
        engine.set_stream_callbacks(self._on_token, self._on_result)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("server already started")
        self._task = asyncio.ensure_future(self._loop())

    def drain(self) -> None:
        """Close admission for good — the engine's ``request_drain()``
        (SIGTERM semantics): queued-but-unadmitted requests stay unserved,
        in-flight and parked requests finish. New submissions shed with the
        retryable ``QueueFull``."""
        self.engine.request_drain()
        self._wake.set()

    async def aclose(self) -> None:
        """Drain, finish everything in flight, release every sleeper, and
        stop the step loop."""
        self.drain()
        self._closed = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None

    @property
    def clock(self) -> float:
        return self.engine.clock

    # ------------------------------------------------------------ admission
    def _pressure(self) -> float:
        bound = self.engine.scheduler.max_queue or self.shed.soft_queue \
            or 4 * self.engine.num_slots
        return self.engine.scheduler.pending() / bound

    def submit(self, request: Request, *,
               timeout: Optional[float] = None) -> RequestStream:
        """Run one submission through the full resilience ladder (module
        docstring order) and return its token stream. Raises the typed
        taxonomy: retryable ``CircuitOpen`` / ``ServerOverloaded`` /
        ``QueueFull`` (back off and resubmit), non-retryable
        ``RequestTooLarge`` (never resubmit). ``timeout`` caps the
        engine-enforced deadline at ``max(clock, arrival) + timeout``."""
        self.stats["submitted"] += 1
        if request.rid in self._streams:
            raise ValueError(f"request {request.rid} is already in flight")
        now = self.engine.clock
        if not self.breaker.allow(now):
            self.stats["shed_breaker"] += 1
            raise CircuitOpen(
                f"request {request.rid}: circuit breaker is open "
                f"(cooldown {self.breaker.cooldown} ticks) — back off"
            )
        pressure = self._pressure()
        if pressure >= self.shed.refuse_pressure:
            # rung 3 — the queue is effectively full for everyone; this IS
            # queue pressure, so it feeds the breaker's window
            self.stats["shed_refused"] += 1
            self.breaker.record(False, now)
            raise ServerOverloaded(
                f"request {request.rid}: queue pressure {pressure:.2f} >= "
                f"{self.shed.refuse_pressure} — refusing all new requests"
            )
        if (pressure >= self.shed.shed_pressure
                and request.priority < self.shed.shed_priority_below):
            self.stats["shed_priority"] += 1
            raise ServerOverloaded(
                f"request {request.rid}: queue pressure {pressure:.2f} — "
                f"shedding priority < {self.shed.shed_priority_below}"
            )
        base = max(now, request.arrival)
        deadline = request.deadline
        if timeout is not None:
            deadline = min(deadline if deadline is not None else math.inf,
                           base + timeout)
        if pressure >= self.shed.tighten_pressure:
            tightened = base + self.shed.tightened_slack
            if deadline is None or tightened < deadline:
                deadline = tightened
                self.stats["deadlines_tightened"] += 1
        if deadline != request.deadline:
            request = dataclasses.replace(request, deadline=deadline)
        try:
            self.engine.submit(request)
        except ServingError as e:
            if e.retryable:
                self.stats["shed_queue"] += 1
                self.breaker.record(False, now)
            raise
        self.breaker.record(True, now)
        self.stats["accepted"] += 1
        stream = RequestStream(request.rid)
        self._streams[request.rid] = stream
        self._wake.set()
        return stream

    async def serve(self, request: Request, *,
                    timeout: Optional[float] = None) -> RequestResult:
        """Submit and consume to completion (no per-token streaming)."""
        return await self.submit(request, timeout=timeout).drain()

    # ------------------------------------------------------------- sleeping
    async def wait_until(self, tick: float) -> None:
        """Sleep until ``engine.clock >= tick`` — released by the step loop
        in (tick, registration) order, so wakeups are deterministic."""
        if self.engine.clock >= tick:
            return
        fut = asyncio.get_event_loop().create_future()
        heapq.heappush(self._waiters, (tick, next(self._seq), fut))
        self._wake.set()
        await fut

    async def wait_ticks(self, n: float) -> None:
        await self.wait_until(self.engine.clock + n)

    # ------------------------------------------------------------- step loop
    def _engine_busy(self) -> bool:
        e = self.engine
        return bool(e._inflight or e._parked
                    or (not e.draining and e.scheduler.pending()))

    async def _loop(self) -> None:
        while True:
            busy = self._engine_busy() or bool(self._waiters)
            if not busy:
                if self._closed:
                    return
                self._wake.clear()
                if self._engine_busy() or self._waiters or self._closed:
                    continue
                await self._wake.wait()
                continue
            for hook in self.pre_step:
                hook(self.steps)
            self.engine.step()
            self.steps += 1
            for hook in self.post_step:
                hook(self.steps)
            self._release_waiters()
            # one cooperative yield per engine step: every coroutine woken
            # by this step's tokens/results/sleeps runs before the next step
            await asyncio.sleep(0)

    def _release_waiters(self) -> None:
        clock = self.engine.clock
        while self._waiters and self._waiters[0][0] <= clock:
            _, _, fut = heapq.heappop(self._waiters)
            if not fut.done():
                fut.set_result(None)

    # ----------------------------------------------------- engine callbacks
    def _on_token(self, rid: int, tokens: list, tick: float) -> None:
        stream = self._streams.get(rid)
        if stream is None:
            return
        for i, tok in enumerate(tokens):
            stream._push(tick + i, int(tok))

    def _on_result(self, result: RequestResult) -> None:
        self.stats["results"][result.status] += 1
        stream = self._streams.pop(result.rid, None)
        if stream is not None:
            stream._finish(result)
