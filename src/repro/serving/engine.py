"""Continuous-batching serving engine for (quantized) LM models.

One engine step interleaves three phases over a slot-based KV-cache pool:

  1. **admit** — while a slot is free and the FIFO head has arrived, claim a
     slot (bookkeeping reset only; stale K/V is masked out exactly).
  2. **chunked prefill** — every admitted-but-unfinished request advances by
     one fixed-size prompt chunk (batch-1, written into its slot of the
     pooled cache). The final chunk is zero-padded; pad writes are
     invalidated (kpos → -1) before the cache is committed, and the first
     generated token is read from the last *valid* position's logits.
  3. **batched decode** — one ``decode_step`` over the full slot batch with
     per-slot positions/masks. Finished requests retire and their slots are
     immediately reusable; free slots ride along as masked garbage rows
     (classic padding), which keeps every decode the same compiled shape.

Because each slot's computation is row-independent (masked keys contribute
exact zeros), a request's tokens are bit-identical whether it is served solo
or inside a mixed batch — the batch-invariance parity tests pin this down.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .cache_pool import CachePool
from .scheduler import FIFOScheduler, Request

def required_cache_len(prompt_len: int, max_new_tokens: int,
                       prefill_chunk: int) -> int:
    """Ring positions a request needs: the zero-padded prefill chunks (pad
    writes land before invalidation) and the full decoded context."""
    padded = -(-prompt_len // prefill_chunk) * prefill_chunk
    return max(padded, prompt_len + max_new_tokens - 1)


# pooled-cache leaves are [L, B, S, ...] except the per-slot bookkeeping
_SLOT_AXIS = {"kpos": 0, "pos": 0}  # default: axis 1


def _slice_slot(cache: dict, slot) -> dict:
    return {
        k: jax.lax.dynamic_slice_in_dim(v, slot, 1, _SLOT_AXIS.get(k, 1))
        for k, v in cache.items()
    }


def _write_slot(cache: dict, sub: dict, slot) -> dict:
    return {
        k: jax.lax.dynamic_update_slice_in_dim(
            cache[k], sub[k].astype(cache[k].dtype), slot, _SLOT_AXIS.get(k, 1)
        )
        for k in cache
    }


@dataclasses.dataclass
class _InFlight:
    req: Request
    slot: int
    admitted_at: float
    prefilled: int = 0
    generated: list = dataclasses.field(default_factory=list)
    cur_token: int = 0

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= len(self.req.prompt)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new_tokens


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: list  # generated token ids
    arrival: float
    admitted_at: float
    finished_at: float


class ServingEngine:
    """Serve requests against one model + params with continuous batching.

    num_slots: decode batch width (cache pool size).
    max_len: per-slot ring-buffer capacity; a request needs
        max(ceil(P/chunk)*chunk, P + G - 1) <= max_len.
    prefill_chunk: fixed prompt-chunk length (one chunk per prefilling
        request per engine step — bounds prefill's latency impact on
        in-flight decodes).
    """

    def __init__(self, model, params, cfg, *, num_slots: int = 4,
                 max_len: int = 128, prefill_chunk: int = 16,
                 cache_dtype=jnp.float32):
        if cfg.family in ("ssm", "hybrid") or cfg.is_encdec:
            raise ValueError(
                f"the serving engine supports attention-family decoder-only "
                f"models (got {cfg.name!r}, family {cfg.family!r})"
            )
        self.model = model
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.prefill_chunk = prefill_chunk
        self.pool = CachePool(model, num_slots, max_len, dtype=cache_dtype)
        # may be < the requested max_len (sliding-window ring); admission is
        # capped at the real ring so wrap-around never clobbers live keys
        self.max_len = self.pool.max_len
        self.scheduler = FIFOScheduler()
        self.clock = 0.0
        self._inflight: dict[int, _InFlight] = {}
        self.results: dict[int, RequestResult] = {}
        self.stats = {
            "decode_steps": 0,
            "prefill_chunks": 0,
            "generated_tokens": 0,
            # running aggregate, not a per-step list: a long-lived engine
            # must not grow memory with uptime
            "occupancy_sum": 0.0,
            "engine_steps": 0,
        }
        self._prefill_fn = jax.jit(self._prefill_chunk_impl)
        self._decode_fn = jax.jit(self._decode_impl)

    @classmethod
    def from_quantized(cls, qm, **kwargs) -> "ServingEngine":
        """Build an engine over a pipeline ``QuantizedModel`` artifact."""
        return cls(qm.model, qm.params, qm.cfg, **kwargs)

    # -------------------------------------------------------- jitted kernels
    def _prefill_chunk_impl(self, params, tokens, cache, slot, n_valid):
        """One batch-1 prompt chunk into `slot` of the pooled cache.

        tokens: [1, C] (zero-padded past n_valid). Pad tokens run through the
        model — causality keeps them out of every valid position's K/V — and
        their cache writes are invalidated before commit. Returns the greedy
        token from the last valid position and the updated pooled cache.
        """
        sub = _slice_slot(cache, slot)
        start = sub["pos"]                                   # [1]
        logits, sub = self.model.prefill(
            params, tokens, sub, logits_at=n_valid - 1
        )
        end = start + n_valid
        sub = {
            **sub,
            "kpos": jnp.where(sub["kpos"] >= end[:, None], -1, sub["kpos"]),
            "pos": end,
        }
        tok = jnp.argmax(logits, -1).astype(jnp.int32)       # [1]
        return tok, _write_slot(cache, sub, slot)

    def _decode_impl(self, params, tokens, cache, active):
        """Full-slot-batch decode. ``active`` [B] marks rows that are really
        decoding; the rest (free, or mid-prefill) ride along for shape
        stability, so their bookkeeping write this step — one kpos entry and
        the pos advance — is rolled back before commit. (Their K/V payload
        write is harmless: masked by kpos=-1 and overwritten by the slot's
        next real token at the same ring index.)"""
        prev_pos = cache["pos"]                              # [B]
        logits, cache = self.model.decode_step(params, tokens, cache)
        S = cache["kpos"].shape[1]
        wrote = jnp.arange(S)[None, :] == (prev_pos % S)[:, None]
        kpos = jnp.where((~active)[:, None] & wrote, -1, cache["kpos"])
        pos = jnp.where(active, cache["pos"], prev_pos)
        cache = {**cache, "kpos": kpos, "pos": pos}
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    # ------------------------------------------------------------ lifecycle
    def submit(self, request: Request) -> None:
        P, G = len(request.prompt), request.max_new_tokens
        need = required_cache_len(P, G, self.prefill_chunk)
        if need > self.max_len:
            raise ValueError(
                f"request {request.rid}: needs {need} cache positions "
                f"(prompt {P}, gen {G}, chunk {self.prefill_chunk}) "
                f"but max_len={self.max_len}"
            )
        self.scheduler.submit(request)

    def _admit(self) -> None:
        while self.pool.n_free:
            req = self.scheduler.pop_ready(self.clock)
            if req is None:
                return
            slot = self.pool.allocate()
            self._inflight[slot] = _InFlight(
                req=req, slot=slot, admitted_at=self.clock
            )

    def _retire(self, fl: _InFlight) -> None:
        self.results[fl.req.rid] = RequestResult(
            rid=fl.req.rid,
            prompt_len=len(fl.req.prompt),
            tokens=list(fl.generated),
            arrival=fl.req.arrival,
            admitted_at=fl.admitted_at,
            finished_at=self.clock,
        )
        del self._inflight[fl.slot]
        self.pool.release(fl.slot)

    def _prefill_phase(self) -> None:
        C = self.prefill_chunk
        for slot in sorted(self._inflight):
            fl = self._inflight[slot]
            if fl.prefill_done:
                continue
            prompt = np.asarray(fl.req.prompt, np.int32)
            n = min(C, len(prompt) - fl.prefilled)
            chunk = np.zeros((1, C), np.int32)
            chunk[0, :n] = prompt[fl.prefilled:fl.prefilled + n]
            tok, self.pool.cache = self._prefill_fn(
                self.params, jnp.asarray(chunk), self.pool.cache,
                jnp.int32(slot), jnp.int32(n),
            )
            fl.prefilled += n
            self.stats["prefill_chunks"] += 1
            if fl.prefill_done:
                first = int(tok[0])
                fl.generated.append(first)
                fl.cur_token = first
                self.stats["generated_tokens"] += 1
                if fl.done:
                    self._retire(fl)

    def _decode_phase(self) -> None:
        active = [fl for fl in self._inflight.values()
                  if fl.prefill_done and not fl.done]
        if not active:
            return
        tokens = np.zeros((self.num_slots, 1), np.int32)
        active_mask = np.zeros((self.num_slots,), bool)
        for fl in active:
            tokens[fl.slot, 0] = fl.cur_token
            active_mask[fl.slot] = True
        next_tok, self.pool.cache = self._decode_fn(
            self.params, jnp.asarray(tokens), self.pool.cache,
            jnp.asarray(active_mask),
        )
        next_np = np.asarray(next_tok)
        self.stats["decode_steps"] += 1
        for fl in active:
            tok = int(next_np[fl.slot])
            fl.generated.append(tok)
            fl.cur_token = tok
            self.stats["generated_tokens"] += 1
            if fl.done:
                self._retire(fl)

    def step(self) -> None:
        """One engine iteration: admit → chunked prefill → batched decode."""
        self._admit()
        self.stats["occupancy_sum"] += len(self._inflight) / self.num_slots
        self.stats["engine_steps"] += 1
        self._prefill_phase()
        self._decode_phase()
        self.clock += 1.0

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> dict[int, RequestResult]:
        """Submit ``requests`` (if given), step until fully drained, and
        return — draining ``self.results`` so a long-lived engine doesn't
        retain every request it ever served."""
        for r in requests or ():
            self.submit(r)
        while self.scheduler.pending() or self._inflight:
            self.step()
        out, self.results = self.results, {}
        return out

    # ------------------------------------------------------------- metrics
    def mean_occupancy(self) -> float:
        steps = self.stats["engine_steps"]
        return self.stats["occupancy_sum"] / steps if steps else 0.0
