"""Continuous-batching serving engine for (quantized) LM models.

One engine step interleaves three phases over a slot-based KV-cache pool:

  1. **admit** — while a slot is free and the FIFO head has arrived, claim a
     slot (bookkeeping reset only; stale K/V is masked out exactly).
  2. **chunked prefill** — every admitted-but-unfinished request advances by
     one fixed-size prompt chunk, written into its slot of the pooled cache.
     The final chunk is zero-padded; pad writes are invalidated (kpos → -1)
     before the cache is committed, and the first generated token is read
     from the last *valid* position's logits.
  3. **batched decode** — ``decode_step`` over the full slot batch with
     per-slot positions/masks. Finished requests retire and their slots are
     immediately reusable; free slots ride along as masked garbage rows
     (classic padding), which keeps every decode the same compiled shape.

Two executions of that loop share the bookkeeping above:

  * the **fast path** (default) is device-resident: all currently-prefilling
    slots advance in ONE ``[P, C]`` dispatch (scattered into the pooled
    cache), decode runs K steps fused in a jitted ``lax.scan`` that returns
    a ``[B, K]`` token buffer (one dispatch, one host sync per horizon), the
    cache argument is donated in every jit so the KV pool updates in place,
    and slot-reset bookkeeping is folded into the first prefill chunk. The
    host picks K adaptively — ``min(decode_horizon, min remaining budget,
    ceil(next scheduled arrival - clock))``, K=1 while any prefill is in
    flight — so retirement, admission, and prefill cadence land on exactly
    the same clock ticks as the stepwise path.
  * the **stepwise reference** (``fast=False``) dispatches one batch-1
    prefill chunk per slot and one decode step per engine step, syncing
    after every step — the PR-2 behavior, kept as the parity oracle.

Because each slot's computation is row-independent (masked keys contribute
exact zeros), a request's tokens are bit-identical whether it is served solo
or inside a mixed batch, and whether decode steps run one-at-a-time or fused
— the batch-invariance and fused-vs-stepwise parity tests pin this down.

**Paged mode** (``page_size=...``): the pool stores KV state as fixed-size
pages + per-slot page tables (see cache_pool.py), and each of the four jits
becomes a thin wrapper around the SAME contiguous impl: gather the slot
rings out of the page pool into a dense ``[L, B, S, ...]`` view, run the
unchanged impl on the view, then scatter back ONLY the ring positions this
dispatch actually wrote (host-known write windows; out-of-range / unmapped
positions drop). Gathered garbage beyond a slot's mapped pages is finite
and masked by ``kpos = -1`` / scale 0 — exactly the recycled-slot
invariant — so paged serving is token-for-token identical to the
contiguous pool. Admission maps shared prefix pages from the scheduler's
``PrefixIndex`` (reuse length aligned DOWN to a prefill-chunk boundary,
which makes the donor's cached K/V bit-identical to recomputing them) and
costs one fused bookkeeping dispatch; prefill completion publishes the
request's fully-covered prompt pages for later requests to share.

**Fault tolerance**: requests carry optional ``deadline``/``priority``; the
engine reaps expired or client-cancelled requests at step/horizon
boundaries and reclaims their pages atomically. When paged admission runs
out of pages it climbs an exhaustion ladder — evict LRU prefix-index
entries, then preempt strictly-lower-priority in-flight requests (pages
released, prompt + generated-so-far parked host-side; the victim's
computed KV pages are published to the prefix index first, so a prompt
resume can remap them instead of recomputing) — before head-of-line
blocking. Every jitted path additionally returns a per-row "bad" flag
(non-finite logits); a poisoned row is quarantined at its next host sync
instead of poisoning the batch (row independence keeps every other slot
bit-identical). ``serving/chaos.py`` drives all of this deterministically.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
import os
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..runtime.fault_tolerance import StragglerMonitor
from .cache_pool import KNOWN_BOOKKEEPING, CachePool
from .errors import QueueFull, RequestTooLarge
from .scheduler import FIFOScheduler, PrefixIndex, Request

def required_cache_len(prompt_len: int, max_new_tokens: int,
                       prefill_chunk: int) -> int:
    """Ring positions a request needs: the zero-padded prefill chunks (pad
    writes land before invalidation) and the full decoded context."""
    padded = -(-prompt_len // prefill_chunk) * prefill_chunk
    return max(padded, prompt_len + max_new_tokens - 1)


def _pow2_floor(n: int) -> int:
    return 1 << (max(1, n).bit_length() - 1)


def _pow2_ceil(n: int) -> int:
    return 1 << (max(1, n) - 1).bit_length()


def _take_window(leaf, win):
    """Gather ring positions ``win`` [B, C] along the S axis of a payload
    leaf [L, B, S, ...] → [L, B, C, ...]."""
    idx = win.astype(jnp.int32).reshape(
        (1,) + win.shape + (1,) * (leaf.ndim - 3))
    return jnp.take_along_axis(leaf, idx, axis=2)


def _put_window(leaf, win, vals):
    """Scatter ``vals`` [L, B, C, ...] back into ring positions ``win``
    [B, C] along the S axis of a payload leaf [L, B, S, ...]."""
    b = jnp.arange(leaf.shape[1])[:, None]
    return leaf.at[:, b, win].set(vals.astype(leaf.dtype))


def _paged_view(cache: dict, page_size: int, max_len: int) -> dict:
    """Gather every slot's mapped pages into the dense contiguous layout
    ``[L, B, S, ...]`` the slot impls were written against. Unmapped table
    entries (-1) clamp to page 0: the gathered rows are garbage, but finite
    garbage at positions the bookkeeping marks dead (``kpos = -1`` / scale
    0) — the same invariant that makes recycled contiguous slots exact.
    ``kpos``/``pos`` are dense in both layouts and pass straight through."""
    pt = jnp.maximum(cache["page_table"], 0)             # [B, S/pg]
    dense = {"kpos": cache["kpos"], "pos": cache["pos"]}
    for name, leaf in cache.items():                     # leaf [L, NP, pg, ...]
        if name in KNOWN_BOOKKEEPING:
            continue
        g = jnp.take(leaf, pt, axis=1)                   # [L, B, S/pg, pg, ...]
        g = g.reshape(g.shape[:2] + (-1,) + leaf.shape[3:])
        dense[name] = jax.lax.slice_in_dim(g, 0, max_len, axis=2)
    return dense


def _paged_commit(cache: dict, dense: dict, rows, page_size: int) -> dict:
    """Scatter the ring positions a dispatch wrote (``rows`` [B, W], -1 for
    rows that wrote nothing) from the dense view back into the page pool.
    The write window is host bookkeeping the engine already tracks — pos
    before the call plus the chunk/horizon extent — so the scatter is a
    fixed [B, W] shape per compiled dispatch, not a data-dependent one.
    Positions mapping to no page (or rows = -1) route to one-past-the-end
    flat indices, which scatter-drop. Pages shared between slots are never
    in any write window (admission copies the one COW boundary page), so
    the non-dropped flat indices are unique and the scatter deterministic.
    ``kpos``/``pos`` come back dense from the impl; the page table is
    read-only inside every dispatch."""
    pg = page_size
    idx = jnp.maximum(rows, 0)                           # [B, W]
    page = jnp.take_along_axis(cache["page_table"], idx // pg, axis=1)
    out = {"kpos": dense["kpos"], "pos": dense["pos"],
           "page_table": cache["page_table"]}
    for name, leaf in cache.items():                     # leaf [L, NP, pg, ...]
        if name in KNOWN_BOOKKEEPING:
            continue
        flat_n = leaf.shape[1] * pg
        flat = jnp.where((rows >= 0) & (page >= 0),
                         page * pg + idx % pg, flat_n)   # [B, W]
        flatleaf = leaf.reshape((leaf.shape[0], flat_n) + leaf.shape[3:])
        tidx = idx.reshape((1,) + idx.shape + (1,) * (dense[name].ndim - 3))
        vals = jnp.take_along_axis(dense[name], tidx, axis=2)  # [L, B, W, ...]
        out[name] = flatleaf.at[:, flat].set(
            vals.astype(leaf.dtype), mode="drop"
        ).reshape(leaf.shape)
    return out


@dataclasses.dataclass
class _InFlight:
    req: Request
    slot: int
    admitted_at: float
    prefilled: int = 0
    generated: list = dataclasses.field(default_factory=list)
    cur_token: int = 0
    # fast path: slot bookkeeping reset deferred to the first prefill chunk
    fresh: bool = False
    # preemption bookkeeping: a resumed request runs as an internal Request
    # whose prompt is (original prompt + tokens generated before the
    # preemption); ``prior`` holds those already-generated tokens and
    # ``orig_req`` the original request, so retirement merges them back into
    # ONE result under the original rid/prompt_len
    prior: list = dataclasses.field(default_factory=list)
    orig_req: Optional[Request] = None

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= len(self.req.prompt)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new_tokens

    @property
    def remaining(self) -> int:
        return self.req.max_new_tokens - len(self.generated)


@dataclasses.dataclass
class _Parked:
    """A preempted request waiting host-side for re-admission: the ORIGINAL
    request plus everything generated before the preemption. Resumption
    re-enters the normal admission path as an internal request whose prompt
    is ``req.prompt + generated`` — the prefix index then remaps whatever
    published pages survived, and re-prefills the rest (bit-identical either
    way: prefill and decode agree on every cached position)."""

    req: Request
    generated: list
    admitted_at: float


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: list  # generated token ids
    arrival: float
    admitted_at: float
    finished_at: float
    # "ok" | "expired" | "cancelled" | "quarantined" — non-ok results carry
    # the tokens generated before the fault (possibly none)
    status: str = "ok"


class ServingEngine:
    """Serve requests against one model + params with continuous batching.

    num_slots: decode batch width (cache pool size).
    max_len: per-slot ring-buffer capacity; a request needs
        max(ceil(P/chunk)*chunk, P + G - 1) <= max_len.
    prefill_chunk: fixed prompt-chunk length (one chunk per prefilling
        request per engine step — bounds prefill's latency impact on
        in-flight decodes).
    decode_horizon: max decode steps fused into one device dispatch (fast
        path). Each distinct adaptive horizon K <= decode_horizon compiles
        its own scan, so keep it modest (compile count is bounded by it).
    fast: use the device-resident path (default). ``fast=False`` selects the
        stepwise reference implementation — same tokens bit-for-bit, one
        host sync per generated token; prefer it when debugging bookkeeping
        or when holding external references to ``pool.cache`` (the fast and
        slow paths both DONATE the cache buffer to the jitted step, so the
        pre-call cache object is invalidated after every dispatch).
    cache_dtype: fp payload dtype of the pooled cache; None (default) uses
        the model's activation compute dtype.
    kv_bits: 8 → int8 pooled KV cache (int8 payload + per-token/per-head
        scales; decode attends through the kv_attention op), 16 → fp, None
        → follow ``cfg.kv_cache_bits`` (so a ``*-kv8`` quantize recipe
        carries its KV precision into the engine).
    mesh: a jax ``Mesh`` ("data", "model" [, leading "pod"]) for sharded
        serving. Params are placed under the serve-mode partition specs
        (Megatron TP on "model", int8 QTensor scales co-sharded with their
        payload columns, no FSDP factor — weights stay resident) and the
        pooled cache under the serve cache specs (slots over "data", KV
        heads over "model"). All four jitted paths pin the cache's
        NamedShardings as out_shardings — with donation preserved, so the
        sharded pool still updates in place — and GSPMD partitions the
        step. Per-slot computation is row-independent, so slot sharding is
        exact; TP's row-parallel psum reorders reductions (float-level
        wobble vs single-device; the parity tests pin the tolerance).
    page_size: switch the pool to the paged layout (fixed pages + per-slot
        page tables + refcounted shared-prefix reuse; see the module and
        cache_pool docstrings). Tokens are bit-identical to the contiguous
        pool. None (default) keeps the contiguous layout.
    num_pages: page-pool size (paged mode only); default gives every slot
        a full ring. Admission blocks head-of-line when the pool can't
        cover the head request's pages, after evicting prefix-index
        entries LRU.
    prefix_reuse: enable the scheduler's PrefixIndex (paged mode only):
        prefill completion publishes fully-covered prompt pages, and later
        admissions map them (copy-on-write) instead of recomputing the
        shared prefix.
    max_queue: bound on the admission queue; ``submit`` beyond it raises the
        retryable ``QueueFull`` (back-pressure) and counts a shed. None
        (default) = unbounded.
    straggler: a ``runtime.fault_tolerance.StragglerMonitor`` observing
        per-engine-step wall time (steps slower than ``threshold ×`` the
        EMA count into ``stats["straggler_steps"]``); None = defaults. The
        monitor's threshold is surfaced as ``stats["straggler_threshold"]``
        so serve reports can show what "slow" meant.

    **Streaming** (``set_stream_callbacks``): the engine exposes a
    step-boundary token surface for the async front-end (serving/server.py)
    — ``on_token(rid, tokens, tick)`` fires at every host sync that
    materializes new tokens for a request (token ``i`` of the batch landed
    at engine tick ``tick + i``; a fused horizon delivers its K tokens in
    one call), and ``on_result(result)`` fires exactly once per request at
    the moment its ``RequestResult`` is recorded, for EVERY terminal status
    (ok / expired / cancelled / quarantined — including requests shed from
    the queue or reaped while parked). A preempted-then-resumed request
    streams each token exactly once: tokens generated before the preemption
    were already delivered, and resumption streams only the continuation.
    Callbacks run synchronously inside ``step()`` at syncs that happen
    anyway, so streaming adds zero extra host round trips.
    """

    def __init__(self, model, params, cfg, *, num_slots: int = 4,
                 max_len: int = 128, prefill_chunk: int = 16,
                 cache_dtype=None, decode_horizon: int = 8,
                 fast: bool = True, kv_bits: Optional[int] = None,
                 mesh=None, page_size: Optional[int] = None,
                 num_pages: Optional[int] = None, prefix_reuse: bool = True,
                 max_queue: Optional[int] = None,
                 straggler: Optional[StragglerMonitor] = None):
        if cfg.family in ("ssm", "hybrid") or cfg.is_encdec:
            raise ValueError(
                f"the serving engine supports attention-family decoder-only "
                f"models (got {cfg.name!r}, family {cfg.family!r})"
            )
        if decode_horizon < 1:
            raise ValueError(f"decode_horizon must be >= 1, got {decode_horizon}")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.prefill_chunk = prefill_chunk
        self.decode_horizon = decode_horizon
        self.fast = fast
        self.mesh = mesh
        if mesh is not None:
            from ..sharding import named_shardings, params_pspecs

            heads = {"n_q": cfg.n_heads, "n_kv": cfg.n_kv_heads}
            p_shapes = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
            )
            specs = params_pspecs(p_shapes, mesh, heads, mode="serve")
            self.params = jax.device_put(params, named_shardings(specs, mesh))
        self.pool = CachePool(model, num_slots, max_len, dtype=cache_dtype,
                              kv_bits=kv_bits, mesh=mesh,
                              page_size=page_size, num_pages=num_pages)
        self.kv_bits = self.pool.kv_bits
        self.page_size = self.pool.page_size
        self.paged = self.pool.paged
        self.prefix_index = (PrefixIndex(self.page_size)
                             if self.paged and prefix_reuse else None)
        # may be < the requested max_len (sliding-window ring); admission is
        # capped at the real ring so wrap-around never clobbers live keys
        self.max_len = self.pool.max_len
        self.scheduler = FIFOScheduler(max_queue=max_queue)
        self.straggler = straggler or StragglerMonitor()
        self.clock = 0.0
        # streaming surface (set_stream_callbacks): fired at existing host
        # syncs — None (default) keeps the batch submit/run contract alone
        self._on_token = None
        self._on_result = None
        self._inflight: dict[int, _InFlight] = {}
        self._parked: collections.deque[_Parked] = collections.deque()
        # rids marked for cancellation while in flight (takes effect at the
        # next step boundary) and for NaN injection (chaos: the row is
        # treated as non-finite at its next host sync)
        self._cancelled: set[int] = set()
        self._inject_bad: set[int] = set()
        self._draining = False
        # REPRO_POOL_CHECK=1: audit pool bookkeeping after every step
        self._pool_check = os.environ.get("REPRO_POOL_CHECK") == "1"
        self.results: dict[int, RequestResult] = {}
        self.stats = {
            "decode_steps": 0,        # token-level steps (fast: += K/horizon)
            "decode_dispatches": 0,   # jitted decode calls
            "prefill_chunks": 0,      # chunk-level prefill advances
            "prefill_dispatches": 0,  # jitted prefill calls
            "host_syncs": 0,          # device→host materializations
            "generated_tokens": 0,
            # running aggregate, not a per-step list: a long-lived engine
            # must not grow memory with uptime
            "occupancy_sum": 0.0,
            "engine_steps": 0,
            # fault-tolerance counters (the serve report's fault table)
            "preempted": 0,           # in-flight requests parked for pages
            "resumed": 0,             # parked requests re-admitted
            "shed": 0,                # submissions rejected (QueueFull)
            "cancelled": 0,           # client cancellations honored
            "expired": 0,             # deadline reaps (queued or in flight)
            "quarantined": 0,         # non-finite rows retired
            "straggler_steps": 0,     # engine steps flagged by the monitor
            # what "slow" means for the monitor above (a config echo, not a
            # counter — serve reports print it next to the flagged count)
            "straggler_threshold": float(getattr(self.straggler,
                                                 "threshold", 0.0)),
        }
        # every jit donates the pooled cache (argnum 2): the KV pool is
        # updated in place instead of being copied on each call, mirroring
        # launch/steps.py / dryrun.py. The buffer passed in is INVALID after
        # the call — the engine immediately rebinds pool.cache to the output.
        # Under a mesh the cache's NamedShardings are additionally pinned as
        # out_shardings (tokens replicate — they're host-bound anyway): the
        # in/out shardings then match leaf-for-leaf, which is what keeps
        # donation's in-place buffer reuse valid for the sharded pool, and
        # GSPMD can't drift the pool's layout between steps (a drift would
        # force a recompile per step).
        kw: dict = {"donate_argnums": (2,)}
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            # outputs are (tokens, bad-row mask, cache): tokens and the bad
            # mask replicate (both host-bound), the cache keeps its specs
            rep = NamedSharding(mesh, PartitionSpec())
            kw["out_shardings"] = (rep, rep, self.pool.shardings)
        # paged mode jits the thin gather/commit wrappers around the SAME
        # impls (identical signatures), so everything downstream — the
        # serving loop, warmup, the lint layer's lowering — is layout-blind
        self._impls = {
            "prefill": (self._paged_prefill_chunk_impl if self.paged
                        else self._prefill_chunk_impl),
            "decode": (self._paged_decode_impl if self.paged
                       else self._decode_impl),
            "prefill_multi": (self._paged_prefill_multi_impl if self.paged
                              else self._prefill_multi_impl),
            "decode_horizon": (self._paged_decode_horizon_impl if self.paged
                               else self._decode_horizon_impl),
        }
        if mesh is not None:
            # arm the serve-mesh context while each impl TRACES, so the
            # decode hot path can shard_map its fused attention kernel over
            # ("data", "model") — see models.layers.set_serve_mesh
            from ..models.layers import set_serve_mesh
            from ..sharding.partition import _dp_world

            dp_axes, _ = _dp_world(mesh)
            if isinstance(dp_axes, str):
                dp_axes = (dp_axes,)

            def _armed(fn):
                @functools.wraps(fn)
                def wrapped(*a, **k):
                    prev = set_serve_mesh(mesh, dp=dp_axes)
                    try:
                        return fn(*a, **k)
                    finally:
                        set_serve_mesh(prev["mesh"], dp=prev["dp"],
                                       model=prev["model"])
                return wrapped

            self._impls = {n: _armed(f) for n, f in self._impls.items()}
        self._prefill_fn = jax.jit(self._impls["prefill"], **kw)
        self._decode_fn = jax.jit(self._impls["decode"], **kw)
        self._prefill_multi_fn = jax.jit(self._impls["prefill_multi"], **kw)
        self._decode_horizon_fn = jax.jit(self._impls["decode_horizon"],
                                          static_argnames=("k",), **kw)

    @classmethod
    def from_quantized(cls, qm, **kwargs) -> "ServingEngine":
        """Build an engine over a pipeline ``QuantizedModel`` artifact."""
        return cls(qm.model, qm.params, qm.cfg, **kwargs)

    # -------------------------------------------------------- jitted kernels
    def _prefill_masked(self, params, tokens, cache, n_valid, fresh, is_real):
        """Full-width masked prefill: EVERY pool slot advances one chunk in
        slot position — no gather/scatter, each slot's rows never move.

        This is what keeps the pool's slot sharding alive under TP: the old
        pooled gather (``jnp.take`` over dynamic slot ids) forced GSPMD to
        all-gather whole cache leaves around every prefill dispatch — the
        collective-budget ``known_debt`` the -tp serving contracts used to
        carry. In slot position the batch axis IS the pool axis, so every
        row stays on its owning shard and the prefill emits no pool-sized
        collectives at all.

        tokens: [B, C] in slot position (zero rows for slots not
        prefilling); n_valid: [B] (pads 1 — they select position 0's
        logits); fresh: [B] rows whose bookkeeping reset (kpos → -1, pos →
        0) was deferred from ``CachePool.allocate(reset=False)``; is_real:
        [B] marks rows that are actually prefilling. Pad rows run the model
        for shape stability; their bookkeeping rolls back wholesale and
        their C-wide ring write window — saved before the model's in-place
        appends — is restored after, so a pad row's cache bytes are
        bit-identical before/after (live keys of decoding slots riding
        along are never clobbered, even across a ring wrap). Returns
        per-row greedy tokens from each row's last valid position, the
        per-row non-finite flag masked to real rows, and the updated pool.
        """
        C = tokens.shape[1]
        S = cache["kpos"].shape[1]
        start = jnp.where(fresh, 0, cache["pos"])            # [B]
        win = (start[:, None]
               + jnp.arange(C, dtype=jnp.int32)[None, :]) % S  # [B, C]
        payload = [k for k in cache if k not in KNOWN_BOOKKEEPING]
        saved = {k: _take_window(cache[k], win) for k in payload}
        sub = {
            **cache,
            "kpos": jnp.where(fresh[:, None], -1, cache["kpos"]),
            "pos": start,
        }
        logits, sub = self.model.prefill(
            params, tokens, sub, logits_at=n_valid - 1
        )
        end = start + n_valid
        kpos = jnp.where(sub["kpos"] >= end[:, None], -1, sub["kpos"])
        out = {
            **sub,
            "kpos": jnp.where(is_real[:, None], kpos, cache["kpos"]),
            "pos": jnp.where(is_real, end, cache["pos"]),
        }
        for k in payload:
            keep = is_real.reshape((1, -1) + (1,) * (saved[k].ndim - 2))
            vals = jnp.where(keep, _take_window(out[k], win), saved[k])
            out[k] = _put_window(out[k], win, vals)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)       # [B]
        bad = ~jnp.all(jnp.isfinite(logits), -1) & is_real   # [B]
        return tok, bad, out

    def _prefill_chunk_impl(self, params, tokens, cache, slot, n_valid):
        """One prompt chunk into `slot` of the pooled cache (the stepwise
        reference path). tokens: [1, C] (zero-padded past n_valid); the row
        is placed at its slot of a full-width masked prefill, so the pool
        is addressed in slot position here too (no dynamic slice under TP).
        Returns the greedy token from the last valid position and the
        per-row non-finite flag, both [1].
        """
        B = cache["kpos"].shape[0]
        is_real = jnp.arange(B) == slot
        tok, bad, cache = self._prefill_masked(
            params,
            jnp.where(is_real[:, None], jnp.broadcast_to(tokens, (B,) + tokens.shape[1:]), 0),
            cache,
            jnp.where(is_real, n_valid, 1).astype(jnp.int32),
            jnp.zeros((B,), bool),
            is_real,
        )
        return (jax.lax.dynamic_slice_in_dim(tok, slot, 1),
                jax.lax.dynamic_slice_in_dim(bad, slot, 1), cache)

    def _prefill_multi_impl(self, params, tokens, cache, n_valid, fresh,
                            is_real):
        """All currently-prefilling slots advance one chunk in ONE
        full-width dispatch (see ``_prefill_masked``). One compiled shape —
        [num_slots, C] — covers every prefill step; row-independent compute
        keeps each row bit-identical to its batch-1 dispatch."""
        return self._prefill_masked(params, tokens, cache, n_valid, fresh,
                                    is_real)

    def _decode_masked(self, params, tokens, cache, active):
        """One full-slot-batch decode step. ``active`` [B] marks rows that
        are really decoding; the rest (free, or mid-prefill) ride along for
        shape stability, so their bookkeeping write this step — one kpos
        entry and the pos advance — is rolled back before commit. (Their K/V
        payload write is harmless: masked by kpos=-1 and overwritten by the
        slot's next real token at the same ring index.) Also returns the
        per-row non-finite-logits flag, masked to active rows (inactive rows
        legitimately carry garbage)."""
        prev_pos = cache["pos"]                              # [B]
        logits, cache = self.model.decode_step(params, tokens, cache)
        S = cache["kpos"].shape[1]
        wrote = jnp.arange(S)[None, :] == (prev_pos % S)[:, None]
        kpos = jnp.where((~active)[:, None] & wrote, -1, cache["kpos"])
        pos = jnp.where(active, cache["pos"], prev_pos)
        cache = {**cache, "kpos": kpos, "pos": pos}
        bad = ~jnp.all(jnp.isfinite(logits), -1) & active    # [B]
        return jnp.argmax(logits, -1).astype(jnp.int32), bad, cache

    def _decode_impl(self, params, tokens, cache, active):
        """Stepwise reference: one decode step, one host round trip."""
        return self._decode_masked(params, tokens, cache, active)

    def _decode_horizon_impl(self, params, tokens, cache, remaining, *, k):
        """K decode steps fused on device: one dispatch, one host sync.

        tokens: [B, 1] current token per slot (garbage for inactive rows);
        remaining: [B] tokens still owed per slot (0 = free / mid-prefill).
        Each scan step applies exactly the stepwise masked decode with
        ``active = remaining > 0``; a row whose budget runs out freezes in
        place (its token stops being fed forward and its bookkeeping rolls
        back), so callers that pick ``k <= min(remaining[active])`` retire
        rows exactly at the horizon boundary. Returns the [B, k] token
        buffer, the per-row bad flag OR-ed across the row's active steps,
        and the updated pooled cache.
        """
        def body(carry, _):
            tokens, cache, remaining, badacc = carry
            active = remaining > 0
            nxt, bad, cache = self._decode_masked(params, tokens, cache,
                                                  active)
            tokens = jnp.where(active[:, None], nxt[:, None], tokens)
            remaining = jnp.where(active, remaining - 1, remaining)
            return (tokens, cache, remaining, badacc | bad), nxt

        badacc = jnp.zeros(remaining.shape, bool)
        (_, cache, _, badacc), toks = jax.lax.scan(
            body, (tokens, cache, remaining, badacc), None, length=k
        )
        return toks.T, badacc, cache                         # [B, k], [B]

    # ------------------------------------------------- paged jit wrappers
    # Same signatures as the contiguous impls: gather the page pool into the
    # dense slot view, run the unchanged impl, commit the host-known write
    # window back into the pages (see _paged_view/_paged_commit).

    def _paged_prefill_chunk_impl(self, params, tokens, cache, slot, n_valid):
        dense = _paged_view(cache, self.page_size, self.max_len)
        start = jax.lax.dynamic_index_in_dim(cache["pos"], slot,
                                             keepdims=False)
        tok, bad, dense = self._prefill_chunk_impl(params, tokens, dense,
                                                   slot, n_valid)
        C = tokens.shape[1]
        B, S = cache["kpos"].shape
        row = (start + jnp.arange(C, dtype=jnp.int32)) % S
        rows = jnp.full((B, C), -1, jnp.int32).at[slot].set(row)
        return tok, bad, _paged_commit(cache, dense, rows, self.page_size)

    def _paged_prefill_multi_impl(self, params, tokens, cache, n_valid,
                                  fresh, is_real):
        dense = _paged_view(cache, self.page_size, self.max_len)
        start = jnp.where(fresh, 0, cache["pos"])            # [B]
        tok, bad, dense = self._prefill_multi_impl(params, tokens, dense,
                                                   n_valid, fresh, is_real)
        C = tokens.shape[1]
        S = cache["kpos"].shape[1]
        rows = (start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]) % S
        rows = jnp.where(is_real[:, None], rows, -1)     # pad rows: no write
        return tok, bad, _paged_commit(cache, dense, rows, self.page_size)

    def _paged_decode_impl(self, params, tokens, cache, active):
        dense = _paged_view(cache, self.page_size, self.max_len)
        prev = cache["pos"]
        tok, bad, dense = self._decode_masked(params, tokens, dense, active)
        S = cache["kpos"].shape[1]
        rows = jnp.where(active, prev % S, -1)[:, None]  # [B, 1]
        return tok, bad, _paged_commit(cache, dense, rows, self.page_size)

    def _paged_decode_horizon_impl(self, params, tokens, cache, remaining,
                                   *, k):
        # ONE gather before the scan and one commit after it: the k fused
        # steps read/write the dense view, so the horizon's page traffic is
        # amortized exactly like its host syncs
        dense = _paged_view(cache, self.page_size, self.max_len)
        prev = cache["pos"]
        toks, bad, dense = self._decode_horizon_impl(params, tokens, dense,
                                                     remaining, k=k)
        S = cache["kpos"].shape[1]
        t = jnp.arange(k, dtype=jnp.int32)[None, :]
        rows = jnp.where(t < remaining[:, None],
                         (prev[:, None] + t) % S, -1)    # [B, k]
        return toks, bad, _paged_commit(cache, dense, rows, self.page_size)

    # ------------------------------------------------------------ lifecycle
    def submit(self, request: Request) -> None:
        P, G = len(request.prompt), request.max_new_tokens
        need = required_cache_len(P, G, self.prefill_chunk)
        if need > self.max_len:
            raise RequestTooLarge(
                f"request {request.rid}: needs {need} cache positions "
                f"(prompt {P}, gen {G}, chunk {self.prefill_chunk}) "
                f"but max_len={self.max_len}"
            )
        if self.paged:
            n_pages = -(-need // self.page_size)
            if n_pages > self.pool.num_pages:
                # would head-of-line block forever — even an empty pool
                # could never map it
                raise RequestTooLarge(
                    f"request {request.rid}: needs {n_pages} pages "
                    f"(page_size {self.page_size}) but the pool only has "
                    f"{self.pool.num_pages}"
                )
        if self._draining:
            self.stats["shed"] += 1
            raise QueueFull(
                f"request {request.rid}: engine is draining — admission "
                f"is closed"
            )
        try:
            self.scheduler.submit(request)
        except QueueFull:
            self.stats["shed"] += 1
            raise

    def set_stream_callbacks(self, on_token=None, on_result=None) -> None:
        """Wire the step-boundary streaming surface (see the class
        docstring): ``on_token(rid, tokens, tick)`` per host sync that
        materialized tokens, ``on_result(result)`` once per recorded
        ``RequestResult``. Pass None to detach either."""
        self._on_token = on_token
        self._on_result = on_result

    def _emit_tokens(self, fl: _InFlight, tokens: Sequence[int],
                     tick: float) -> None:
        if self._on_token is not None:
            # a resumed request keeps its original rid (_resume_request), so
            # the stream is continuous across preemption
            self._on_token(fl.req.rid, list(tokens), tick)

    def _emit_result(self, result: RequestResult) -> None:
        if self._on_result is not None:
            self._on_result(result)

    def _drop_result(self, req: Request, status: str,
                     tokens: Sequence[int] = (),
                     admitted_at: Optional[float] = None) -> None:
        """Record a result for a request dropped OUTSIDE a slot (shed from
        the queue, or reaped while parked)."""
        self.results[req.rid] = RequestResult(
            rid=req.rid, prompt_len=len(req.prompt), tokens=list(tokens),
            arrival=req.arrival,
            admitted_at=self.clock if admitted_at is None else admitted_at,
            finished_at=self.clock, status=status,
        )
        self._emit_result(self.results[req.rid])

    def _next_admission(self) -> Optional[Request]:
        """The next admission candidate: the head of the queue once it has
        arrived — after reaping cancelled/expired heads (they shed here, at
        exactly the tick a free slot would otherwise have admitted them)."""
        while True:
            req = self.scheduler.peek_ready(self.clock)
            if req is None:
                return None
            if req.rid in self._cancelled:
                self.scheduler.drop_head()
                self._cancelled.discard(req.rid)
                self._drop_result(req, "cancelled")
                self.stats["cancelled"] += 1
                continue
            if req.deadline is not None and req.deadline <= self.clock:
                self.scheduler.drop_head()
                self._drop_result(req, "expired")
                self.stats["expired"] += 1
                continue
            return req

    def _next_parked(self) -> Optional[_Parked]:
        """The parked head due for resumption, reaping cancelled/expired
        parked entries (their partial tokens are returned)."""
        while self._parked:
            parked = self._parked[0]
            req = parked.req
            if req.rid in self._cancelled:
                self._parked.popleft()
                self._cancelled.discard(req.rid)
                self._drop_result(req, "cancelled", tokens=parked.generated,
                                  admitted_at=parked.admitted_at)
                self.stats["cancelled"] += 1
                continue
            if req.deadline is not None and req.deadline <= self.clock:
                self._parked.popleft()
                self._drop_result(req, "expired", tokens=parked.generated,
                                  admitted_at=parked.admitted_at)
                self.stats["expired"] += 1
                continue
            return parked
        return None

    def _resume_request(self, parked: _Parked) -> Request:
        """The internal request a parked entry resumes as: original prompt
        plus everything generated before the preemption, owing the
        remainder of the budget. Re-prefilling that prompt reproduces the
        victim's cache state exactly (prefill and decode agree on every
        cached position — the naive-oracle parity), and the prefix index
        remaps whatever published victim pages survived instead."""
        req = parked.req
        return Request(
            rid=req.rid,
            prompt=list(req.prompt) + [int(t) for t in parked.generated],
            max_new_tokens=req.max_new_tokens - len(parked.generated),
            arrival=req.arrival,
            deadline=req.deadline,
            priority=req.priority,
        )

    def _admit(self) -> None:
        """Admission: parked (preempted) requests resume first — they were
        already admitted once, so a drain still serves them — then the FIFO
        queue (closed while draining)."""
        if self.paged:
            return self._admit_paged()
        pool = self.pool
        while pool.n_free:
            parked = self._next_parked()
            if parked is not None:
                self._parked.popleft()
                req = self._resume_request(parked)
                # fast path: defer the slot's bookkeeping reset into the
                # first jitted prefill chunk, like any fresh admission
                slot = pool.allocate(reset=not self.fast)
                self._inflight[slot] = _InFlight(
                    req=req, slot=slot, admitted_at=parked.admitted_at,
                    fresh=self.fast, prior=list(parked.generated),
                    orig_req=parked.req,
                )
                self.stats["resumed"] += 1
                continue
            if self._draining:
                return
            req = self._next_admission()
            if req is None:
                return
            self.scheduler.pop_ready(self.clock)
            # fast path: defer the slot's bookkeeping reset into the first
            # jitted prefill chunk (fresh mask) — admission costs 0 dispatches
            slot = pool.allocate(reset=not self.fast)
            self._inflight[slot] = _InFlight(
                req=req, slot=slot, admitted_at=self.clock, fresh=self.fast
            )

    def _admit_paged(self) -> None:
        """Page-aware FIFO admission: peek the candidate (parked resumes
        first), map its shared prefix pages from the index, and admit only
        when the pool can cover the rest — climbing the exhaustion ladder
        first: (1) evict LRU prefix-index entries, (2) preempt
        strictly-lower-priority in-flight requests (most recently admitted
        first), and finally (3) block head-of-line, exactly like a missing
        slot would."""
        pool = self.pool
        while pool.n_free:
            parked = self._next_parked()
            if parked is not None:
                req = self._resume_request(parked)
            else:
                if self._draining:
                    return
                req = self._next_admission()
                if req is None:
                    return
            P, G = len(req.prompt), req.max_new_tokens
            need = required_cache_len(P, G, self.prefill_chunk)
            shared: list = []
            reuse = 0
            if self.prefix_index is not None:
                pages = self.prefix_index.lookup(req.prompt)
                pg, C = self.page_size, self.prefill_chunk
                # reuse ends on a prefill-chunk boundary — the donor's
                # chunks started there too, which is what makes its cached
                # K/V bit-identical to recomputing them — and leaves >= 1
                # prompt token to prefill, so the first generated token
                # comes from THIS request's own logits
                reuse = (min(len(pages) * pg, P - 1) // C) * C
                shared = pages[: -(-reuse // pg)]
            fresh_needed = pool.pages_needed(need, reuse)
            if not self._cover_pages(fresh_needed, shared, req.priority):
                return                      # head-of-line blocks on pages
            if parked is not None:
                self._parked.popleft()
            else:
                self.scheduler.pop_ready(self.clock)
            slot = pool.allocate_pages(need, shared=shared, reuse_len=reuse)
            self._inflight[slot] = _InFlight(
                req=req, slot=slot,
                admitted_at=(self.clock if parked is None
                             else parked.admitted_at),
                prefilled=reuse,
                prior=(list(parked.generated) if parked is not None else []),
                orig_req=(parked.req if parked is not None else None),
            )
            if parked is not None:
                self.stats["resumed"] += 1

    def _cover_pages(self, fresh_needed: int, shared: Sequence[int],
                     priority: int) -> bool:
        """Climb the exhaustion ladder until ``fresh_needed`` pages are
        free: evict LRU index entries, then preempt strictly-lower-priority
        victims (each preemption publishes the victim's computed pages, so
        eviction runs again behind it). Returns False when the ladder is
        exhausted and the candidate must block head-of-line."""
        pool = self.pool

        def evict():
            if self.prefix_index is None:
                return
            protect = set(shared)
            while (fresh_needed > pool.n_free_pages
                   and self.prefix_index.evict_lru(pool, protect)):
                pass

        evict()
        while fresh_needed > pool.n_free_pages:
            victim = self._select_victim(priority)
            if victim is None:
                return False
            self._preempt_one(victim)
            evict()
        return True

    def _retire(self, fl: _InFlight, at: Optional[float] = None,
                status: str = "ok") -> None:
        req = fl.orig_req or fl.req
        self.results[req.rid] = RequestResult(
            rid=req.rid,
            prompt_len=len(req.prompt),
            tokens=fl.prior + list(fl.generated),
            arrival=req.arrival,
            admitted_at=fl.admitted_at,
            finished_at=self.clock if at is None else at,
            status=status,
        )
        del self._inflight[fl.slot]
        self.pool.release(fl.slot)
        self._emit_result(self.results[req.rid])

    def _quarantine(self, fl: _InFlight, at: Optional[float] = None) -> None:
        """Retire a row whose dispatch produced non-finite logits: its slot
        (and pages) are reclaimed, the tokens of the poisoned dispatch are
        dropped, and the tokens generated before it are returned with
        status "quarantined". Row independence means no other slot saw the
        poison. The row's pages are NOT published to the prefix index
        (nothing after the last finite sync can be trusted)."""
        self._inject_bad.discard(fl.req.rid)
        self._retire(fl, at=at, status="quarantined")
        self.stats["quarantined"] += 1

    def _select_victim(self, priority: int) -> Optional[_InFlight]:
        """Preemption victim for an admission at ``priority``: a
        strictly-lower-priority in-flight request, most recently admitted
        first (it has the least sunk work; ties broken by slot id for
        determinism), skipping victims whose resume request could never be
        re-admitted (prompt + generated can outgrow the ring: prefill
        re-pads to chunk multiples)."""
        cands = [fl for fl in self._inflight.values()
                 if fl.req.priority < priority and self._resumable(fl)]
        if not cands:
            return None
        return max(cands, key=lambda fl: (fl.admitted_at, fl.slot))

    def _resumable(self, fl: _InFlight) -> bool:
        """Whether a preempted ``fl`` could be admitted again: its resume
        prompt (original prompt + everything generated) must still fit the
        ring and the page pool after prefill-chunk padding."""
        P = len(fl.req.prompt) + len(fl.generated)
        G = fl.remaining
        if G < 1:
            return False
        need = required_cache_len(P, G, self.prefill_chunk)
        if need > self.max_len:
            return False
        if self.paged and -(-need // self.page_size) > self.pool.num_pages:
            return False
        return True

    def _preempt_one(self, fl: _InFlight) -> None:
        """Preempt ``fl``: publish its computed pages to the prefix index
        (page remapping — a resume maps them back instead of recomputing;
        if pool pressure evicts them first, resume re-prefills, still
        bit-identical), park the request host-side, and release the slot.

        The cache's valid positions cover the prompt plus all generated
        tokens EXCEPT the last (its KV lands with the next decode feed), so
        that is exactly the token prefix published."""
        if self.prefix_index is not None:
            if fl.prefill_done:
                covered = list(fl.req.prompt) + fl.generated[:-1]
            else:
                # mid-prefill: the committed chunks cover prompt[:prefilled]
                covered = list(fl.req.prompt[:fl.prefilled])
            if len(covered) >= self.page_size:
                self.prefix_index.publish(covered, self.pool, fl.slot)
        self._parked.append(_Parked(
            req=fl.orig_req or fl.req,
            generated=fl.prior + list(fl.generated),
            admitted_at=fl.admitted_at,
        ))
        del self._inflight[fl.slot]
        self.pool.release(fl.slot)
        self.stats["preempted"] += 1

    def preempt(self, rid: int) -> None:
        """Manually preempt an in-flight request by id: its slot and pages
        are released and the request parks host-side, resuming through
        normal admission (before any queued request) with bit-identical
        final tokens. Raises KeyError for a request not in flight and
        ValueError when the resume could never fit (see ``_resumable``)."""
        for fl in self._inflight.values():
            if fl.req.rid == rid:
                if not self._resumable(fl):
                    raise ValueError(
                        f"request {rid} cannot be preempted: its resume "
                        f"prompt would exceed the engine's capacity"
                    )
                self._preempt_one(fl)
                return
        raise KeyError(f"request {rid} is not in flight")

    def cancel(self, rid: int) -> bool:
        """Client cancellation. Queued and parked requests are dropped at
        the next step boundary; an in-flight request is removed at its next
        step/horizon boundary, returning the tokens generated so far with
        status "cancelled". Returns False when the rid is unknown (already
        finished, or never submitted)."""
        if any(fl.req.rid == rid for fl in self._inflight.values()):
            self._cancelled.add(rid)
            return True
        if any(p.req.rid == rid for p in self._parked):
            self._cancelled.add(rid)
            return True
        req = self.scheduler.remove(rid)
        if req is not None:
            # dropped from the queue immediately; the result is stamped
            # with the current clock, same as a boundary reap
            self._drop_result(req, "cancelled")
            self.stats["cancelled"] += 1
            return True
        return False

    def request_drain(self) -> None:
        """Graceful drain (the SIGTERM contract): close admission — new
        ``submit`` calls shed with ``QueueFull``, queued requests stay
        unserved — but finish everything in flight INCLUDING parked
        (preempted) requests, which were already admitted once."""
        self._draining = True

    @property
    def draining(self) -> bool:
        return self._draining

    def _reap(self) -> None:
        """Step-boundary reaping: cancel and expire in-flight requests
        (their partial tokens are returned; pages reclaimed atomically via
        the normal release path). Queued/parked reaping happens in
        admission, at the tick a slot would have considered them."""
        for slot in sorted(self._inflight):
            fl = self._inflight[slot]
            rid = fl.req.rid
            if rid in self._cancelled:
                self._cancelled.discard(rid)
                self._retire(fl, status="cancelled")
                self.stats["cancelled"] += 1
            elif (fl.req.deadline is not None
                    and fl.req.deadline <= self.clock):
                self._retire(fl, status="expired")
                self.stats["expired"] += 1

    def check_invariants(self) -> None:
        """Audit the pool against every external page pin the engine knows
        about (the prefix index); raises AssertionError on violation. The
        chaos harness calls this after every step; ``REPRO_POOL_CHECK=1``
        turns it on per-step everywhere."""
        ext: dict[int, int] = {}
        if self.prefix_index is not None:
            for page in self.prefix_index.pages():
                ext[page] = ext.get(page, 0) + 1
        self.pool.check_invariants(external_refs=ext)

    def inject_bad(self, rid: int) -> None:
        """Chaos hook: treat ``rid``'s row as non-finite at its next host
        sync (prefill completion or decode boundary) — exercises the
        quarantine path deterministically without poisoning device state."""
        self._inject_bad.add(rid)

    def _finish_prefill(self, fl: _InFlight, first: int) -> None:
        if self.prefix_index is not None:
            # publish at prefill COMPLETION (not retirement) so concurrent
            # requests right behind the donor already share its pages
            self.prefix_index.publish(fl.req.prompt, self.pool, fl.slot)
        fl.generated.append(first)
        fl.cur_token = first
        self.stats["generated_tokens"] += 1
        self._emit_tokens(fl, [first], self.clock)
        if fl.done:
            self._retire(fl)

    def _prefill_phase(self) -> None:
        C = self.prefill_chunk
        for slot in sorted(self._inflight):
            fl = self._inflight[slot]
            if fl.prefill_done:
                continue
            prompt = np.asarray(fl.req.prompt, np.int32)
            n = min(C, len(prompt) - fl.prefilled)
            chunk = np.zeros((1, C), np.int32)
            chunk[0, :n] = prompt[fl.prefilled:fl.prefilled + n]
            tok, bad, self.pool.cache = self._prefill_fn(
                self.params, jnp.asarray(chunk), self.pool.cache,
                jnp.int32(slot), jnp.int32(n),
            )
            fl.prefilled += n
            self.stats["prefill_chunks"] += 1
            self.stats["prefill_dispatches"] += 1
            if fl.prefill_done:
                # bad is examined only at syncs that happen anyway (here:
                # prefill completion) — NaN quarantine costs zero extra
                # host round trips
                self.stats["host_syncs"] += 1
                if bool(bad[0]) or fl.req.rid in self._inject_bad:
                    self._quarantine(fl)
                else:
                    self._finish_prefill(fl, int(tok[0]))

    def _prefill_phase_fast(self) -> None:
        """One full-width [B, C] dispatch covering every prefilling slot in
        slot position (non-prefilling slots ride along masked — see
        ``_prefill_masked``); syncs only when some row consumed its final
        prompt chunk this step."""
        C = self.prefill_chunk
        pending = [self._inflight[s] for s in sorted(self._inflight)
                   if not self._inflight[s].prefill_done]
        if not pending:
            return
        B = self.num_slots
        tokens = np.zeros((B, C), np.int32)
        n_valid = np.ones((B,), np.int32)   # pads select position 0's logits
        fresh = np.zeros((B,), bool)
        is_real = np.zeros((B,), bool)
        for fl in pending:
            s = fl.slot
            prompt = np.asarray(fl.req.prompt, np.int32)
            n = min(C, len(prompt) - fl.prefilled)
            tokens[s, :n] = prompt[fl.prefilled:fl.prefilled + n]
            n_valid[s], fresh[s], is_real[s] = n, fl.fresh, True
        tok, bad, self.pool.cache = self._prefill_multi_fn(
            self.params, jnp.asarray(tokens), self.pool.cache,
            jnp.asarray(n_valid), jnp.asarray(fresh), jnp.asarray(is_real),
        )
        self.stats["prefill_chunks"] += len(pending)
        self.stats["prefill_dispatches"] += 1
        finishers = []
        for fl in pending:
            if fl.fresh:
                fl.fresh = False
                # the deferred fresh-mask reset just committed inside the
                # jitted prefill — the pool stops tracking it as pending
                self.pool.note_reset_committed(fl.slot)
            fl.prefilled += int(n_valid[fl.slot])
            if fl.prefill_done:
                finishers.append(fl)
        if finishers:
            tok_np = np.asarray(tok)      # materialize once for all rows
            bad_np = np.asarray(bad)
            self.stats["host_syncs"] += 1
            for fl in finishers:
                if bool(bad_np[fl.slot]) or fl.req.rid in self._inject_bad:
                    self._quarantine(fl)
                else:
                    self._finish_prefill(fl, int(tok_np[fl.slot]))

    def _decode_phase(self) -> None:
        active = [fl for fl in self._inflight.values()
                  if fl.prefill_done and not fl.done]
        if not active:
            return
        tokens = np.zeros((self.num_slots, 1), np.int32)
        active_mask = np.zeros((self.num_slots,), bool)
        for fl in active:
            tokens[fl.slot, 0] = fl.cur_token
            active_mask[fl.slot] = True
        next_tok, bad, self.pool.cache = self._decode_fn(
            self.params, jnp.asarray(tokens), self.pool.cache,
            jnp.asarray(active_mask),
        )
        next_np = np.asarray(next_tok)
        bad_np = np.asarray(bad)
        self.stats["decode_steps"] += 1
        self.stats["decode_dispatches"] += 1
        self.stats["host_syncs"] += 1
        for fl in active:
            if bool(bad_np[fl.slot]) or fl.req.rid in self._inject_bad:
                self._quarantine(fl)
                continue
            tok = int(next_np[fl.slot])
            fl.generated.append(tok)
            fl.cur_token = tok
            self.stats["generated_tokens"] += 1
            self._emit_tokens(fl, [tok], self.clock)
            if fl.done:
                self._retire(fl)

    def _choose_horizon(self, active) -> int:
        """Adaptive K: fuse as many decode steps as possible without moving
        any retire/admit/prefill event off its stepwise-path clock tick.
        The result is rounded DOWN to a power of two — every cap below is an
        upper bound, so the tick-exact schedule is preserved while the
        number of distinct compiled scans stays log2(decode_horizon)+1."""
        k = min(self.decode_horizon, min(fl.remaining for fl in active))
        if any(not fl.prefill_done for fl in self._inflight.values()):
            # a prefilling slot advances one chunk per engine tick; a long
            # horizon would starve it, so fall back to stepwise cadence
            return 1
        deadlines = [fl.req.deadline for fl in self._inflight.values()
                     if fl.req.deadline is not None]
        if deadlines:
            # expiry is reaped at step starts (clock >= deadline); the
            # horizon must not coast past the earliest one, so the reap
            # lands on the same tick as the stepwise path (the deadline
            # twin of the arrival cap below)
            k = min(k, max(1, int(math.ceil(min(deadlines) - self.clock))))
        if self.pool.n_free:
            nxt = self.scheduler.peek_arrival()
            if nxt is not None:
                if nxt <= self.clock:
                    # head is ready and a slot freed mid-step (prefill
                    # retire): admit on the very next tick, like stepwise
                    return 1
                # a free slot is waiting on the FIFO head's arrival:
                # admission must not be delayed past it by a long horizon
                k = min(k, int(math.ceil(nxt - self.clock)))
        return _pow2_floor(k)

    def _decode_phase_fast(self) -> int:
        """Fused decode horizon; returns the number of decode steps run (the
        engine-clock ticks this phase consumed)."""
        active = [fl for fl in self._inflight.values()
                  if fl.prefill_done and not fl.done]
        if not active:
            return 1
        k = self._choose_horizon(active)
        tokens = np.zeros((self.num_slots, 1), np.int32)
        remaining = np.zeros((self.num_slots,), np.int32)
        for fl in active:
            tokens[fl.slot, 0] = fl.cur_token
            # cap at k: the scan must not generate past this horizon even if
            # bookkeeping and the device view of the budget ever diverged
            remaining[fl.slot] = min(fl.remaining, k)
        toks, bad, self.pool.cache = self._decode_horizon_fn(
            self.params, jnp.asarray(tokens), self.pool.cache,
            jnp.asarray(remaining), k=k,
        )
        toks_np = np.asarray(toks)        # the horizon's single host sync
        bad_np = np.asarray(bad)
        self.stats["decode_steps"] += k
        self.stats["decode_dispatches"] += 1
        self.stats["host_syncs"] += 1
        for fl in active:
            if bool(bad_np[fl.slot]) or fl.req.rid in self._inject_bad:
                # the bad flag is OR-ed across the horizon: the whole
                # horizon's tokens for this row are untrusted and dropped
                # (other rows are untouched — row independence)
                self._quarantine(fl, at=self.clock + k - 1)
                continue
            new = [int(t) for t in toks_np[fl.slot, :k]]
            fl.generated.extend(new)
            fl.cur_token = new[-1]
            self.stats["generated_tokens"] += k
            self._emit_tokens(fl, new, self.clock)
            if fl.done:
                # the last token landed on the horizon's final tick — stamp
                # completion with that tick, matching the stepwise timeline
                self._retire(fl, at=self.clock + k - 1)
        return k

    def step(self) -> None:
        """One engine iteration: reap (deadlines/cancellations) → admit →
        chunked prefill → batched decode. On the fast path a fused decode
        horizon advances the engine clock by K ticks (one tick per
        generated-token step, matching the stepwise path's timeline)."""
        t0 = time.monotonic()
        self._reap()
        self._admit()
        occ_pre = len(self._inflight) / self.num_slots
        if self.fast:
            self._prefill_phase_fast()
            # a gen-at-prefill request may have retired above; ticks 2..K of
            # the horizon see that state (no admissions can land mid-horizon
            # — the arrival cap ends the horizon at the next arrival — and
            # decode retires only on the final tick), so the occupancy
            # accounting stays tick-identical to the stepwise path
            occ_post = len(self._inflight) / self.num_slots
            ticks = self._decode_phase_fast()
            self.stats["occupancy_sum"] += occ_pre + occ_post * (ticks - 1)
        else:
            self._prefill_phase()
            self._decode_phase()
            ticks = 1
            self.stats["occupancy_sum"] += occ_pre
        self.stats["engine_steps"] += ticks
        self.clock += float(ticks)
        if self.straggler.observe(self.stats["engine_steps"],
                                  time.monotonic() - t0):
            self.stats["straggler_steps"] += 1
        if self._pool_check:
            self.check_invariants()

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> dict[int, RequestResult]:
        """Submit ``requests`` (if given), step until fully drained, and
        return — draining ``self.results`` so a long-lived engine doesn't
        retain every request it ever served. While ``request_drain()`` is
        in effect queued requests are NOT served (admitted + parked work
        still finishes)."""
        for r in requests or ():
            self.submit(r)
        while (self._inflight or self._parked
               or (not self._draining and self.scheduler.pending())):
            self.step()
        out, self.results = self.results, {}
        return out

    # ------------------------------------------------- static introspection
    # The lint layer (analysis/lint) reasons about the serve paths WITHOUT
    # running them: which jits exist, what shapes they can be dispatched at,
    # and what warmup() compiles. warmup() itself is driven off the same
    # enumeration so the two can never drift apart.

    def warmup_shapes(self) -> set:
        """The (jit, dim) pairs ``warmup()`` compiles: the single full-width
        prefill shape and every power-of-two decode-scan horizon on the fast
        path; the batch-1 stepwise shapes otherwise."""
        if not self.fast:
            return {("prefill", 1), ("decode", 1)}
        horizons = {1 << i for i in range(self.decode_horizon.bit_length())
                    if 1 << i <= self.decode_horizon}
        return ({("prefill_multi", self.num_slots)}
                | {("decode_horizon", k) for k in horizons})

    def dispatch_shapes(self) -> set:
        """Every (jit, dim) the serving loop can actually dispatch: the
        full-width masked prefill is ONE compiled shape ([num_slots, C] in
        slot position), horizons ``pow2_floor(k)`` for 1 <= k <=
        decode_horizon. The recompilation-guard lint rule checks this set is
        CLOSED under ``warmup_shapes()`` — a live step never compiles."""
        if not self.fast:
            return {("prefill", 1), ("decode", 1)}
        horizons = {_pow2_floor(k)
                    for k in range(1, self.decode_horizon + 1)}
        return ({("prefill_multi", self.num_slots)}
                | {("decode_horizon", k) for k in horizons})

    def serve_jit_specs(self) -> dict:
        """{name: (jit_fn, impl_fn, args, static_kwargs)} for every jitted
        serve path, with representative arguments at the widest warmed shape
        (prefill_multi at P=num_slots, decode_horizon at k=decode_horizon).
        ``params``/``cache`` are the engine's live (possibly sharded) arrays
        so lowering sees the real placements; tracing/lowering never
        executes, so donation does not invalidate the pool."""
        B, C = self.num_slots, self.prefill_chunk
        cache = self.pool.cache
        return {
            "prefill": (
                self._prefill_fn, self._impls["prefill"],
                (self.params, jnp.zeros((1, C), jnp.int32), cache,
                 jnp.int32(0), jnp.int32(C)),
                {},
            ),
            "decode": (
                self._decode_fn, self._impls["decode"],
                (self.params, jnp.zeros((B, 1), jnp.int32), cache,
                 jnp.ones((B,), bool)),
                {},
            ),
            "prefill_multi": (
                self._prefill_multi_fn, self._impls["prefill_multi"],
                (self.params, jnp.zeros((B, C), jnp.int32), cache,
                 jnp.ones((B,), jnp.int32), jnp.zeros((B,), bool),
                 jnp.ones((B,), bool)),
                {},
            ),
            "decode_horizon": (
                self._decode_horizon_fn, self._impls["decode_horizon"],
                (self.params, jnp.zeros((B, 1), jnp.int32), cache,
                 jnp.full((B,), self.decode_horizon, jnp.int32)),
                {"k": self.decode_horizon},
            ),
        }

    def lowered_serve_jits(self) -> dict:
        """{name: jax.stages.Lowered} for the four serve jits — traced and
        lowered (StableHLO), NOT compiled or run."""
        return {
            name: fn.lower(*args, **kw)
            for name, (fn, _, args, kw) in self.serve_jit_specs().items()
        }

    def warmup(self) -> None:
        """Compile every serving shape ahead of traffic — exactly the
        ``warmup_shapes()`` set: the power-of-two prefill widths and decode
        horizons this engine can dispatch (the stepwise shapes when
        ``fast=False``). Runs tiny throwaway requests through the real loop
        so a production engine (or a benchmark) serves steady state instead
        of hitting XLA compiles mid-traffic.

        Warmup is side-effect-free: stats, clock, results, straggler EMA,
        the prefix index (warmup publishes throwaway ``[0]`` prompts into a
        TEMPORARY index, never the live one) and the pool — cache contents
        AND bookkeeping, down to free-list order — are all bit-identical
        before/after (the warmup-pollution regression test pins this)."""
        if self.scheduler.pending() or self._inflight or self._parked:
            raise RuntimeError(
                "warmup() needs an idle engine — it runs (and discards) "
                "throwaway requests through the serving loop"
            )
        pool = self.pool
        snap_stats, snap_clock = dict(self.stats), self.clock
        snap_order = list(self.scheduler.admitted_order)
        snap_results = dict(self.results)
        snap_straggler, self.straggler = self.straggler, StragglerMonitor()
        # throwaway warmup traffic must not stream into a wired front-end
        snap_cbs = (self._on_token, self._on_result)
        self._on_token = self._on_result = None
        # deep-copy the cache: every jit donates it, so warmup traffic would
        # otherwise overwrite the pre-warmup buffers in place
        snap_cache = jax.tree.map(jnp.copy, pool.cache)
        snap_free, snap_alloc = set(pool._free), set(pool._allocated)
        snap_pending = set(pool._pending_reset)
        if pool.paged:
            snap_pages = list(pool._free_pages)
            snap_ref = list(pool._page_ref)
            snap_slot_pages = {s: list(p) for s, p in
                               pool._slot_pages.items()}
            snap_cow = pool.cow_copies
        snap_index = self.prefix_index
        if snap_index is not None:
            self.prefix_index = PrefixIndex(self.page_size)
        try:
            shapes = self.warmup_shapes()
            rid = -1
            widths = sorted(w for j, w in shapes if j.startswith("prefill"))
            for w in widths:             # prefill widths (no decode: gen 1)
                self.run([Request(rid=rid - j, prompt=[0], max_new_tokens=1)
                          for j in range(w)])
                rid -= w
            horizons = sorted(k for j, k in shapes if j.startswith("decode"))
            for k in horizons:           # decode horizons
                self.run([Request(rid=rid, prompt=[0],
                                  max_new_tokens=min(k + 1, self.max_len))])
                rid -= 1
        finally:
            if snap_index is not None:
                # release the temporary index's page pins, then restore the
                # live index untouched
                self.prefix_index.clear(pool)
                self.prefix_index = snap_index
            pool.cache = (snap_cache if pool.shardings is None
                          else jax.device_put(snap_cache, pool.shardings))
            pool._free, pool._allocated = snap_free, snap_alloc
            pool._pending_reset = snap_pending
            if pool.paged:
                pool._free_pages = snap_pages
                pool._page_ref = snap_ref
                pool._slot_pages = snap_slot_pages
                pool.cow_copies = snap_cow
            self.stats, self.clock = snap_stats, snap_clock
            self.results = snap_results
            self.straggler = snap_straggler
            self._on_token, self._on_result = snap_cbs
            self.scheduler.admitted_order.clear()
            self.scheduler.admitted_order.extend(snap_order)

    # ------------------------------------------------------------- metrics
    def mean_occupancy(self) -> float:
        steps = self.stats["engine_steps"]
        return self.stats["occupancy_sum"] / steps if steps else 0.0

    def syncs_per_token(self) -> float:
        gen = self.stats["generated_tokens"]
        return self.stats["host_syncs"] / gen if gen else 0.0
