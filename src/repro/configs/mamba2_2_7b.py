"""Mamba2-2.7B [arXiv:2405.21060; unverified] — attention-free SSD."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    act="silu_glu",
    norm="rms",
    rope=False,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    max_seq=1048576,
)
