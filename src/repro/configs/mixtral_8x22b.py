"""Mixtral-8x22B [arXiv:2401.04088] — 8 experts top-2, sliding-window attn
(window 4096 per the assignment note ⇒ bounded KV, long_500k applicable)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    act="silu_glu",
    norm="rms",
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1e6,
    tie_embeddings=False,
    max_seq=65536,
)
