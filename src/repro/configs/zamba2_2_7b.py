"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention
blocks (2 parameter-shared transformer blocks interleaved every 6 SSM layers)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    act="gelu_glu",
    norm="rms",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,
    hybrid_n_shared_blocks=2,
    tie_embeddings=True,
    max_seq=4096,
)
