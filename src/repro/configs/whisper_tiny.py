"""Whisper-tiny [arXiv:2212.04356; unverified] — enc-dec, conv audio
frontend STUBBED (input_specs supplies precomputed frame embeddings)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,           # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    norm="ln",
    qkv_bias=True,
    rope=False,
    tie_embeddings=True,
    enc_seq=1500,
    max_seq=532480,       # decoder learned-pos table sized for assigned shapes
    frontend="audio_stub",
)
