"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from ..models.config import ModelConfig
from . import (  # noqa: E402
    chameleon_34b,
    gemma_7b,
    llama4_scout_17b_a16e,
    mamba2_2_7b,
    mistral_nemo_12b,
    mixtral_8x22b,
    qwen2_0_5b,
    whisper_tiny,
    yi_34b,
    zamba2_2_7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_0_5b,
        yi_34b,
        mistral_nemo_12b,
        gemma_7b,
        llama4_scout_17b_a16e,
        mixtral_8x22b,
        chameleon_34b,
        whisper_tiny,
        zamba2_2_7b,
        mamba2_2_7b,
    )
}


def list_archs() -> list[str]:
    return sorted(ARCHS)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name.endswith("-smoke"):
        name, smoke = name[: -len("-smoke")], True
    cfg = ARCHS[name]
    return cfg.smoke() if smoke else cfg
