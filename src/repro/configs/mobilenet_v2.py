"""MobileNetV2-family CNN [arXiv:1801.04381] — the paper's own experimental
architecture (Nagel et al. evaluate DFQ on MobileNetV1/V2 + ResNet18).

Not part of the LM pool; built in `repro.models.cnn` with BatchNorm + ReLU6
so the FULL paper pipeline (BN fold → ReLU6→ReLU → CLE → BA → analytic BC)
applies exactly. This module exposes the config for the benchmarks.
"""
from ..models.cnn import CNNConfig

CONFIG = CNNConfig(
    name="mobilenet_v2",
    in_channels=3,
    num_classes=8,
    width=16,
    blocks=((1, 16, 1), (4, 24, 2), (4, 24, 1), (4, 32, 2), (4, 32, 1)),
    img_size=32,
    act_clip=6.0,
)
