"""Yi-34B [arXiv:2403.04652; hf] — llama-arch dense, GQA kv=8."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    act="silu_glu",
    norm="rms",
    rope_theta=5e6,
    tie_embeddings=False,
    max_seq=200000,
)
