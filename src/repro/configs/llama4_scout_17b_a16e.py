"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] —
MoE 16 experts top-1 + shared expert, early fusion (vision frontend stubbed)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    act="silu_glu",
    norm="rms",
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    rope_theta=5e5,
    tie_embeddings=False,
    max_seq=262144,
    frontend="vision_stub",
)
