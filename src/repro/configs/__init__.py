"""Assigned architecture configs (public literature) + the paper's own CNN."""

from .registry import ARCHS, get_config, list_archs  # noqa: F401
