"""Chameleon-34B [arXiv:2405.09818; unverified] — early-fusion VQ image
tokens share the text vocab; QK-norm for stability (blocks q↔k CLE —
DESIGN.md §Arch-applicability)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    act="silu_glu",
    norm="rms",
    qk_norm=True,
    tie_embeddings=False,
    max_seq=4096,
    frontend="vision_stub",
)
