"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA kv=8,
128k context, head_dim 128 (d_model 5120 / 32 heads ⇒ 160, but Nemo pins 128)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    act="silu_glu",
    norm="rms",
    rope_theta=1e6,
    tie_embeddings=False,
    max_seq=131072,
)
