"""Qwen2-0.5B [arXiv:2407.10671; hf] — dense, GQA kv=2, QKV bias."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    act="silu_glu",
    norm="rms",
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    max_seq=131072,
)
