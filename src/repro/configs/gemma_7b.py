"""Gemma-7B [arXiv:2403.08295; hf] — GeGLU, head_dim 256, MQA only on 2B."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    act="gelu_glu",
    norm="rms",
    tie_embeddings=True,
    max_seq=8192,
)
