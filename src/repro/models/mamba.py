"""Mamba2 (state-space duality, arXiv:2405.21060) mixer — TPU-native chunked
SSD formulation.

The chunked algorithm recasts the selective-scan as dense matmuls (MXU
friendly): within chunks of length Q the recurrence is an attention-like
masked ``(C·Bᵀ ⊙ decay) · X`` product; across chunks a short ``lax.scan``
carries the [H, P, S] state. Decode is the O(1) single-step recurrence.

Param layout per layer (leading scan dims broadcast):
  in_proj  [D, 2·din + 2·G·S + H]   → z, x, B, C, dt
  conv_w   [W, din + 2·G·S]         depthwise causal conv over (x, B, C)
  conv_b   [din + 2·G·S]
  A_log    [H]      (A = −exp(A_log), scalar per head)
  D        [H]      skip
  dt_bias  [H]
  norm_w   [din]    gated RMSNorm before out_proj
  out_proj [din, D]
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import linear, rms_norm


def ssm_dims(cfg: ModelConfig):
    din = cfg.d_inner
    H = cfg.ssm_heads
    G, S = cfg.ssm_n_groups, cfg.ssm_state
    d_proj = 2 * din + 2 * G * S + H
    d_conv = din + 2 * G * S
    return din, H, G, S, d_proj, d_conv


def _split_proj(proj, cfg: ModelConfig):
    din, H, G, S, _, _ = ssm_dims(cfg)
    z = proj[..., :din]
    xbc = proj[..., din : din + din + 2 * G * S]
    dt = proj[..., -H:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state: Optional[jnp.ndarray]):
    """Depthwise causal conv1d, width W. conv_state: [B, W-1, C] past inputs
    (decode) or None (prefill, zero-padded left)."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)            # [B, T+W-1, C]
    out = sum(
        full[:, i : i + xbc.shape[1], :] * conv_w[i] for i in range(W)
    )
    new_state = full[:, -(W - 1) :, :]
    return jax.nn.silu(out + conv_b), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int, unroll: bool = False):
    """Chunked SSD scan.

    x: [b, T, H, P]; dt: [b, T, H] (post-softplus); A: [H] (negative);
    B, C: [b, T, G, S]. Returns y: [b, T, H, P] and final state [b, H, P, S].
    """
    b, T, H, P = x.shape
    G, S = B.shape[-2], B.shape[-1]
    Q = min(chunk, T)
    n = T // Q
    hpg = H // G

    xb = x.reshape(b, n, Q, H, P)
    dtb = dt.reshape(b, n, Q, H)
    Bb = B.reshape(b, n, Q, G, S)
    Cb = C.reshape(b, n, Q, G, S)

    dA = dtb * A                                           # [b,n,Q,H] (≤ 0)
    cum = jnp.cumsum(dA, axis=2)                           # within-chunk
    total = cum[:, :, -1, :]                               # [b,n,H]

    # intra-chunk: masked decay kernel  L[q,k] = exp(cum_q − cum_k), q ≥ k
    CB = jnp.einsum("bnqgs,bnkgs->bngqk", Cb, Bb)          # [b,n,G,Q,Q]
    CB = jnp.repeat(CB, hpg, axis=2)                       # [b,n,H,Q,Q]
    cum_h = cum.transpose(0, 1, 3, 2)                      # [b,n,H,Q]
    logL = cum_h[..., :, None] - cum_h[..., None, :]       # [b,n,H,Q,K]
    qk_mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(qk_mask, jnp.exp(logL), 0.0)
    dt_k = dtb.transpose(0, 1, 3, 2)[:, :, :, None, :]     # [b,n,H,1,K]
    M = CB * (L * dt_k).astype(CB.dtype)
    y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", M.astype(x.dtype), xb)

    # chunk-local end states: S_loc = Σ_k exp(total − cum_k) dt_k B_k ⊗ x_k
    w_end = jnp.exp(total[:, :, None, :] - cum) * dtb      # [b,n,Q,H]
    B_h = jnp.repeat(Bb, hpg, axis=3)                      # [b,n,Q,H,S]
    S_loc = jnp.einsum(
        "bnqhs,bnqhp->bnhps", (B_h * w_end[..., None]).astype(x.dtype), xb
    )

    # inter-chunk scan: S_n = exp(total_n)·S_{n−1} + S_loc_n
    def body(carry, inp):
        s_prev = carry
        tot, s_loc = inp
        s_new = jnp.exp(tot)[:, :, None, None] * s_prev + s_loc
        return s_new, s_prev

    from .layers import scan_layers

    s0 = jnp.zeros((b, H, P, S), jnp.float32)
    s_final, s_prevs = scan_layers(
        body,
        s0,
        (total.transpose(1, 0, 2), S_loc.transpose(1, 0, 2, 3, 4).astype(jnp.float32)),
        unroll,
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)             # [b,n,H,P,S]

    # inter-chunk contribution: y_inter_q = exp(cum_q) · C_q · S_prev
    C_h = jnp.repeat(Cb, hpg, axis=3)                      # [b,n,Q,H,S]
    y_inter = jnp.einsum(
        "bnqhs,bnhps->bnqhp", C_h, s_prevs.astype(x.dtype)
    ) * jnp.exp(cum)[..., None].astype(x.dtype)

    y = (y_intra + y_inter).reshape(b, T, H, P)
    return y, s_final


def mamba_block(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
    capture: bool = False,
):
    """Full Mamba2 block. state (decode): {"ssm": [B,H,P,S] fp32,
    "conv": [B,W-1,d_conv]}. Returns (out, new_state, stats)."""
    bsz, T, D = x.shape
    din, H, G, S, _, _ = ssm_dims(cfg)
    P = cfg.ssm_head_dim
    stats = {}
    if capture:
        stats["ssm_in"] = jnp.mean(x.reshape(-1, D), 0)

    proj = linear(x, p["in_proj"], p.get("in_bias"))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)

    xs = xbc[..., :din].reshape(bsz, T, H, P)
    B = xbc[..., din : din + G * S].reshape(bsz, T, G, S)
    C = xbc[..., din + G * S :].reshape(bsz, T, G, S)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [b,T,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                     # [H]

    if state is None or T > 1:
        # pad T to a chunk multiple; padded steps get dt = 0 (decay exp(0·A)=1
        # and increment dt·Bx = 0 ⇒ state and outputs are exactly unaffected)
        Q = min(cfg.ssm_chunk, max(T, 1))
        pad = (-T) % Q
        if pad:
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B_p = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
            C_p = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            xs_p, dt_p, B_p, C_p = xs, dt, B, C
        y, s_final = ssd_chunked(xs_p, dt_p, A, B_p, C_p, Q,
                                 unroll=cfg.unroll_layers)
        y = y[:, :T]
    else:
        # O(1) decode recurrence
        s_prev = state["ssm"]
        dA = jnp.exp(dt[:, 0] * A)                                   # [b,H]
        B_h = jnp.repeat(B[:, 0], H // G, axis=1)                    # [b,H,S]
        inc = jnp.einsum("bhs,bhp->bhps", B_h * dt[:, 0][..., None], xs[:, 0])
        s_final = dA[:, :, None, None] * s_prev + inc.astype(jnp.float32)
        C_h = jnp.repeat(C[:, 0], H // G, axis=1)
        y = jnp.einsum("bhps,bhs->bhp", s_final.astype(x.dtype), C_h)[:, None]

    y = y + xs * p["D"][:, None].astype(x.dtype)
    y = y.reshape(bsz, T, din)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    if capture:
        stats["ssm_out_in"] = jnp.mean(y.reshape(-1, din), 0)
    out = linear(y, p["out_proj"], p.get("out_bias"))

    new_state = None
    if state is not None:
        new_state = {"ssm": s_final, "conv": new_conv.astype(state["conv"].dtype)}
    return out, new_state, stats


def init_mamba_params(key, cfg: ModelConfig, dtype) -> dict:
    din, H, G, S, d_proj, d_conv = ssm_dims(cfg)
    D = cfg.d_model
    k = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(D)
    dt = jnp.exp(
        jax.random.uniform(k[2], (H,)) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": (jax.random.normal(k[0], (D, d_proj)) * scale_in).astype(dtype),
        "in_bias": jnp.zeros((d_proj,), dtype),
        "conv_w": (jax.random.normal(k[1], (cfg.ssm_conv_width, d_conv)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_conv,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_w": jnp.ones((din,), dtype),
        "out_proj": (jax.random.normal(k[3], (din, D)) * (1.0 / jnp.sqrt(din))).astype(dtype),
        "out_bias": jnp.zeros((D,), dtype),
    }
