"""Decoder-only LM covering the dense / moe / vlm / ssm / hybrid families.

Layers are scan-stacked (params carry a leading [L] dim) to bound HLO size at
production depth; the hybrid (zamba2) family scans homogeneous Mamba segments
and interleaves the *shared* attention blocks between segments.

The class exposes:  init / apply (train fwd) / loss / init_cache / prefill /
decode_step / dfq_plan / calibration_stats — everything the launcher, the
dry-run, and the DFQ pipeline need.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.graph import (
    DFQPlan,
    DensePairOp,
    NormFoldOp,
    QKPairOp,
    VBiasAbsorbOp,
    VOPairOp,
    WeightSite,
)
from .config import ModelConfig
from .layers import (
    AttnDims,
    apply_norm,
    attention_block,
    causal_mask,
    linear,
    mlp_block,
    moe_block,
    scan_layers,
)
from .mamba import init_mamba_params, mamba_block, ssm_dims


def _init_linear(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def _norm_params(cfg: ModelConfig, d: int, dtype):
    p = {"w": jnp.ones((d,), dtype)}
    if cfg.norm == "ln":
        p["b"] = jnp.zeros((d,), dtype)
    return p


class LMModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _init_attn(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = {
            "wq": _init_linear(ks[0], cfg.d_model, cfg.attn_dim, dtype),
            "wk": _init_linear(ks[1], cfg.d_model, cfg.kv_dim, dtype),
            "wv": _init_linear(ks[2], cfg.d_model, cfg.kv_dim, dtype),
            "wo": _init_linear(ks[3], cfg.attn_dim, cfg.d_model, dtype),
            "bo": jnp.zeros((cfg.d_model,), dtype),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.attn_dim,), dtype)
            p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
            p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
            p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
        return p

    def _init_mlp(self, key, dtype, d_ff=None):
        cfg = self.cfg
        f = d_ff or cfg.d_ff
        ks = jax.random.split(key, 3)
        p = {
            "wu": _init_linear(ks[0], cfg.d_model, f, dtype),
            "wd": _init_linear(ks[1], f, cfg.d_model, dtype),
            "bd": jnp.zeros((cfg.d_model,), dtype),
        }
        if cfg.act.endswith("_glu"):
            p["wg"] = _init_linear(ks[2], cfg.d_model, f, dtype)
        return p

    def _init_moe(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
        experts = {
            "wu": (jax.random.normal(ks[0], (E, D, F)) / D ** 0.5).astype(dtype),
            "wd": (jax.random.normal(ks[1], (E, F, D)) / F ** 0.5).astype(dtype),
        }
        if cfg.act.endswith("_glu"):
            experts["wg"] = (jax.random.normal(ks[2], (E, D, F)) / D ** 0.5).astype(dtype)
        p = {"router": _init_linear(ks[3], D, E, dtype), "experts": experts}
        if cfg.n_shared_experts:
            p["shared"] = self._init_mlp(ks[4], dtype, cfg.d_ff * cfg.n_shared_experts)
        return p

    def _init_block(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        block = {
            "attn_norm": _norm_params(cfg, cfg.d_model, dtype),
            "attn": self._init_attn(ks[0], dtype),
            "mlp_norm": _norm_params(cfg, cfg.d_model, dtype),
        }
        block["mlp"] = (
            self._init_moe(ks[1], dtype) if cfg.n_experts else self._init_mlp(ks[1], dtype)
        )
        return block

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 4)
        params: dict = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
            "final_norm": _norm_params(cfg, cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = _init_linear(ks[3], cfg.d_model, cfg.vocab_size, dtype)

        if cfg.family == "ssm":
            params["blocks"] = self._stack_init(
                lambda k: {
                    "norm": _norm_params(cfg, cfg.d_model, dtype),
                    "mixer": init_mamba_params(k, cfg, dtype),
                },
                ks[1],
                cfg.n_layers,
            )
        elif cfg.family == "hybrid":
            params["blocks"] = self._stack_init(
                lambda k: {
                    "norm": _norm_params(cfg, cfg.d_model, dtype),
                    "mixer": init_mamba_params(k, cfg, dtype),
                },
                ks[1],
                cfg.n_layers,
            )
            params["shared_blocks"] = self._stack_init(
                lambda k: self._init_block(k, dtype),
                ks[2],
                cfg.hybrid_n_shared_blocks,
            )
        else:
            params["blocks"] = self._stack_init(
                lambda k: self._init_block(k, dtype), ks[1], cfg.n_layers
            )
        return params

    @staticmethod
    def _stack_init(fn, key, n):
        keys = jax.random.split(key, n)
        trees = [fn(k) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    # -------------------------------------------------------------- forward
    def _attn_dims(self) -> AttnDims:
        cfg = self.cfg
        return AttnDims(
            n_q=cfg.n_heads,
            n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            qk_norm=cfg.qk_norm,
            rope=cfg.rope,
            rope_theta=cfg.rope_theta,
            window=cfg.sliding_window,
            causal_segments=cfg.attn_causal_segments,
        )

    def _transformer_block(
        self, p, x, *, positions, mask, cache=None, chunk_kv=None, capture=False
    ):
        cfg = self.cfg
        h = apply_norm(x, p["attn_norm"], cfg.norm)
        attn_out, new_cache, s1 = attention_block(
            p["attn"], h, self._attn_dims(),
            positions=positions, mask=mask, cache=cache,
            chunk_kv=chunk_kv, capture=capture, unroll=cfg.unroll_layers,
        )
        x = x + attn_out
        h = apply_norm(x, p["mlp_norm"], cfg.norm)
        aux = 0.0
        if cfg.n_experts:
            mlp_out, aux, s2 = moe_block(p["mlp"], h, cfg, capture=capture)
        else:
            mlp_out, s2 = mlp_block(p["mlp"], h, cfg.act, capture=capture)
        x = x + mlp_out
        stats = {**s1, **s2} if capture else {}
        return x, new_cache, aux, stats

    def _mamba_layer(self, p, x, *, state=None, capture=False):
        h = apply_norm(x, p["norm"], self.cfg.norm)
        out, new_state, stats = mamba_block(
            p["mixer"], h, self.cfg, state=state, capture=capture
        )
        return x + out, new_state, stats

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        return x

    def _unembed(self, params, h):
        from .layers import _SHARD_CTX, _wsc

        w = params.get("lm_head")
        if w is None:
            w = params["embed"].T
        # seq-sharded hidden (context parallelism) meets a vocab-sharded
        # head: without boundary constraints GSPMD replicates the full
        # [B, C, V] logits (measured 2×40 GB collectives). Re-shard h to
        # batch-only and pin logits to vocab-parallel.
        if _SHARD_CTX["enabled"]:
            h = _wsc(h, _SHARD_CTX["dp"], *([None] * (h.ndim - 1)))
        logits = h @ w.astype(h.dtype)
        if _SHARD_CTX["enabled"]:
            logits = _wsc(logits, _SHARD_CTX["dp"],
                          *([None] * (h.ndim - 2)), _SHARD_CTX["model"])
        return logits

    def apply(
        self,
        params,
        tokens,
        *,
        capture: bool = False,
        chunk_kv: Optional[int] = None,
        return_hidden: bool = False,
    ):
        """Training/eval forward: causal, no cache. Returns logits (or hidden)
        and (aux_loss, stats)."""
        cfg = self.cfg
        compute = jnp.dtype(cfg.dtype)
        params = jax.tree.map(
            lambda a: a.astype(compute) if a.dtype == jnp.float32 and compute != jnp.float32 else a,
            params,
        )
        B, T = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.arange(T)
        mask = causal_mask(T, T, 0, cfg.sliding_window)

        aux_total = 0.0
        stats_all: dict = {}

        if cfg.family in ("ssm", "hybrid"):
            def ssm_body(carry, p):
                x = carry
                x, _, stats = self._mamba_layer(p, x, capture=capture)
                return x, stats

            body = jax.checkpoint(ssm_body) if cfg.remat else ssm_body
            if cfg.family == "ssm":
                x, stats = scan_layers(body, x, params["blocks"], cfg.unroll_layers)
                stats_all.update(stats if capture else {})
            else:
                every = cfg.hybrid_attn_every
                n_seg = cfg.n_layers // every
                seg_params = jax.tree.map(
                    lambda a: a.reshape(n_seg, every, *a.shape[1:]), params["blocks"]
                )
                mamba_stats = []
                for seg in range(n_seg):
                    p_seg = jax.tree.map(lambda a: a[seg], seg_params)
                    x, stats = scan_layers(body, x, p_seg, cfg.unroll_layers)
                    if capture:
                        mamba_stats.append(stats)
                    shared = jax.tree.map(
                        lambda a: a[seg % cfg.hybrid_n_shared_blocks],
                        params["shared_blocks"],
                    )
                    x, _, aux, s = self._transformer_block(
                        shared, x, positions=positions, mask=mask,
                        chunk_kv=chunk_kv, capture=capture,
                    )
                    aux_total = aux_total + aux
                    if capture:
                        stats_all[f"shared_{seg}"] = s
                if capture and mamba_stats:
                    stats_all["mamba"] = jax.tree.map(
                        lambda *xs: jnp.concatenate(xs), *mamba_stats
                    )
        else:
            def block_body(carry, p):
                x, aux = carry
                x, _, a, stats = self._transformer_block(
                    p, x, positions=positions, mask=mask,
                    chunk_kv=chunk_kv, capture=capture,
                )
                return (x, aux + a), stats

            body = jax.checkpoint(block_body) if cfg.remat else block_body
            (x, aux_total), stats = scan_layers(body, (x, 0.0), params["blocks"],
                                                cfg.unroll_layers)
            if capture:
                stats_all = stats

        x = apply_norm(x, params["final_norm"], cfg.norm)
        if capture:
            stats_all["final_h"] = jnp.mean(x.reshape(-1, cfg.d_model), 0)
        if return_hidden:
            return x, (aux_total, stats_all)
        return self._unembed(params, x), (aux_total, stats_all)

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch, *, chunk_kv: Optional[int] = None):
        """Chunked-over-sequence cross entropy (bounds the [B, c, V] logits
        buffer); adds the MoE load-balance aux loss."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        h, (aux, _) = self.apply(
            params, tokens, chunk_kv=chunk_kv, return_hidden=True
        )
        B, T, D = h.shape
        C = min(cfg.logit_chunk, T)
        n = T // C
        h_c = h.reshape(B, n, C, D).transpose(1, 0, 2, 3)
        l_c = labels.reshape(B, n, C).transpose(1, 0, 2)

        def chunk_loss(carry, inp):
            hc, lc = inp
            logits = self._unembed(params, hc).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, -1)
            # gold logit via a masked reduce (NOT take_along_axis): the iota
            # compare propagates through a vocab-sharded logits tensor, while
            # a gather forces GSPMD to replicate the full [B,C,V] logits
            # (measured: 2x40 GB per-device collectives on qwen2 train_4k).
            iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
            gold = jnp.sum(jnp.where(iota == lc[..., None], logits, 0.0), axis=-1)
            return carry + jnp.sum(logz - gold), None

        total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (h_c, l_c))
        loss = total / (B * T)
        if cfg.n_experts:
            loss = loss + 0.01 * aux
        return loss

    # ---------------------------------------------------------------- cache
    def cache_len(self, seq_len: int) -> int:
        if self.cfg.sliding_window is not None:
            return min(seq_len, self.cfg.sliding_window)
        return seq_len

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16,
                   per_slot: bool = False, kv_bits: Optional[int] = None) -> dict:
        """``per_slot=True`` builds the continuous-batching variant: each
        batch row is an independent serving slot with its own write offset
        (``pos`` [B]) and absolute slot positions (``kpos`` [B, S]), so the
        engine can prefill/retire rows at different sequence positions.
        ``kv_bits`` overrides ``cfg.kv_cache_bits`` (8 → int8 payload +
        per-token/per-head scales; 16 → fp payload in ``dtype``)."""
        cfg = self.cfg
        kv_bits = cfg.kv_cache_bits if kv_bits is None else int(kv_bits)
        if kv_bits not in (8, 16):
            raise ValueError(f"kv_bits must be 8 or 16, got {kv_bits}")
        S = self.cache_len(seq_len)
        if per_slot and cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"per-slot caches are only supported for attention-family "
                f"models (got family={cfg.family!r}); SSM state handoff is "
                f"position-free but needs dedicated plumbing"
            )
        if cfg.family == "ssm":
            _, H, G, St, _, d_conv = ssm_dims(cfg)
            return {
                "ssm": jnp.zeros((cfg.n_layers, batch, H, cfg.ssm_head_dim, St), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv_width - 1, d_conv), dtype),
                "pos": jnp.zeros((), jnp.int32),
            }
        kv_dtype = jnp.int8 if kv_bits == 8 else dtype
        kv = {
            "k": jnp.zeros((cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.head_dim), kv_dtype),
            "v": jnp.zeros((cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.head_dim), kv_dtype),
            "kpos": (jnp.full((batch, S), -1, jnp.int32) if per_slot
                     else jnp.full((S,), -1, jnp.int32)),
            "pos": (jnp.zeros((batch,), jnp.int32) if per_slot
                    else jnp.zeros((), jnp.int32)),
        }
        if kv_bits == 8:
            # scale 0 == "position invalid" (the kv_attention masking
            # contract): an unwritten cache position is masked by
            # construction, not just by the kpos bookkeeping
            kv["k_scale"] = jnp.zeros((cfg.n_layers, batch, S, cfg.n_kv_heads), jnp.float32)
            kv["v_scale"] = jnp.zeros((cfg.n_layers, batch, S, cfg.n_kv_heads), jnp.float32)
            if cfg.kv_bias_correct:
                kv["v_err"] = jnp.zeros(
                    (cfg.n_layers, batch, S, cfg.n_kv_heads), jnp.float32)
        if cfg.family == "hybrid":
            _, H, G, St, _, d_conv = ssm_dims(cfg)
            n_app = cfg.n_layers // cfg.hybrid_attn_every
            return {
                "ssm": jnp.zeros((cfg.n_layers, batch, H, cfg.ssm_head_dim, St), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv_width - 1, d_conv), dtype),
                "k": jnp.zeros((n_app, batch, S, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((n_app, batch, S, cfg.n_kv_heads, cfg.head_dim), dtype),
                "kpos": jnp.full((S,), -1, jnp.int32),
                "pos": jnp.zeros((), jnp.int32),
            }
        return kv

    def _forward_cached(self, params, tokens, cache, *, chunk_kv=None,
                        logits_at=None):
        """Shared prefill/decode path: runs T tokens starting at cache['pos']
        (scalar, or [B] for per-slot caches). ``logits_at`` selects which
        position's logits to return (default: the last — chunked-prefill
        callers pass the final *valid* offset of a padded chunk, either a
        shared scalar or a per-row [B] vector)."""
        cfg = self.cfg
        compute = jnp.dtype(cfg.dtype)
        params = jax.tree.map(
            lambda a: a.astype(compute) if a.dtype == jnp.float32 and compute != jnp.float32 else a,
            params,
        )
        B, T = tokens.shape
        pos = cache["pos"]
        if pos.ndim == 1:
            positions = pos[:, None] + jnp.arange(T)[None, :]   # [B, T]
        else:
            positions = pos + jnp.arange(T)
        x = self._embed(params, tokens)

        if cfg.family == "ssm":
            def body(carry, inp):
                x = carry
                p, st = inp
                x, new_st, _ = self._mamba_layer(p, x, state=st)
                return x, new_st

            states = {"ssm": cache["ssm"], "conv": cache["conv"]}
            x, new_states = scan_layers(body, x, (params["blocks"], states),
                                        cfg.unroll_layers)
            new_cache = {**new_states, "pos": pos + T}
        elif cfg.family == "hybrid":
            every = cfg.hybrid_attn_every
            n_seg = cfg.n_layers // every
            seg_params = jax.tree.map(
                lambda a: a.reshape(n_seg, every, *a.shape[1:]), params["blocks"]
            )
            seg_states = jax.tree.map(
                lambda a: a.reshape(n_seg, every, *a.shape[1:]),
                {"ssm": cache["ssm"], "conv": cache["conv"]},
            )
            new_ssm, new_conv, new_k, new_v = [], [], [], []
            kpos = cache["kpos"]
            for seg in range(n_seg):
                p_seg = jax.tree.map(lambda a: a[seg], seg_params)
                st_seg = jax.tree.map(lambda a: a[seg], seg_states)

                def body(carry, inp):
                    x = carry
                    p, st = inp
                    x, new_st, _ = self._mamba_layer(p, x, state=st)
                    return x, new_st

                x, st_new = scan_layers(body, x, (p_seg, st_seg), cfg.unroll_layers)
                new_ssm.append(st_new["ssm"])
                new_conv.append(st_new["conv"])
                shared = jax.tree.map(
                    lambda a: a[seg % cfg.hybrid_n_shared_blocks],
                    params["shared_blocks"],
                )
                layer_cache = {
                    "k": cache["k"][seg], "v": cache["v"][seg],
                    "kpos": kpos, "pos": pos,
                }
                x, lc, _, _ = self._transformer_block(
                    shared, x, positions=positions, mask=None,
                    cache=layer_cache, chunk_kv=chunk_kv,
                )
                new_k.append(lc["k"])
                new_v.append(lc["v"])
                new_kpos = lc["kpos"]
            new_cache = {
                "ssm": jnp.concatenate(new_ssm),
                "conv": jnp.concatenate(new_conv),
                "k": jnp.stack(new_k),
                "v": jnp.stack(new_v),
                "kpos": new_kpos,
                "pos": pos + T,
            }
        else:
            kv_keys = [k for k in ("k", "v", "k_scale", "v_scale", "v_err")
                       if k in cache]

            def body(carry, inp):
                x = carry
                p, kv = inp
                layer_cache = {**kv, "kpos": cache["kpos"], "pos": pos}
                x, lc, _, _ = self._transformer_block(
                    p, x, positions=positions, mask=None,
                    cache=layer_cache, chunk_kv=chunk_kv,
                )
                return x, {**{k: lc[k] for k in kv_keys}, "kpos": lc["kpos"]}

            x, new_kv = scan_layers(
                body, x, (params["blocks"], {k: cache[k] for k in kv_keys}),
                cfg.unroll_layers,
            )
            new_cache = {
                **{k: new_kv[k] for k in kv_keys},
                "kpos": new_kv["kpos"][0],
                "pos": pos + T,
            }

        x = apply_norm(x, params["final_norm"], cfg.norm)
        if logits_at is None:
            h_last = x[:, -1:, :]
        elif jnp.ndim(logits_at) == 1:
            # per-row offsets [B] (batched multi-slot prefill: each row's
            # final valid position differs when chunks are zero-padded)
            h_last = jnp.take_along_axis(
                x, logits_at.astype(jnp.int32)[:, None, None], axis=1
            )
        else:
            h_last = jax.lax.dynamic_slice_in_dim(x, logits_at, 1, axis=1)
        logits = self._unembed(params, h_last)[:, 0]
        return logits, new_cache

    def prefill(self, params, tokens, cache, *, chunk_kv: Optional[int] = None,
                logits_at=None):
        return self._forward_cached(params, tokens, cache, chunk_kv=chunk_kv,
                                    logits_at=logits_at)

    def decode_step(self, params, token, cache):
        """token: [B, 1] int32 → (logits [B, V], cache)."""
        return self._forward_cached(params, token, cache)

    # ------------------------------------------------------------- DFQ plan
    def dfq_plan(self) -> DFQPlan:
        cfg = self.cfg
        ops: list = []
        sites: list = []
        if cfg.family in ("ssm", "hybrid"):
            # Mamba mixers: norm-fold only; CLE pairs are blocked by the
            # grouped RMSNorm before out_proj (DESIGN.md §Arch-applicability).
            ops.append(NormFoldOp(
                norm_w=("blocks", "norm", "w"),
                consumers=[("blocks", "mixer", "in_proj")],
                consumer_biases=[("blocks", "mixer", "in_bias")],
            ))
            sites += [
                WeightSite("ssm_in_proj", ("blocks", "mixer", "in_proj"),
                           ("blocks", "mixer", "in_bias"), "dense", "ssm_in"),
                WeightSite("ssm_out_proj", ("blocks", "mixer", "out_proj"),
                           ("blocks", "mixer", "out_bias"), "dense", "ssm_out_in"),
            ]
        if cfg.family == "ssm":
            return DFQPlan(tuple(ops), tuple(sites), cfg.name)

        prefix = ("shared_blocks",) if cfg.family == "hybrid" else ("blocks",)

        def P(*rest):
            return prefix + rest

        attn_bias = (P("attn", "bq"), P("attn", "bk"), P("attn", "bv")) if cfg.qkv_bias else (None, None, None)
        ops.append(NormFoldOp(
            norm_w=P("attn_norm", "w"),
            norm_b=P("attn_norm", "b") if cfg.norm == "ln" else None,
            consumers=[P("attn", "wq"), P("attn", "wk"), P("attn", "wv")],
            consumer_biases=list(attn_bias),
        ))
        mlp_consumers = [P("mlp", "router")] if cfg.n_experts else []
        mlp_cons_biases: list = [None] if cfg.n_experts else []
        if cfg.n_experts:
            # expert weights [L, E, D, F] fold over D with broadcast γ [L, 1, D]
            pass  # handled by a dedicated fold below (needs reshape) — skip γ
        else:
            if cfg.act.endswith("_glu"):
                mlp_consumers += [P("mlp", "wg"), P("mlp", "wu")]
                mlp_cons_biases += [None, None]
            else:
                mlp_consumers += [P("mlp", "wu")]
                mlp_cons_biases += [None]
        if mlp_consumers and not cfg.n_experts:
            ops.append(NormFoldOp(
                norm_w=P("mlp_norm", "w"),
                norm_b=P("mlp_norm", "b") if cfg.norm == "ln" else None,
                consumers=mlp_consumers,
                consumer_biases=mlp_cons_biases,
            ))

        # exact CLE pairs
        ops.append(VOPairOp(
            wv=P("attn", "wv"), wo=P("attn", "wo"),
            bv=P("attn", "bv") if cfg.qkv_bias else None,
            n_q=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        ))
        if not cfg.qk_norm:
            ops.append(QKPairOp(
                wq=P("attn", "wq"), wk=P("attn", "wk"),
                bq=P("attn", "bq") if cfg.qkv_bias else None,
                bk=P("attn", "bk") if cfg.qkv_bias else None,
                n_q=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope=cfg.rope,
            ))
        if cfg.n_experts:
            ops.append(DensePairOp(
                w1=P("mlp", "experts", "wu"), w2=P("mlp", "experts", "wd"),
                exact=cfg.act.endswith("_glu"),
            ))
            if cfg.n_shared_experts:
                ops.append(DensePairOp(
                    w1=P("mlp", "shared", "wu"), w2=P("mlp", "shared", "wd"),
                    exact=cfg.act.endswith("_glu"),
                ))
        else:
            ops.append(DensePairOp(
                w1=P("mlp", "wu"), w2=P("mlp", "wd"),
                b1=P("mlp", "bu") if cfg.mlp_bias else None,
                exact=cfg.act.endswith("_glu") or cfg.act == "relu",
            ))
        if cfg.qkv_bias:
            ops.append(VBiasAbsorbOp(
                bv=P("attn", "bv"), wo=P("attn", "wo"), bo=P("attn", "bo"),
                n_q=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            ))

        sites += [
            WeightSite("wq", P("attn", "wq"), P("attn", "bq"), "dense", "attn_in"),
            WeightSite("wk", P("attn", "wk"), P("attn", "bk"), "dense", "attn_in"),
            WeightSite("wv", P("attn", "wv"), P("attn", "bv"), "dense", "attn_in"),
            WeightSite("wo", P("attn", "wo"), P("attn", "bo"), "dense", "o_in"),
        ]
        if cfg.n_experts:
            sites += [
                WeightSite("router", P("mlp", "router"), P("mlp", "router_b"),
                           "dense", "mlp_in"),
                WeightSite("experts_wu", P("mlp", "experts", "wu"), None, "dense", None),
                WeightSite("experts_wd", P("mlp", "experts", "wd"), None, "dense", None),
            ]
            if cfg.act.endswith("_glu"):
                sites.append(WeightSite("experts_wg", P("mlp", "experts", "wg"),
                                        None, "dense", None))
        else:
            sites += [
                WeightSite("wu", P("mlp", "wu"), P("mlp", "bu"), "dense", "mlp_in"),
                WeightSite("wd", P("mlp", "wd"), P("mlp", "bd"), "dense", "down_in"),
            ]
            if cfg.act.endswith("_glu"):
                sites.append(WeightSite("wg", P("mlp", "wg"), P("mlp", "bg"),
                                        "dense", "mlp_in"))
        return DFQPlan(tuple(ops), tuple(sites), cfg.name)

    # -------------------------------------------------- calibration (BC/BA)
    def calibration_stats(self, params, tokens):
        """Synthetic-calibration E[x] per stat_key (data-free — tokens are
        random ids). Returns a flat dict keyed like WeightSite.stat_key with
        [L, ...]-stacked means."""
        _, (_, stats) = self.apply(params, tokens, capture=True)
        return stats
