"""Unified architecture configuration covering the assigned 10-arch pool.

One frozen dataclass describes dense / MoE / VLM / audio-enc-dec / SSM /
hybrid LM-family transformers, plus the reduced smoke variants used in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None           # default d_model // n_heads
    act: str = "silu_glu"                     # silu_glu | gelu_glu | gelu | relu
    norm: str = "rms"                         # rms | ln
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    qk_norm: bool = False                     # chameleon
    attn_causal_segments: int = 8             # causal block skipping granularity
    kv_cache_bits: int = 16                   # 8 → int8 KV cache (per-token,
                                              # per-head absmax scales)
    kv_bias_correct: bool = False             # int8 KV only: store per-token
                                              # V dequant-error means and
                                              # subtract them from attention
                                              # output (paper §4.2 applied to
                                              # the V quantization error)
    tie_embeddings: bool = True
    sliding_window: Optional[int] = None      # mixtral SWA
    max_seq: int = 131072

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0                 # llama4 shared expert
    capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2 mamba blocks)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_n_groups: int = 1

    # hybrid (zamba2): a shared attention+MLP block applied every k SSM layers
    hybrid_attn_every: int = 0                # 0 → not hybrid
    hybrid_n_shared_blocks: int = 2

    # encoder-decoder (whisper)
    n_enc_layers: int = 0                     # 0 → decoder-only
    enc_seq: int = 1500                       # whisper 30 s → 1500 frames
    frontend: str = "none"                    # none | audio_stub | vision_stub

    # numerics / training
    dtype: str = "bfloat16"                   # activation compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    logit_chunk: int = 1024                   # vocab-loss sequence chunking
    unroll_layers: bool = False               # cost-probe mode: python loop
                                              # instead of lax.scan (XLA's
                                              # cost_analysis counts while
                                              # bodies once — launch/dryrun)

    def __post_init__(self):
        if self.head_dim is None and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # --- derived -----------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.n_heads * (self.head_dim or 0)

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * (self.head_dim or 0)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (or bounded-KV) decode at 500k+ tokens."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d  # embedding (tied head)
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.family == "ssm":
            din = self.d_inner
            dproj = 2 * din + 2 * self.ssm_n_groups * self.ssm_state + self.ssm_heads
            per_layer = d * dproj + din * d + self.ssm_conv_width * (
                din + 2 * self.ssm_n_groups * self.ssm_state
            )
            n += self.n_layers * per_layer
            return n
        attn = d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
        if self.n_experts:
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
            mlp += self.n_shared_experts * 3 * d * f
        else:
            mlp = (3 if self.act.endswith("_glu") else 2) * d * f
        per_layer = attn + mlp
        if self.family == "hybrid":
            din = self.d_inner
            dproj = 2 * din + 2 * self.ssm_n_groups * self.ssm_state + self.ssm_heads
            ssm_per = d * dproj + din * d
            n += self.n_layers * ssm_per
            n += self.hybrid_n_shared_blocks * per_layer  # shared blocks
            return n
        layers = self.n_layers + self.n_enc_layers
        n += layers * per_layer
        if self.is_encdec:  # cross attention in decoder
            n += self.n_layers * (d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k counting)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_moe = self.n_experts * 3 * d * f
        active_moe = (self.top_k + self.n_shared_experts) * 3 * d * f
        return self.param_count() - self.n_layers * (dense_moe - active_moe - d * self.n_experts)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=4.0,   # drop-free in smoke: cache-parity testable
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=8,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_seq=16,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            sliding_window=16 if self.sliding_window else None,
            max_seq=128,
            dtype="float32",
            param_dtype="float32",
            remat=False,
            logit_chunk=32,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k needs sub-quadratic attention; enc-only
    archs skip decode (none assigned here are encoder-only)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch — quadratic 500k decode skipped (DESIGN.md §7)"
    if shape.kind in ("prefill", "decode") and cfg.is_encdec and shape.seq_len > cfg.max_seq:
        return True, ""  # backbone-only rule: run mechanically with the cache
    return True, ""
