"""Transformer building blocks — pure functions over explicit param pytrees.

Conventions:
  * linear weights are ``[d_in, d_out]`` applied as ``y = x @ w + b``,
  * attention projections are flat ``[D, n_heads*head_dim]`` (head-major),
  * every linear has an (often zero-initialized) bias slot — DFQ's bias
    correction folds ε·E[x] into it (paper §4.2),
  * blocks broadcast over a leading scan dim when params are stacked.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

# shard_map moved to the jax namespace and check_rep → check_vma across
# releases — independently (0.5/0.6 expose jax.shard_map but still take
# check_rep), so resolve the location and the kwarg name separately.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_SHARD_MAP_CHECK_KW = (
    "check_vma"
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else "check_rep"
)


def scan_layers(body, carry, xs, unroll: bool = False):
    """lax.scan over stacked layer params, or an unrolled python loop when
    ``unroll`` (dry-run cost probes: XLA's cost_analysis counts while-loop
    bodies once, so probes unroll shallow variants and extrapolate)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys_stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys_stacked = ys[0] if ys else None
    return carry, ys_stacked


def linear(x, w, b=None):
    """y = x @ w + b. Dispatches on weight type: an int8 ``QTensor`` routes
    through the Pallas W8A16/W8A8 kernels (repro.quantized) — the same model
    code serves fp and quantized."""
    if type(w).__name__ == "QTensor":
        from ..quantized.qtensor import qtensor_matmul

        return qtensor_matmul(x, w, b)
    y = x @ w
    if b is not None:
        y = y + b
    return y


def _all_w8a8(*ws) -> bool:
    return all(type(w).__name__ == "QTensor" and w.mode == "w8a8"
               for w in ws)


def _shared_linears(x, wbs):
    """Several W8A8 projections reading the SAME activation (the qkv trio,
    the GLU gate/up pair) share one ``quantize_act`` dispatch. Per-row
    dynamic quantization depends only on the row, so each output is bitwise
    what its own ``linear``/``qtensor_matmul`` would have produced."""
    from ..quantized.qtensor import qtensor_matmul_prequant, quantize_input

    a_q, a_s, lead = quantize_input(x)
    return [qtensor_matmul_prequant(a_q, a_s, w, b, lead, out_dtype=x.dtype)
            for w, b in wbs]


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    # statistics in f32, data path in the compute dtype: keeping x itself
    # bf16 keeps its COTANGENT bf16, which halves every boundary psum the
    # backward pass emits (measured on mixtral train_4k — EXPERIMENTS §Perf)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * weight.astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x - mu.astype(x.dtype)) * inv * weight.astype(x.dtype) + bias.astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rms":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p["b"])


# --------------------------------------------------------------------------
# RoPE (rotate-half convention — matches core.cle.equalize_qk's pair layout)
# --------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., T, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, T, H, hd]; cos/sin: [T, hd/2] or [B, T, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(
        x.dtype
    )


# --------------------------------------------------------------------------
# Sharding hints (set by launch.steps when tracing under a mesh): inside
# attention we steer GSPMD to either head-parallel (Megatron) or, when the
# head count doesn't divide the model axis, SEQUENCE-parallel (context
# parallelism) — measured on qwen2 train_4k: 16× less redundant compute than
# replication and ~50× fewer collective bytes than GSPMD's factored fallback.
# --------------------------------------------------------------------------

_SHARD_CTX = {"enabled": False, "dp": ("data",), "model": "model",
              "attn_seq": False, "kv_heads_ok": False}


def set_shard_ctx(*, enabled: bool, dp=("data",), model="model",
                  attn_seq=False, kv_heads_ok=False, mesh=None):
    _SHARD_CTX.update(enabled=enabled, dp=tuple(dp), model=model,
                      attn_seq=attn_seq, kv_heads_ok=kv_heads_ok, mesh=mesh)


def _wsc(x, *spec):
    if not _SHARD_CTX["enabled"]:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*spec))


# Serving mesh context — SEPARATE from _SHARD_CTX (which arms the training
# constraints and the MoE shard_map). The serving engine sets this while
# tracing its jitted impls; the decode hot path then hand-partitions the
# fused attention kernel with shard_map over ("data", "model") — heads are
# model-local and slots data-local, so the kernel body runs with ZERO
# collectives and per-shard results concatenate bitwise.

_SERVE_MESH = {"mesh": None, "dp": ("data",), "model": "model"}


def set_serve_mesh(mesh=None, *, dp=("data",), model="model") -> dict:
    """Arm (or clear, mesh=None) the serve-mesh context. Returns the
    previous context so engine wrappers can restore it after tracing."""
    prev = dict(_SERVE_MESH)
    _SERVE_MESH.update(mesh=mesh, dp=tuple(dp), model=model)
    return prev


def _serve_decode_partition(nq: int, nkv: int, B: int):
    """(mesh, dp_spec, model_axis) when the decode attention can shard_map
    head-locally — the model axis must divide BOTH head counts (a shard owns
    whole GQA groups) — else None. ``dp_spec`` degrades to replication when
    the slot count doesn't divide the data axis."""
    mesh = _SERVE_MESH["mesh"]
    if mesh is None:
        return None
    sizes = dict(mesh.shape)
    mdl = _SERVE_MESH["model"]
    m_n = sizes.get(mdl, 1)
    if m_n <= 1 or nq % m_n or nkv % m_n:
        return None
    dp = tuple(a for a in _SERVE_MESH["dp"] if a in sizes)
    dp_n = 1
    for a in dp:
        dp_n *= sizes[a]
    dp_spec = dp if (dp_n > 1 and B % dp_n == 0) else None
    return mesh, dp_spec, mdl


def _fused_decode_tp(part, q1, cache, k_new, v_new, idx, valid, out_dtype):
    """shard_map the fused decode attention over (data, model): q heads and
    the KV cache's head axis live on "model", slots on "data". Attention is
    head-local, so the body emits no collectives — the -tp serving contracts
    pin the decode collective budget at the same level as single-device."""
    from jax.sharding import PartitionSpec as P

    from ..kernels.fused_decode.ops import fused_decode

    mesh, dp, mdl = part
    per_slot = idx.ndim == 2

    def local_fn(q1, ck, cks, cv, cvs, kn, vn, idx, valid):
        return fused_decode(q1, ck, cks, cv, cvs, kn, vn, idx,
                            valid=valid, out_dtype=out_dtype)

    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(dp, mdl, None),                    # q [B, Hq, hd]
            P(dp, None, mdl, None),              # cache k [B, S, Hkv, hd]
            P(dp, None, mdl),                    # k_scale [B, S, Hkv]
            P(dp, None, mdl, None),              # cache v
            P(dp, None, mdl),                    # v_scale
            P(dp, None, mdl, None),              # k_new [B, 1, Hkv, hd]
            P(dp, None, mdl, None),              # v_new
            P(dp, None) if per_slot else P(None),        # idx [B, 1] | [1]
            P(dp, None) if per_slot else P(None, None),  # valid [B|1, S]
        ),
        out_specs=(P(dp, mdl, None),
                   (P(dp, None, mdl, None), P(dp, None, mdl),
                    P(dp, None, mdl, None), P(dp, None, mdl))),
        **{_SHARD_MAP_CHECK_KW: False},
    )
    return fn(q1, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"],
              k_new, v_new, idx, valid)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

NEG_INF = -1e30


def _repeat_kv(x, group: int):
    if group == 1:
        return x
    return jnp.repeat(x, group, axis=2)


def attention_scores_softmax(
    q, k, v, mask, chunk_kv: Optional[int] = None, chunk_q: Optional[int] = None,
    unroll: bool = False, causal_segments: int = 1,
):
    """softmax(q·kᵀ)·v with optional two-level online-softmax chunking.

    q: [B, Tq, H, hd]; k, v: [B, Tk, H, hd]; mask is 2-D [Tq, Tk]
    (True = attend) or None. Chunking bounds the live score buffer to
    [B, H, chunk_q, chunk_kv] — the flash-attention dataflow expressed in
    pure JAX (XLA-fused on TPU) so 32k-token training fits HBM.

    ``causal_segments > 1`` splits the query range into static segments and
    bounds each segment's KV scan at its causal frontier — block skipping
    for the lower-triangular mask (8 segments ≈ 44 % of the quadratic
    FLOPs eliminated; EXPERIMENTS §Perf, yi-34b prefill iteration).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    B, Tk, H, hd = k.shape
    Tq = q.shape[1]

    if chunk_kv is None or Tk <= chunk_kv:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        s = s.astype(jnp.float32)
        if mask is not None:
            # 2-D [Tq, Tk] shared mask, or 3-D [B, Tq, Tk] per-slot mask
            # (continuous batching: each batch row is an independent request)
            m = mask[None, None] if mask.ndim == 2 else mask[:, None]
            s = jnp.where(m, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    if mask is not None and mask.ndim == 3:
        raise NotImplementedError(
            "per-slot (3-D) masks require the unchunked attention path — "
            "call without chunk_kv (serving decode/prefill-chunk shapes are "
            "small enough that chunking buys nothing)"
        )

    n_kv = Tk // chunk_kv
    k_b = k.reshape(B, n_kv, chunk_kv, H, hd).transpose(1, 0, 2, 3, 4)
    v_b = v.reshape(B, n_kv, chunk_kv, H, hd).transpose(1, 0, 2, 3, 4)

    # probe mode (unroll): match chunk_q to chunk_kv so the unrolled block
    # count stays tiny; production scans use finer q chunks for VMEM
    chunk_q = chunk_q or (min(Tq, chunk_kv) if unroll
                          else min(Tq, max(chunk_kv // 4, 256)))
    if Tq % chunk_q != 0:
        chunk_q = Tq
    n_q = Tq // chunk_q
    q_b = q.reshape(B, n_q, chunk_q, H, hd).transpose(1, 0, 2, 3, 4)
    mask_b = None
    if mask is not None:
        # [n_q, chunk_q, n_kv, chunk_kv] — tiny (no B/H dims)
        mask_b = mask.reshape(n_q, chunk_q, n_kv, chunk_kv)

    def run_block(q_part, mask_part, k_part, v_part):
        """Online-softmax over the given KV blocks for the given q chunks."""

        def q_body(_, q_blk_and_mask):
            if mask_part is not None:
                qb, mb_all = q_blk_and_mask
            else:
                qb = q_blk_and_mask
                mb_all = None

            m0 = jnp.full((B, H, chunk_q), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, H, chunk_q), jnp.float32)
            acc0 = jnp.zeros((B, chunk_q, H, hd), jnp.float32)

            @jax.checkpoint
            def kv_body(carry, blk):
                # remat: the bwd recomputes s/p per block instead of saving
                # the [B, H, cq, ckv] probabilities for every iteration
                m, l, acc = carry
                if mb_all is not None:
                    kb, vb, mb = blk
                else:
                    kb, vb = blk
                    mb = None
                s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32) * scale
                if mb is not None:
                    s = jnp.where(mb[None, None], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, -1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, -1)
                acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                    "bhqk,bkhd->bqhd", p.astype(qb.dtype), vb
                ).astype(jnp.float32)
                return (m_new, l_new, acc_new), None

            xs = ((k_part, v_part, mb_all.transpose(1, 0, 2))
                  if mb_all is not None else (k_part, v_part))
            (m, l, acc), _ = scan_layers(kv_body, (m0, l0, acc0), xs, unroll)
            out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
            return None, out.astype(q.dtype)

        xs_q = (q_part, mask_part) if mask_part is not None else q_part
        _, out_b = scan_layers(q_body, None, xs_q, unroll)
        return out_b

    nseg = causal_segments
    if nseg > 1 and mask is not None and n_q % nseg == 0 and Tq == Tk:
        seg_q = n_q // nseg
        outs = []
        for si in range(nseg):
            q_hi = (si + 1) * seg_q * chunk_q
            n_kv_s = -(-q_hi // chunk_kv)                  # ceil
            outs.append(run_block(
                q_b[si * seg_q:(si + 1) * seg_q],
                mask_b[si * seg_q:(si + 1) * seg_q, :, :n_kv_s],
                k_b[:n_kv_s], v_b[:n_kv_s],
            ))
        out_b = jnp.concatenate(outs, axis=0)
    else:
        out_b = run_block(q_b, mask_b, k_b, v_b)
    return out_b.transpose(1, 0, 2, 3, 4).reshape(B, Tq, H, hd)


def causal_mask(Tq: int, Tk: int, q_offset, window: Optional[int] = None):
    """[Tq, Tk] boolean; query i (absolute pos q_offset+i) sees key j ≤ i,
    within the sliding window if given."""
    qpos = jnp.arange(Tq) + q_offset
    kpos = jnp.arange(Tk)
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_q: int
    n_kv: int
    head_dim: int
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    window: Optional[int] = None
    causal_segments: int = 1


def attention_block(
    p: dict,
    x: jnp.ndarray,
    dims: AttnDims,
    *,
    positions: jnp.ndarray,
    mask,
    cache: Optional[dict] = None,
    kv_input: Optional[jnp.ndarray] = None,   # cross-attention source
    chunk_kv: Optional[int] = None,
    capture: bool = False,
    unroll: bool = False,
):
    """Full attention sub-block: qkv proj → rope → (cached) attention → out.

    cache (decode): {"k": [B, S, n_kv, hd], "v": ..., "pos": int32 scalar}
    written as a ring buffer of length S (S = min(seq, window) for SWA).
    Returns (out, new_cache, stats).
    """
    B, T, D = x.shape
    nq, nkv, hd = dims.n_q, dims.n_kv, dims.head_dim
    stats = {}
    if capture:
        stats["attn_in"] = jnp.mean(x.reshape(-1, D), 0)

    src = x if kv_input is None else kv_input
    if kv_input is None and _all_w8a8(p["wq"], p["wk"], p["wv"]):
        q, k, v = _shared_linears(
            x, [(p["wq"], p.get("bq")), (p["wk"], p.get("bk")),
                (p["wv"], p.get("bv"))])
    else:
        q = linear(x, p["wq"], p.get("bq"))
        k = linear(src, p["wk"], p.get("bk"))
        v = linear(src, p["wv"], p.get("bv"))
    Tk_in = src.shape[1]
    q = q.reshape(B, T, nq, hd)
    k = k.reshape(B, Tk_in, nkv, hd)
    v = v.reshape(B, Tk_in, nkv, hd)

    if dims.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    if dims.rope:
        cos_q, sin_q = rope_angles(positions, hd, dims.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        if kv_input is None:
            k = apply_rope(k, cos_q, sin_q)

    new_cache = None
    attn_fused = None        # set by the int8 decode fast path (kv_attention)
    attn_q8 = None           # (int8, scale) from the fused quantize-out epilogue
    if cache is not None and kv_input is None:
        # Ring-buffer KV cache with explicit absolute slot positions: length
        # S = min(context, window) for SWA. ``kpos`` holds each slot's
        # absolute token position (-1 = never written). With "k_scale" in the
        # cache the payload is INT8 (per-token, per-head absmax scales) —
        # DFQ's deployment story applied to the decode memory wall: the
        # cache-stream roofline term halves vs bf16.
        S = cache["k"].shape[1]
        pos = cache["pos"]
        # pos may be a scalar (whole-batch serving: every row at the same
        # offset) or a [B] vector (continuous batching: per-slot offsets, with
        # kpos then [B, S]). The vector form scatters per row.
        per_slot = pos.ndim == 1
        if per_slot:
            qpos = pos[:, None] + jnp.arange(T)[None, :]           # [B, T]
            row = jnp.arange(B)[:, None]
        else:
            qpos = pos + jnp.arange(T)
        idx = qpos % S                       # ring write offset per new token
        # bookkeeping + attention mask from the POST-write slot positions
        if per_slot:
            kpos = cache["kpos"].at[row, idx].set(qpos)
            m = (kpos >= 0)[:, None, :] & (kpos[:, None, :] <= qpos[..., None])
            if dims.window is not None:
                m = m & (kpos[:, None, :] > qpos[..., None] - dims.window)
            mask = m  # 3-D [B, Tq, S]
        else:
            kpos = cache["kpos"].at[idx].set(qpos)
            m = (kpos >= 0)[None, :] & (kpos[None, :] <= qpos[:, None])
            if dims.window is not None:
                m = m & (kpos[None, :] > qpos[:, None] - dims.window)
            mask = m  # 2-D [Tq, S]
        if "k_scale" in cache:
            from ..kernels.kv_attention.ops import (
                append_quantize,
                kv_attention_decode,
            )

            valid = m[:, 0, :] if per_slot else m[0][None, :]     # [B|1, S]
            if T == 1:
                # decode hot path: ONE dispatch from roped q/k/v to the
                # attention out — the fused_decode megakernel quantizes the
                # new token's K/V in VMEM, appends it to the int8 cache in
                # place, and runs the online-softmax attention over the
                # updated block (Pallas on TPU, the exact stepwise
                # composition on the XLA tier — backend resolution lives in
                # kernels.dispatch). Masking rides on the scales: invalid
                # positions get scale 0, so no dequantized [B, S, H, hd]
                # cache is ever materialized. The V bias correction is
                # XLA-composition-only, so a v_err cache routes off Pallas.
                from ..kernels.dispatch import serving_backend
                from ..kernels.fused_decode.ops import (
                    fused_decode,
                    fusion_enabled,
                )

                verr = cache.get("v_err")
                backend = serving_backend(pallas_ok=verr is None)
                part = (None if verr is not None
                        else _serve_decode_partition(nq, nkv, B))
                # the W8A8 wo projection reads the kernel's quantize-out
                # epilogue directly (int8 + per-row scale): the standalone
                # quantize_act dispatch between attention and wo is gone
                want_q8 = (_all_w8a8(p["wo"]) and verr is None
                           and part is None)
                if not fusion_enabled():
                    out, leaves = kv_attention_decode(
                        q[:, 0], cache["k"], cache["k_scale"], cache["v"],
                        cache["v_scale"], k, v, idx, valid=valid,
                        out_dtype=x.dtype, backend=backend,
                        cache_verr=verr,
                    )
                elif part is not None:
                    # TP: shard_map over (data, model) — head-local, zero
                    # collectives, no quantize-out (the row scale is a
                    # cross-head reduction)
                    out, leaves = _fused_decode_tp(
                        part, q[:, 0], cache, k, v, idx, valid, x.dtype)
                else:
                    res, leaves = fused_decode(
                        q[:, 0], cache["k"], cache["k_scale"], cache["v"],
                        cache["v_scale"], k, v, idx, valid=valid,
                        out_dtype=x.dtype,
                        backend=None if verr is not None else backend,
                        cache_verr=verr, quantize_out=want_q8,
                    )
                    if want_q8:
                        out, attn_q8 = res[0], (res[1], res[2])
                    else:
                        out = res
                attn_fused = out[:, None]                   # [B, 1, Hq, hd]
            else:
                # chunked prefill: append-quantize once, then dequantize for
                # the batched attention (compute-bound regime; the kernel is
                # a single-token decode op)
                leaves = append_quantize(
                    cache["k"], cache["k_scale"], cache["v"],
                    cache["v_scale"], k, v, idx,
                    cache_verr=cache.get("v_err"),
                )
                ck, ks, cv, vs = leaves[:4]
                k = ck.astype(x.dtype) * ks.astype(x.dtype)[..., None]
                v = cv.astype(x.dtype) * vs.astype(x.dtype)[..., None]
                if "v_err" in cache:
                    # Σ p (ṽ − e) == Σ p ṽ − Σ p e: same correction as decode
                    v = v - leaves[4].astype(x.dtype)[..., None]
            new_cache = {"k": leaves[0], "k_scale": leaves[1],
                         "v": leaves[2], "v_scale": leaves[3],
                         "kpos": kpos, "pos": pos + T}
            if "v_err" in cache:
                new_cache["v_err"] = leaves[4]
        else:
            if per_slot:
                ck = cache["k"].at[row, idx].set(k.astype(cache["k"].dtype))
                cv = cache["v"].at[row, idx].set(v.astype(cache["v"].dtype))
            else:
                ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
                cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv, "kpos": kpos, "pos": pos + T}
            k, v = ck.astype(x.dtype), cv.astype(x.dtype)
    elif cache is not None and kv_input is not None:
        # cross-attention cache: static encoder K/V (computed at prefill)
        k = cache["k"].astype(x.dtype)
        v = cache["v"].astype(x.dtype)
        new_cache = cache

    if attn_fused is not None:
        attn = attn_fused.reshape(B, T, nq * hd)
        if capture:
            stats["o_in"] = jnp.mean(attn.reshape(-1, nq * hd), 0)
        if attn_q8 is not None:
            from ..quantized.qtensor import qtensor_matmul_prequant

            out = qtensor_matmul_prequant(
                attn_q8[0], attn_q8[1], p["wo"], p.get("bo"), (B, T),
                out_dtype=x.dtype)
        else:
            out = linear(attn, p["wo"], p.get("bo"))
        return out, new_cache, stats

    group = nq // nkv
    k = _repeat_kv(k, group)
    v = _repeat_kv(v, group)
    if _SHARD_CTX["enabled"] and cache is None and kv_input is None:
        ctx = _SHARD_CTX
        if ctx["attn_seq"]:
            # context parallelism: q-sequence over the model axis; K/V
            # replicated (they are the small GQA tensors)
            q = _wsc(q, ctx["dp"], ctx["model"], None, None)
            k = _wsc(k, ctx["dp"], None, None, None)
            v = _wsc(v, ctx["dp"], None, None, None)
        else:
            # Megatron head parallelism
            q = _wsc(q, ctx["dp"], None, ctx["model"], None)
            k = _wsc(k, ctx["dp"], None, ctx["model"], None)
            v = _wsc(v, ctx["dp"], None, ctx["model"], None)
    attn = attention_scores_softmax(
        q, k, v, mask, chunk_kv=chunk_kv, unroll=unroll,
        causal_segments=(dims.causal_segments if kv_input is None else 1),
    )
    attn = attn.reshape(B, T, nq * hd)
    if _SHARD_CTX["enabled"] and cache is None and kv_input is None:
        ctx = _SHARD_CTX
        if ctx["attn_seq"]:
            attn = _wsc(attn, ctx["dp"], ctx["model"], None)
        else:
            attn = _wsc(attn, ctx["dp"], None, ctx["model"])
    if capture:
        stats["o_in"] = jnp.mean(attn.reshape(-1, nq * hd), 0)
    out = linear(attn, p["wo"], p.get("bo"))
    return out, new_cache, stats


# --------------------------------------------------------------------------
# MLP (dense / GLU) and MoE
# --------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_block(p: dict, x: jnp.ndarray, act: str, capture: bool = False):
    """act ∈ {silu_glu, gelu_glu, gelu, relu}."""
    stats = {}
    if capture:
        stats["mlp_in"] = jnp.mean(x.reshape(-1, x.shape[-1]), 0)
    if act.endswith("_glu"):
        if _all_w8a8(p["wg"], p["wu"]):
            # gate and up read the same x: one shared quantize dispatch
            g, u = _shared_linears(x, [(p["wg"], p.get("bg")),
                                       (p["wu"], p.get("bu"))])
        else:
            g = linear(x, p["wg"], p.get("bg"))
            u = linear(x, p["wu"], p.get("bu"))
        h = _act(act[:-4])(g) * u
    else:
        h = _act(act)(linear(x, p["wu"], p.get("bu")))
    if capture:
        stats["down_in"] = jnp.mean(h.reshape(-1, h.shape[-1]), 0)
    return linear(h, p["wd"], p.get("bd")), stats


def moe_block(p: dict, x: jnp.ndarray, cfg: ModelConfig, capture: bool = False):
    """Top-k token-choice MoE with capacity. Expert params are stacked on a
    leading E axis.

    Under a mesh (shard-ctx armed) the block is HAND-PARTITIONED with
    shard_map: dispatch/combine gathers stay shard-local (GSPMD's generic
    scatter/gather partitioning replicated the batch — measured 100 GB/device
    of collectives on mixtral train_4k), expert FFNs are TP over d_ff, and a
    single [B,T,D] psum per layer closes the block. See EXPERIMENTS §Perf.
    """
    if _SHARD_CTX["enabled"] and _SHARD_CTX.get("mesh") is not None and not capture:
        return _moe_block_shardmap(p, x, cfg)
    return _moe_block_local(p, x, cfg, capture)


def _moe_block_local(p: dict, x: jnp.ndarray, cfg: ModelConfig, capture: bool = False):
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(T * K / E * cfg.capacity_factor))
    stats = {}
    if capture:
        stats["mlp_in"] = jnp.mean(x.reshape(-1, D), 0)

    router_logits = linear(x, p["router"], p.get("router_b"))  # [B, T, E]
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)         # [B, T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9
    )

    # --- gather/scatter dispatch --------------------------------------------
    # The classic one-hot dispatch einsum ('btec,btd->becd') costs
    # 2·B·T·E·C·D FLOPs — measured ~100× the expert FFN itself on mixtral
    # train_4k (EXPERIMENTS §Perf iteration 2). Instead we build an explicit
    # slot→token index map and move tokens with gathers (≈0 FLOPs; per-batch
    # gathers stay shard-local under the B=data sharding).
    slot_token = jnp.full((B, E, C + 1), T, jnp.int32)     # T = OOB sentinel
    slot_gate = jnp.zeros((B, E, C + 1), jnp.float32)
    token_pos = []
    used = jnp.zeros((B, E), jnp.int32)
    b_idx = jnp.arange(B)[:, None]
    t_idx = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    for slot in range(K):
        e = gate_idx[..., slot]                            # [B, T]
        onehot = jax.nn.one_hot(e, E, dtype=jnp.int32)     # [B, T, E]
        pos = jnp.cumsum(onehot, axis=1) - 1 + used[:, None, :]
        pos_sel = jnp.take_along_axis(pos, e[..., None], -1)[..., 0]  # [B, T]
        keep = pos_sel < C
        write_pos = jnp.where(keep, pos_sel, C)            # C = dropped bin
        slot_token = slot_token.at[b_idx, e, write_pos].set(t_idx)
        slot_gate = slot_gate.at[b_idx, e, write_pos].set(
            jnp.where(keep, gate_vals[..., slot], 0.0))
        token_pos.append((e, write_pos, keep))
        used = used + jnp.sum(onehot * (pos < C), axis=1)

    slot_token = slot_token[..., :C]                       # [B, E, C]
    slot_gate = slot_gate[..., :C]
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    # single flat gather along T — indexing via a [B, E, T+1, D] broadcast
    # operand was measured to 6× the collective bytes (EXPERIMENTS §Perf)
    ex_in = jnp.take_along_axis(
        x_pad, slot_token.reshape(B, E * C)[..., None], axis=1
    ).reshape(B, E, C, D)

    def expert_ffn(w, xin):
        # linear() so int8 QTensor expert weights dispatch through the kernels
        if cfg.act.endswith("_glu"):
            h = _act(cfg.act[:-4])(linear(xin, w["wg"])) * linear(xin, w["wu"])
        else:
            h = _act(cfg.act)(linear(xin, w["wu"]))
        return h, linear(h, w["wd"])

    h_pre, ex_out = jax.vmap(
        lambda w, xin: expert_ffn(w, xin), in_axes=(0, 1), out_axes=(0, 1)
    )(p["experts"], ex_in)

    # combine: per-slot gather from the expert outputs, weighted by the gate
    y = jnp.zeros((B, T, D), x.dtype)
    for slot in range(K):
        e, write_pos, keep = token_pos[slot]
        flat = e * C + jnp.minimum(write_pos, C - 1)       # [B, T]
        ex_flat = ex_out.reshape(B, E * C, D)
        picked = jnp.take_along_axis(ex_flat, flat[..., None], axis=1)
        w_k = jnp.where(keep, gate_vals[..., slot], 0.0).astype(x.dtype)
        y = y + picked * w_k[..., None]

    if cfg.n_shared_experts:
        shared, _ = mlp_block(p["shared"], x, cfg.act)
        y = y + shared
    if capture:
        stats["down_in_moe"] = jnp.mean(h_pre, axis=(1, 2))  # [E, F] per expert
        stats["router_probs"] = jnp.mean(probs.reshape(-1, E), 0)
    # load-balancing auxiliary loss (Switch/GShard) for training
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0].reshape(-1), E, dtype=jnp.float32), axis=0
    )
    aux = E * jnp.sum(me * ce)
    return y, aux, stats


def _moe_specs(p: dict, dp, mdl):
    """shard_map in_specs for the MoE param subtree, by leaf name. QTensor
    children flatten with index keys: index 0 = int8 payload (follows the
    parent weight's spec), index 1 = scale (replicated)."""
    from jax.sharding import PartitionSpec as P

    def spec(path, leaf):
        nd = leaf.ndim if hasattr(leaf, "ndim") else 0
        name = None
        is_scale = False
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
            if hasattr(entry, "idx") or hasattr(entry, "index"):
                idx = getattr(entry, "idx", getattr(entry, "index", None))
                is_scale = is_scale or idx == 1
        if is_scale or name is None:
            return P(*([None] * nd))
        if name in ("wu", "wg"):
            return P(*([None] * (nd - 1)), mdl)       # F sharded (col-parallel)
        if name == "wd":
            return P(*([None] * (nd - 2)), mdl, None)  # F sharded (row-parallel)
        return P(*([None] * nd))                       # router / biases replicate

    return jax.tree_util.tree_map_with_path(spec, p)


def _moe_block_shardmap(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    from jax.sharding import PartitionSpec as P

    ctx = _SHARD_CTX
    dp, mdl, mesh = ctx["dp"], ctx["model"], ctx["mesh"]
    dp_n = 1
    for a in dp:
        dp_n *= dict(mesh.shape)[a]
    # batch=1 long-context decode can't shard over data — replicate instead
    x_batch_spec = dp if x.shape[0] % dp_n == 0 else None

    def local_fn(p, x):
        if cfg.n_shared_experts:
            # the shared expert's output bias is replicated across the model
            # axis but the block output is psum'd — pre-scale to keep it exact
            n = jax.lax.psum(1.0, mdl)
            p = {**p, "shared": {**p["shared"], "bd": p["shared"]["bd"] / n}}
        y, aux, _ = _moe_block_local(p, x, cfg, capture=False)
        # wd was applied on a d_ff shard → partial sums; one psum closes it
        y = jax.lax.psum(y, mdl)
        aux = jax.lax.pmean(jax.lax.pmean(aux, mdl), dp)
        return y, aux

    y, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(_moe_specs(p, dp, mdl), P(x_batch_spec, None, None)),
        out_specs=(P(x_batch_spec, None, None), P()),
        **{_SHARD_MAP_CHECK_KW: False},
    )(p, x)
    return y, aux, {}
