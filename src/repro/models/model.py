"""Model construction dispatch + input-spec factory for the dry-run."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig, ShapeConfig
from .encdec import EncDecModel
from .lm import LMModel


def build_model(cfg: ModelConfig):
    if cfg.is_encdec:
        return EncDecModel(cfg)
    return LMModel(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell — weak-type
    correct, shardable, no device allocation (dry-run contract)."""
    B, T = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
    if shape.kind == "train":
        specs = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": tok}
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            )
        return specs
    # decode: one new token against a cache of length T
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the KV/SSM cache of a decode cell."""
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype)
    )
