from .config import SHAPES, SHAPE_BY_NAME, ModelConfig, ShapeConfig, shape_applicable  # noqa: F401
from .model import build_model  # noqa: F401
