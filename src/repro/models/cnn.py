"""MobileNetV2-style inverted-residual CNN — the paper's own architecture
(Sandler et al. 2018), built in JAX with BatchNorm + ReLU6 so the FULL
paper pipeline applies exactly: BN fold → ReLU6→ReLU swap → CLE → bias
absorption (BN stats) → analytic bias correction (clipped normal).

This is the faithful-reproduction vehicle: benchmarks/table*.py replay the
paper's ablations on it (Tables 1, 2, 6, 7, 8; Figs. 2, 3, 6).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from ..core import (
    BNParams,
    ConvLayer,
    QuantSpec,
    absorb_conv,
    absorption_amount,
    bias_correction_conv,
    bias_correction_dense,
    equalize_conv_chain,
    expected_input_analytic,
    fake_quant,
    fold_bn_conv,
)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "mobilenet_v2"
    in_channels: int = 3
    num_classes: int = 10
    width: int = 16
    # (expansion, out_channels, stride) per inverted-residual block
    blocks: tuple = ((1, 16, 1), (4, 24, 2), (4, 24, 1), (4, 32, 2), (4, 32, 1))
    img_size: int = 32
    act_clip: Optional[float] = 6.0  # ReLU6 (paper swaps to ReLU pre-CLE)


def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def _act(x, clip_max):
    x = jax.nn.relu(x)
    return jnp.minimum(x, clip_max) if clip_max is not None else x


class MobileNetCNN:
    """Params: stem conv+bn, blocks of (expand 1x1, depthwise 3x3, project
    1x1) each with BN, then GAP + dense classifier."""

    def __init__(self, cfg: CNNConfig):
        self.cfg = cfg

    def init(self, key) -> dict:
        cfg = self.cfg
        ks = iter(jax.random.split(key, 4 + 3 * len(cfg.blocks)))

        def conv_init(k, kh, kw, cin, cout):
            fan = kh * kw * cin
            return jax.random.normal(k, (kh, kw, cin, cout)) / (fan ** 0.5)

        def bn_init(c):
            return {"gamma": jnp.ones(c), "beta": jnp.zeros(c),
                    "mean": jnp.zeros(c), "var": jnp.ones(c)}

        params: dict = {
            "stem": {"w": conv_init(next(ks), 3, 3, cfg.in_channels, cfg.width),
                     "bn": bn_init(cfg.width)},
            "blocks": [],
        }
        cin = cfg.width
        for exp, cout, stride in cfg.blocks:
            mid = cin * exp
            params["blocks"].append({
                "expand": {"w": conv_init(next(ks), 1, 1, cin, mid), "bn": bn_init(mid)},
                "dw": {"w": conv_init(next(ks), 3, 3, 1, mid), "bn": bn_init(mid)},
                "project": {"w": conv_init(next(ks), 1, 1, mid, cout), "bn": bn_init(cout)},
            })
            cin = cout
        params["head"] = {
            "w": jax.random.normal(next(ks), (cin, cfg.num_classes)) / (cin ** 0.5),
            "b": jnp.zeros(cfg.num_classes),
        }
        return params

    # ---------------------------------------------------------- training fwd
    def apply_train(self, params, x, train_bn: bool = True):
        """Forward with live batch statistics; returns logits and updated
        running BN stats (momentum 0.9)."""
        cfg = self.cfg
        new_params = jax.tree.map(lambda a: a, params)

        def bn_apply(h, bn, path):
            if train_bn:
                mu = jnp.mean(h, axis=(0, 1, 2))
                var = jnp.var(h, axis=(0, 1, 2))
                node = new_params
                for k in path[:-1]:
                    node = node[k]
                node[path[-1]] = {
                    "gamma": bn["gamma"], "beta": bn["beta"],
                    "mean": 0.9 * bn["mean"] + 0.1 * mu,
                    "var": 0.9 * bn["var"] + 0.1 * var,
                }
            else:
                mu, var = bn["mean"], bn["var"]
            return (h - mu) * jax.lax.rsqrt(var + 1e-5) * bn["gamma"] + bn["beta"]

        h = _conv(x, params["stem"]["w"], 2)
        h = _act(bn_apply(h, params["stem"]["bn"], ("stem", "bn")), cfg.act_clip)
        for i, blk in enumerate(params["blocks"]):
            inp = h
            h = _conv(h, blk["expand"]["w"])
            h = _act(bn_apply(h, blk["expand"]["bn"], ("blocks", i, "expand", "bn")), cfg.act_clip)
            h = _conv(h, blk["dw"]["w"], self.cfg.blocks[i][2],
                      groups=blk["dw"]["w"].shape[-1])
            h = _act(bn_apply(h, blk["dw"]["bn"], ("blocks", i, "dw", "bn")), cfg.act_clip)
            h = _conv(h, blk["project"]["w"])
            h = bn_apply(h, blk["project"]["bn"], ("blocks", i, "project", "bn"))
            if inp.shape == h.shape:
                h = h + inp
        h = jnp.mean(h, axis=(1, 2))
        logits = h @ params["head"]["w"] + params["head"]["b"]
        return logits, new_params

    def loss(self, params, batch):
        logits, new_params = self.apply_train(params, batch["x"])
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(logz - gold), new_params

    # ------------------------------------------------- folded inference form
    def fold(self, params) -> dict:
        """BN-fold every conv (paper §5). Returns an inference pytree of
        ConvLayer-style entries + per-layer BN moments for BA/BC."""
        def fold_one(w, bn):
            return fold_bn_conv(w, None, BNParams(
                bn["gamma"], bn["beta"], bn["mean"], bn["var"]))

        folded: dict = {"stem": fold_one(params["stem"]["w"], params["stem"]["bn"]),
                        "blocks": []}
        for i, blk in enumerate(params["blocks"]):
            folded["blocks"].append({
                "expand": fold_one(blk["expand"]["w"], blk["expand"]["bn"]),
                "dw": fold_one(blk["dw"]["w"], blk["dw"]["bn"]),
                "stride": self.cfg.blocks[i][2],
                "project": fold_one(blk["project"]["w"], blk["project"]["bn"]),
            })
        folded["head"] = dict(params["head"])
        return folded

    def apply_folded(self, folded, x, act_clip=None, act_quant=None):
        """Inference on the folded form. ``act_quant(h, layer_name, moments)``
        optionally fake-quantizes activations (data-free ranges β ± 6γ)."""
        def act(h, name, mean, std):
            h = _act(h, act_clip)
            if act_quant is not None:
                h = act_quant(h, name, mean, std)
            return h

        h = _conv(x, folded["stem"].w, 2) + folded["stem"].b
        h = act(h, "stem", folded["stem"].act_mean, folded["stem"].act_std)
        for i, blk in enumerate(folded["blocks"]):
            inp = h
            h = _conv(h, blk["expand"].w) + blk["expand"].b
            h = act(h, f"b{i}_expand", blk["expand"].act_mean, blk["expand"].act_std)
            h = _conv(h, blk["dw"].w, blk["stride"], groups=blk["dw"].w.shape[-1])
            h = act(h, f"b{i}_dw", blk["dw"].act_mean, blk["dw"].act_std)
            h = _conv(h, blk["project"].w) + blk["project"].b
            if inp.shape == h.shape:
                h = h + inp
        h = jnp.mean(h, axis=(1, 2))
        return h @ folded["head"]["w"] + folded["head"]["b"]

    # -------------------------------------------------------------- DFQ flow
    def chains(self, folded) -> List[List[tuple]]:
        """Equalization chains (paths into the folded tree), one per
        inverted-residual block: expand → depthwise → project (paper §5.1.1:
        equalization within each residual block)."""
        out = []
        for i in range(len(folded["blocks"])):
            out.append([
                (("blocks", i, "expand"), "conv"),
                (("blocks", i, "dw"), "depthwise"),
                (("blocks", i, "project"), "conv"),
            ])
        return out

    def equalize(self, folded, iterations: int = 20) -> dict:
        import copy
        folded = copy.deepcopy(jax.device_get(folded))
        for chain in self.chains(folded):
            layers = []
            for path, kind in chain:
                node = folded
                for k in path[:-1]:
                    node = node[k]
                fl = node[path[-1]]
                layers.append(ConvLayer(jnp.asarray(fl.w), jnp.asarray(fl.b), kind))
            new_layers, cum = equalize_conv_chain(layers, iterations)
            for j, (path, kind) in enumerate(chain):
                node = folded
                for k in path[:-1]:
                    node = node[k]
                fl = node[path[-1]]
                nl = new_layers[j]
                if j < len(cum):
                    # layer j's output channels were divided by cum[j] — the
                    # BN-derived pre-activation moments scale identically
                    # (exact: the whole channel, weights+bias, is rescaled).
                    mean = jnp.asarray(fl.act_mean) / cum[j]
                    std = jnp.asarray(fl.act_std) / cum[j]
                else:
                    mean, std = fl.act_mean, fl.act_std
                node[path[-1]] = fl._replace(w=nl.w, b=nl.b, act_mean=mean,
                                             act_std=std)
        return folded

    def absorb_high_bias(self, folded, n_sigma: float = 3.0) -> dict:
        """Paper §4.1.3 over each (expand→dw) and (dw→project) interface."""
        import copy
        folded = copy.deepcopy(jax.device_get(folded))
        for i in range(len(folded["blocks"])):
            blk = folded["blocks"][i]
            for src, dst, depthwise in (("expand", "dw", True), ("dw", "project", False)):
                fl1, fl2 = blk[src], blk[dst]
                c = absorption_amount(jnp.asarray(fl1.act_mean),
                                      jnp.asarray(fl1.act_std), n_sigma)
                res = absorb_conv(jnp.asarray(fl1.b), jnp.asarray(fl2.w),
                                  jnp.asarray(fl2.b), c, depthwise=depthwise)
                blk[src] = fl1._replace(b=res.b1, act_mean=fl1.act_mean - c)
                blk[dst] = fl2._replace(b=res.b2)
        return folded

    def quantize_weights(self, folded, spec: QuantSpec) -> dict:
        import copy
        q = copy.deepcopy(jax.device_get(folded))
        q["stem"] = q["stem"]._replace(w=fake_quant(jnp.asarray(q["stem"].w), spec))
        for blk in q["blocks"]:
            for k in ("expand", "dw", "project"):
                blk[k] = blk[k]._replace(w=fake_quant(jnp.asarray(blk[k].w), spec))
        q["head"]["w"] = fake_quant(jnp.asarray(q["head"]["w"]), spec)
        return q

    def bias_correct_analytic(self, folded, q, spec: QuantSpec,
                              act_clip=None) -> dict:
        """Paper §4.2.1: E[x] from the clipped-normal closed form on the
        PREVIOUS layer's BN moments; correction per conv (appendix B)."""
        import copy
        q = copy.deepcopy(jax.device_get(q))
        act = "relu6" if act_clip == 6.0 else "relu"
        for i, blk in enumerate(folded["blocks"]):
            prev = folded["stem"] if i == 0 else folded["blocks"][i - 1]["project"]
            # project has no activation after it (linear bottleneck) → identity
            e_in = (expected_input_analytic(jnp.asarray(prev.act_mean),
                                            jnp.asarray(prev.act_std), act)
                    if i == 0 else jnp.asarray(prev.act_mean))
            qblk = q["blocks"][i]
            qblk["expand"] = qblk["expand"]._replace(
                b=bias_correction_conv(jnp.asarray(blk["expand"].w),
                                       jnp.asarray(qblk["expand"].b), e_in, spec))
            e_mid = expected_input_analytic(jnp.asarray(blk["expand"].act_mean),
                                            jnp.asarray(blk["expand"].act_std), act)
            qblk["dw"] = qblk["dw"]._replace(
                b=bias_correction_conv(jnp.asarray(blk["dw"].w), jnp.asarray(qblk["dw"].b),
                                       e_mid, spec, depthwise=True))
            e_dw = expected_input_analytic(jnp.asarray(blk["dw"].act_mean),
                                           jnp.asarray(blk["dw"].act_std), act)
            qblk["project"] = qblk["project"]._replace(
                b=bias_correction_conv(jnp.asarray(blk["project"].w),
                                       jnp.asarray(qblk["project"].b), e_dw, spec))
        return q
