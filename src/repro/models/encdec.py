"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the conv/audio frontend is a STUB: ``input_specs`` /
callers provide precomputed frame embeddings [B, enc_seq, d_model]. The
backbone is faithful: pre-LN transformer, LayerNorm (γ, β), GELU MLPs with
biases everywhere, sinusoidal encoder positions, learned decoder positions,
causal decoder self-attention + cross-attention to the encoder output.

DFQ notes (DESIGN §3): plain-GELU MLP pairs are *approximate* CLE (flagged
``exact=False``); LayerNorm gives the analytic bias-correction route its
(β, γ) statistics — the LN analogue of the paper's BatchNorm assumption.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.graph import (
    DFQPlan,
    DensePairOp,
    NormFoldOp,
    QKPairOp,
    VBiasAbsorbOp,
    VOPairOp,
    WeightSite,
)
from .config import ModelConfig
from .layers import (
    AttnDims,
    apply_norm,
    attention_block,
    causal_mask,
    linear,
    mlp_block,
    scan_layers,
)


def sinusoidal_positions(T: int, d: int):
    pos = jnp.arange(T)[:, None]
    dim = jnp.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _init_attn(self, key, dtype, v_bias=True):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        s = 1.0 / (cfg.d_model ** 0.5)
        return {
            "wq": (jax.random.normal(ks[0], (cfg.d_model, cfg.attn_dim)) * s).astype(dtype),
            "bq": jnp.zeros((cfg.attn_dim,), dtype),
            "wk": (jax.random.normal(ks[1], (cfg.d_model, cfg.kv_dim)) * s).astype(dtype),
            "bk": jnp.zeros((cfg.kv_dim,), dtype),
            "wv": (jax.random.normal(ks[2], (cfg.d_model, cfg.kv_dim)) * s).astype(dtype),
            "bv": jnp.zeros((cfg.kv_dim,), dtype),
            "wo": (jax.random.normal(ks[3], (cfg.attn_dim, cfg.d_model)) * s).astype(dtype),
            "bo": jnp.zeros((cfg.d_model,), dtype),
        }

    def _init_mlp(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {
            "wu": (jax.random.normal(ks[0], (cfg.d_model, cfg.d_ff)) / cfg.d_model ** 0.5).astype(dtype),
            "bu": jnp.zeros((cfg.d_ff,), dtype),
            "wd": (jax.random.normal(ks[1], (cfg.d_ff, cfg.d_model)) / cfg.d_ff ** 0.5).astype(dtype),
            "bd": jnp.zeros((cfg.d_model,), dtype),
        }

    def _ln(self, dtype):
        return {"w": jnp.ones((self.cfg.d_model,), dtype),
                "b": jnp.zeros((self.cfg.d_model,), dtype)}

    def _init_enc_block(self, key, dtype):
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": self._ln(dtype), "attn": self._init_attn(k1, dtype),
            "mlp_norm": self._ln(dtype), "mlp": self._init_mlp(k2, dtype),
        }

    def _init_dec_block(self, key, dtype):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "attn_norm": self._ln(dtype), "attn": self._init_attn(k1, dtype),
            "cross_norm": self._ln(dtype), "cross": self._init_attn(k2, dtype),
            "mlp_norm": self._ln(dtype), "mlp": self._init_mlp(k3, dtype),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 5)
        stack = lambda fn, k, n: jax.tree.map(
            lambda *xs: jnp.stack(xs), *[fn(kk, dtype) for kk in jax.random.split(k, n)]
        )
        return {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
            "dec_pos": (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model)) * 0.01).astype(dtype),
            "enc_blocks": stack(self._init_enc_block, ks[2], cfg.n_enc_layers),
            "dec_blocks": stack(self._init_dec_block, ks[3], cfg.n_layers),
            "enc_final_norm": self._ln(dtype),
            "final_norm": self._ln(dtype),
        }

    # -------------------------------------------------------------- forward
    def _dims(self, window=None) -> AttnDims:
        cfg = self.cfg
        return AttnDims(n_q=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                        rope=False, window=window,
                        causal_segments=cfg.attn_causal_segments)

    def encode(self, params, frames, *, capture: bool = False):
        """frames: [B, enc_seq, d_model] stub embeddings → encoder states."""
        cfg = self.cfg
        compute = jnp.dtype(cfg.dtype)
        x = frames.astype(compute) + sinusoidal_positions(
            frames.shape[1], cfg.d_model
        ).astype(compute)
        positions = jnp.arange(frames.shape[1])

        def body(carry, p):
            x = carry
            h = apply_norm(x, p["attn_norm"], "ln")
            a, _, s1 = attention_block(
                p["attn"], h, self._dims(), positions=positions, mask=None,
                capture=capture, unroll=cfg.unroll_layers,
            )
            x = x + a
            h = apply_norm(x, p["mlp_norm"], "ln")
            m, s2 = mlp_block(p["mlp"], h, cfg.act, capture=capture)
            return x + m, {**s1, **s2} if capture else {}

        body = jax.checkpoint(body) if cfg.remat else body
        x, stats = scan_layers(body, x, self._cast(params["enc_blocks"], compute),
                               cfg.unroll_layers)
        x = apply_norm(x, self._cast(params["enc_final_norm"], compute), "ln")
        return x, stats

    @staticmethod
    def _cast(tree, compute):
        return jax.tree.map(
            lambda a: a.astype(compute) if a.dtype == jnp.float32 and compute != jnp.float32 else a,
            tree,
        )

    def decode(
        self, params, tokens, enc_out, *, cache: Optional[dict] = None,
        capture: bool = False, chunk_kv: Optional[int] = None,
    ):
        cfg = self.cfg
        compute = jnp.dtype(cfg.dtype)
        params = self._cast(params, compute)
        B, T = tokens.shape
        pos0 = cache["pos"] if cache is not None else 0
        positions = pos0 + jnp.arange(T)
        x = jnp.take(params["embed"], tokens, axis=0).astype(compute)
        x = x + jnp.take(params["dec_pos"], positions, axis=0).astype(compute)
        mask = None if cache is not None else causal_mask(T, T, 0)

        def body(carry, inp):
            x = carry
            if cache is not None:
                p, kv = inp
                self_cache = {"k": kv["k"], "v": kv["v"],
                              "kpos": cache["kpos"], "pos": pos0}
                cross_cache = {"k": kv["ck"], "v": kv["cv"]}
            else:
                p = inp
                self_cache = None
                cross_cache = None
            h = apply_norm(x, p["attn_norm"], "ln")
            a, new_self, s1 = attention_block(
                p["attn"], h, self._dims(), positions=positions, mask=mask,
                cache=self_cache, chunk_kv=chunk_kv, capture=capture,
                unroll=cfg.unroll_layers,
            )
            x = x + a
            h = apply_norm(x, p["cross_norm"], "ln")
            if cross_cache is not None:
                c, _, s2 = attention_block(
                    p["cross"], h, self._dims(), positions=positions, mask=None,
                    cache=cross_cache, kv_input=jnp.zeros_like(h[:, :1]),
                    capture=capture,
                )
            else:
                c, _, s2 = attention_block(
                    p["cross"], h, self._dims(), positions=positions, mask=None,
                    kv_input=enc_out, capture=capture,
                )
            x = x + c
            h = apply_norm(x, p["mlp_norm"], "ln")
            m, s3 = mlp_block(p["mlp"], h, cfg.act, capture=capture)
            x = x + m
            ys = {}
            if cache is not None:
                ys.update({"k": new_self["k"], "v": new_self["v"],
                           "kpos": new_self["kpos"]})
            if capture:
                ys["stats"] = {
                    **{f"dec_{k}": v for k, v in s1.items()},
                    **{f"cross_{k}": v for k, v in s2.items()},
                    **{f"dec_{k}": v for k, v in s3.items()},
                }
            return x, ys

        if cache is not None:
            xs = (params["dec_blocks"],
                  {"k": cache["k"], "v": cache["v"],
                   "ck": cache["ck"], "cv": cache["cv"]})
        else:
            xs = params["dec_blocks"]
        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, ys = scan_layers(body_fn, x, xs, cfg.unroll_layers)
        x = apply_norm(x, params["final_norm"], "ln")
        from .layers import _SHARD_CTX, _wsc

        if _SHARD_CTX["enabled"]:
            x = _wsc(x, _SHARD_CTX["dp"], None, None)
        logits = x @ params["embed"].T.astype(x.dtype)
        if _SHARD_CTX["enabled"]:
            logits = _wsc(logits, _SHARD_CTX["dp"], None, _SHARD_CTX["model"])
        new_cache = None
        if cache is not None:
            new_cache = {
                "k": ys["k"], "v": ys["v"], "kpos": ys["kpos"][0],
                "ck": cache["ck"], "cv": cache["cv"], "pos": pos0 + T,
            }
        stats = ys.get("stats", {}) if capture else {}
        return logits, new_cache, stats

    def apply(self, params, tokens, frames=None, *, capture=False, chunk_kv=None,
              return_hidden=False):
        """Teacher-forced training forward. frames default: zeros stub."""
        cfg = self.cfg
        if frames is None:
            frames = jnp.zeros((tokens.shape[0], cfg.enc_seq, cfg.d_model),
                               jnp.dtype(cfg.dtype))
        enc_out, enc_stats = self.encode(params, frames, capture=capture)
        logits, _, dec_stats = self.decode(
            params, tokens, enc_out, capture=capture, chunk_kv=chunk_kv
        )
        stats = {}
        if capture:
            stats = {**{f"enc_{k}": v for k, v in enc_stats.items()}, **dec_stats}
        return logits, (0.0, stats)

    def loss(self, params, batch, *, chunk_kv=None):
        logits, _ = self.apply(
            params, batch["tokens"], batch.get("frames"), chunk_kv=chunk_kv
        )
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(
            jnp.where(iota == batch["labels"][..., None], logits, 0.0), axis=-1
        )
        return jnp.mean(logz - gold)

    # ---------------------------------------------------------------- cache
    def cache_len(self, seq_len: int) -> int:
        return seq_len

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((L, batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "ck": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            "cv": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            "kpos": jnp.full((seq_len,), -1, jnp.int32),
            "pos": jnp.zeros((), jnp.int32),
        }

    def warm_cache(self, params, frames, cache):
        """Encoder pass + cross K/V projection (once per request)."""
        cfg = self.cfg
        compute = jnp.dtype(cfg.dtype)
        enc_out, _ = self.encode(params, frames)
        p = self._cast(params["dec_blocks"], compute)

        def proj(p_layer):
            k = linear(enc_out, p_layer["cross"]["wk"], p_layer["cross"]["bk"])
            v = linear(enc_out, p_layer["cross"]["wv"], p_layer["cross"]["bv"])
            B, S = enc_out.shape[:2]
            return (k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
                    v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim))

        ck, cv = jax.vmap(proj)(p)
        return {**cache, "ck": ck.astype(cache["ck"].dtype),
                "cv": cv.astype(cache["cv"].dtype)}

    def prefill(self, params, tokens, cache, *, chunk_kv=None):
        logits, new_cache, _ = self.decode(
            params, tokens, None, cache=cache, chunk_kv=chunk_kv
        )
        return logits[:, -1] if logits.ndim == 3 else logits, new_cache

    def decode_step(self, params, token, cache):
        logits, new_cache, _ = self.decode(params, token, None, cache=cache)
        return logits[:, -1] if logits.ndim == 3 else logits, new_cache

    # ------------------------------------------------------------- DFQ plan
    def dfq_plan(self) -> DFQPlan:
        cfg = self.cfg
        ops: list = []
        sites: list = []
        for stack, pre in (("enc_blocks", "enc"), ("dec_blocks", "dec")):
            def P(*rest, stack=stack):
                return (stack,) + rest

            attns = [("attn", f"{pre}_attn")]
            if stack == "dec_blocks":
                attns.append(("cross", "cross_attn"))
            for attn_key, stat in attns:
                ops.append(NormFoldOp(
                    norm_w=P(f"{'attn' if attn_key == 'attn' else 'cross'}_norm", "w"),
                    norm_b=P(f"{'attn' if attn_key == 'attn' else 'cross'}_norm", "b"),
                    consumers=[P(attn_key, "wq"), P(attn_key, "wk"), P(attn_key, "wv")],
                    consumer_biases=[P(attn_key, "bq"), P(attn_key, "bk"), P(attn_key, "bv")],
                ))
                ops.append(VOPairOp(
                    wv=P(attn_key, "wv"), wo=P(attn_key, "wo"), bv=P(attn_key, "bv"),
                    n_q=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                ))
                ops.append(QKPairOp(
                    wq=P(attn_key, "wq"), wk=P(attn_key, "wk"),
                    bq=P(attn_key, "bq"), bk=P(attn_key, "bk"),
                    n_q=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                    rope=False,
                ))
                ops.append(VBiasAbsorbOp(
                    bv=P(attn_key, "bv"), wo=P(attn_key, "wo"), bo=P(attn_key, "bo"),
                    n_q=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                ))
                in_stat = f"{pre}_attn_in" if attn_key == "attn" else "cross_attn_in"
                o_stat = f"{pre}_o_in" if attn_key == "attn" else "cross_o_in"
                sites += [
                    WeightSite(f"{pre}_{attn_key}_wq", P(attn_key, "wq"), P(attn_key, "bq"),
                               "dense", in_stat),
                    WeightSite(f"{pre}_{attn_key}_wk", P(attn_key, "wk"), P(attn_key, "bk"),
                               "dense", None),
                    WeightSite(f"{pre}_{attn_key}_wv", P(attn_key, "wv"), P(attn_key, "bv"),
                               "dense", None),
                    WeightSite(f"{pre}_{attn_key}_wo", P(attn_key, "wo"), P(attn_key, "bo"),
                               "dense", o_stat),
                ]
            ops.append(NormFoldOp(
                norm_w=P("mlp_norm", "w"), norm_b=P("mlp_norm", "b"),
                consumers=[P("mlp", "wu")], consumer_biases=[P("mlp", "bu")],
            ))
            # plain-GELU MLP: CLE is approximate here (DESIGN §3.1)
            ops.append(DensePairOp(
                w1=P("mlp", "wu"), b1=P("mlp", "bu"), w2=P("mlp", "wd"), exact=False,
            ))
            sites += [
                WeightSite(f"{pre}_wu", P("mlp", "wu"), P("mlp", "bu"),
                           "dense", f"{pre}_mlp_in"),
                WeightSite(f"{pre}_wd", P("mlp", "wd"), P("mlp", "bd"),
                           "dense", f"{pre}_down_in"),
            ]
        return DFQPlan(tuple(ops), tuple(sites), cfg.name)

    def calibration_stats(self, params, tokens, frames=None):
        _, (_, stats) = self.apply(params, tokens, frames, capture=True)
        return stats
