from .adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
from .compression import compressed_mean, ef_compress, ef_init  # noqa: F401
