"""Int8 error-feedback gradient compression for cross-pod all-reduce.

The paper's own insight — quantization error is *biased* and must be
corrected (§4.2) — applied to distributed optimization: error feedback keeps
a per-tensor residual of the int8 quantization error and adds it back before
the next round, making the compressed all-reduce unbiased over time.

``compressed_mean`` runs inside ``shard_map`` over the gradient-sync axis:
int8 payload (+1 fp32 scale per tensor) crosses the interconnect instead of
fp32 — a 4× cross-pod byte reduction visible in the dry-run HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress(g: jnp.ndarray, residual: jnp.ndarray):
    """Quantize g+residual to int8; return (q, scale, new_residual)."""
    x = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_residual = x - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def compressed_mean(g: jnp.ndarray, residual: jnp.ndarray, axis_name: str):
    """Mean of g across ``axis_name`` with int8 payload + error feedback.

    all_gather(int8) + local dequant-sum: the wire carries 1 byte/element.
    Returns (mean_g fp32, new_residual).
    """
    q, scale, new_residual = ef_compress(g, residual)
    q_all = jax.lax.all_gather(q, axis_name)              # int8 on the wire
    s_all = jax.lax.all_gather(scale, axis_name)
    n = q_all.shape[0]
    mean = jnp.tensordot(
        s_all / n, q_all.astype(jnp.float32), axes=((0,), (0,))
    )
    return mean, new_residual
