"""AdamW with decoupled weight decay and global-norm clipping.

Hand-rolled (no optax in the container) as pure pytree transforms: the m/v
moments inherit the parameter sharding, so under pjit the optimizer update is
fully sharded (ZeRO-style when params are FSDP-sharded over the data axis).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        decay = weight_decay if p.ndim >= 2 else 0.0  # no decay on norms/biases
        return (p.astype(jnp.float32) - lr * (u + decay * p.astype(jnp.float32))
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), gn
