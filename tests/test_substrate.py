"""Distributed-substrate tests: optimizer, data determinism, checkpointing,
fault tolerance, gradient compression, sharding planner, quantized serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import calibration_tokens, synthetic_image_batch, token_batch
from repro.optim import (
    adamw_init,
    adamw_update,
    compressed_mean,
    cosine_schedule,
    ef_compress,
    ef_init,
)
from repro.runtime import FaultTolerantLoop, StragglerMonitor, elastic_restore


# ------------------------------------------------------------------ optimizer
def test_adamw_reduces_quadratic_loss():
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (8, 8))
    params = {"w": jnp.zeros((8, 8))}
    state = adamw_init(params)
    loss = lambda p: jnp.mean((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, lr=3e-2,
                                        weight_decay=0.0)
    assert float(loss(params)) < 0.01 * l0


def test_adamw_clips_global_norm():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    _, _, gn = adamw_update(huge, state, params, lr=1e-3, clip_norm=1.0)
    assert float(gn) > 1e8  # reported pre-clip norm


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert abs(float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100)) - 1.0) < 1e-6
    assert float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100)) <= 0.11


# ----------------------------------------------------------------------- data
def test_token_batch_deterministic_and_shard_independent():
    a = token_batch(0, step=3, shard=1, batch=4, seq=16, vocab=100)
    b = token_batch(0, step=3, shard=1, batch=4, seq=16, vocab=100)
    c = token_batch(0, step=3, shard=2, batch=4, seq=16, vocab=100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert int(jnp.max(a["tokens"])) < 100


def test_labels_are_next_tokens():
    b = token_batch(0, 0, 0, 2, 8, 50)
    # structurally: labels[t] should continue the stream (bigram structure is
    # learnable); here just check shapes/dtypes and range
    assert b["tokens"].shape == b["labels"].shape == (2, 8)


def test_calibration_tokens_data_free():
    t1 = calibration_tokens(0, 4, 32, 1000)
    t2 = calibration_tokens(0, 4, 32, 1000)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_synthetic_images_class_structure():
    b = synthetic_image_batch(0, 0, 64, 16, 3, 4)
    assert b["x"].shape == (64, 16, 16, 3)
    assert set(np.unique(np.asarray(b["y"]))) <= set(range(4))


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4) * 2}}
    ckpt.save(5, tree, blocking=True)
    restored, step = ckpt.restore(tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_checkpoint_retention_and_latest(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree, blocking=True)
    assert ckpt.latest_step() == 4
    dirs = sorted(os.listdir(tmp_path))
    assert "step_1" not in dirs and "step_2" not in dirs


def test_checkpoint_ignores_partial_writes(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    tree = {"a": jnp.zeros(3)}
    ckpt.save(1, tree, blocking=True)
    os.makedirs(tmp_path / "step_9.tmp-123")  # simulated crash mid-write
    assert ckpt.latest_step() == 1
    ckpt2 = Checkpointer(str(tmp_path))  # restart cleans tmp
    assert not any(".tmp" in d for d in os.listdir(tmp_path))


def test_checkpoint_async(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(1000)}
    ckpt.save(7, tree, blocking=False)
    ckpt.wait()
    assert ckpt.latest_step() == 7


def test_elastic_restore_onto_new_mesh(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    tree = {"blocks": {"w": jnp.arange(512, dtype=jnp.float32).reshape(2, 16, 16)}}
    ckpt.save(1, tree, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))  # "new" world: 1 device CPU
    restored, step = elastic_restore(ckpt, tree, mesh)
    np.testing.assert_array_equal(np.asarray(restored["blocks"]["w"]),
                                  np.asarray(tree["blocks"]["w"]))


# ----------------------------------------------------------- fault tolerance
def _toy_step(state, batch):
    state = {"x": state["x"] + jnp.sum(batch["tokens"]) * 0 + 1}
    return state, {"loss": 1.0 / float(state["x"])}


def test_ft_loop_runs_and_checkpoints(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(
        _toy_step, lambda s: token_batch(0, s, 0, 2, 8, 50), ckpt, ckpt_every=5
    )
    state, end = loop.run({"x": jnp.zeros(())}, 0, 12)
    assert end == 12 and loop.metrics.steps_run == 12
    assert ckpt.latest_step() == 12


def test_ft_loop_retries_and_restores(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(0, {"x": jnp.zeros(())}, blocking=True)
    fail_at = {7}
    fired = []

    def inject(step):
        if step in fail_at and step not in fired:
            fired.append(step)
            return True
        return False

    loop = FaultTolerantLoop(
        _toy_step, lambda s: token_batch(0, s, 0, 2, 8, 50), ckpt, ckpt_every=5
    )
    state, end = loop.run({"x": jnp.zeros(())}, 0, 10, inject_failure=inject)
    assert end == 10
    assert loop.metrics.retries == 1 and loop.metrics.restores == 1
    # replayed from step 5 checkpoint → state counts every step exactly once
    assert float(state["x"]) == 10.0


def test_ft_loop_preemption_checkpoint(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(
        _toy_step, lambda s: token_batch(0, s, 0, 2, 8, 50), ckpt, ckpt_every=100
    )

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            loop.request_preemption()
        return _toy_step(state, batch)

    loop.step_fn = step_fn
    state, end = loop.run({"x": jnp.zeros(())}, 0, 50)
    assert loop.metrics.preempted and end == 3
    assert ckpt.latest_step() == 3  # clean preemption checkpoint


def test_straggler_monitor_detects_slow_steps():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=1)
    for s in range(10):
        mon.observe(s, 0.1)
    assert mon.observe(10, 0.5)  # 5× slower
    assert len(mon.events) == 1
    assert not mon.observe(11, 0.11)  # EMA not poisoned by the spike


# ------------------------------------------------------ gradient compression
def test_ef_compress_error_feedback_unbiased():
    """Error feedback makes the LONG-RUN compressed sum match fp: the paper's
    bias-correction principle applied to gradient compression."""
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (256,)) * 0.01
    residual = jnp.zeros_like(g)
    acc_q = jnp.zeros_like(g)
    for i in range(50):
        q, scale, residual = ef_compress(g, residual)
        acc_q = acc_q + q.astype(jnp.float32) * scale
    acc_fp = g * 50
    rel = float(jnp.linalg.norm(acc_q - acc_fp) / jnp.linalg.norm(acc_fp))
    assert rel < 0.01


def test_compressed_mean_under_shard_map():
    from functools import partial

    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("dp",))
    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    r = jnp.zeros_like(g)

    from repro.models.layers import _SHARD_MAP_CHECK_KW, _shard_map

    @partial(_shard_map, mesh=mesh, in_specs=(P(), P()),
             out_specs=(P(), P()), **{_SHARD_MAP_CHECK_KW: False})
    def sync(g, r):
        return compressed_mean(g, r, "dp")

    mean, new_r = sync(g, r)
    np.testing.assert_allclose(np.asarray(mean + new_r), np.asarray(g),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- sharding planner
def test_params_pspecs_rules():
    from jax.sharding import PartitionSpec as P

    from repro.sharding import params_pspecs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate a (4, 4) production mesh via shape dict for rule checking

    class FakeMesh:
        shape = {"data": 4, "model": 4}

    shapes = {
        "embed": jax.ShapeDtypeStruct((1024, 512), jnp.float32),
        "lm_head": jax.ShapeDtypeStruct((512, 1024), jnp.float32),
        "blocks": {
            "attn": {"wq": jax.ShapeDtypeStruct((8, 512, 896), jnp.float32)},
            "mlp": {
                "experts": {"wu": jax.ShapeDtypeStruct((8, 4, 512, 1024), jnp.float32)}
            },
            "norm": {"w": jax.ShapeDtypeStruct((8, 512), jnp.float32)},
        },
    }
    specs = params_pspecs(shapes, FakeMesh(), heads={"n_q": 8, "n_kv": 8})
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model")
    # expert dim (4, not ≥128) and scan dim never sharded
    assert specs["blocks"]["mlp"]["experts"]["wu"] == P(None, None, "data", "model")
    assert specs["blocks"]["norm"]["w"] == P()
    # head count not divisible by the model axis → attention out replicates
    specs_bad = params_pspecs(shapes, FakeMesh(), heads={"n_q": 14, "n_kv": 2})
    assert specs_bad["blocks"]["attn"]["wq"] == P(None, "data", None)
    # row-parallel second matrices: in=model, out=data
    shapes_wd = {"blocks": {"mlp": {"wd": jax.ShapeDtypeStruct((8, 1024, 512), jnp.float32)}}}
    specs_wd = params_pspecs(shapes_wd, FakeMesh())
    assert specs_wd["blocks"]["mlp"]["wd"] == P(None, "model", "data")


def test_cache_pspecs_long_context_seq_sharding():
    from jax.sharding import PartitionSpec as P

    from repro.sharding import cache_pspecs

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    shapes = {
        "k": jax.ShapeDtypeStruct((4, 1, 524288, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((4, 1, 524288, 8, 128), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    specs = cache_pspecs(shapes, FakeMesh(), batch=1)
    # batch=1 unshardable → sequence axis takes the data axis
    assert specs["k"][2] == "data"


# ---------------------------------------------------------- quantized serving
def test_qtensor_roundtrip_and_dispatch():
    from repro.quantized import QTensor, quantize_param

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 32)) * 0.1
    qt = quantize_param(w, per_channel=True)
    np.testing.assert_allclose(np.asarray(qt.dequant()), np.asarray(w),
                               atol=float(jnp.max(qt.scale)) * 0.51)
    from repro.models.layers import linear

    x = jax.random.normal(key, (4, 8, 64))
    y_fp = linear(x, w, None)
    y_q = linear(x, qt, None)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.02


def test_quantized_lm_serving_end_to_end():
    """DFQ → int8 serving params → decode matches fp within int8 noise, and
    parameter bytes shrink ≈ 4×."""
    from repro.configs import get_config
    from repro.core import DFQConfig, apply_dfq
    from repro.models import build_model
    from repro.quantized import dequantize_params, quantize_for_serving, serving_summary

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = model.dfq_plan()
    params_eq = apply_dfq(params, plan, DFQConfig())
    qparams = quantize_for_serving(params_eq, plan, mode="w8a16")

    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    cache_fp = model.init_cache(2, 16, dtype=jnp.float32)
    cache_q = model.init_cache(2, 16, dtype=jnp.float32)
    logits_fp, _ = model.prefill(params_eq, tokens, cache_fp)
    logits_q, _ = model.prefill(qparams, tokens, cache_q)
    rel = float(jnp.linalg.norm(logits_q - logits_fp) / jnp.linalg.norm(logits_fp))
    assert rel < 0.05
    summary = serving_summary(qparams)
    assert summary["compression"] > 2.0
