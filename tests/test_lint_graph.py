"""QuantLint integration: real extracted graphs vs the committed contracts.

Builds the actual smoke serving engine per recipe, extracts the lint graph
(trace + lower + compile, nothing executes) and asserts (a) the committed
contract still describes it exactly — the same check the blocking lint-graph
CI job runs — and (b) the rules fire when the contract is perturbed. The TP
recipes need 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)
and skip otherwise, same idiom as test_serving_sharded.py.
"""
import copy

import jax
import pytest

from repro.analysis.lint import build_graph, run_rules
from repro.analysis.lint.contracts import diff_contracts, load_contract, snapshot

ENGINE_JITS = ("prefill", "prefill_multi", "decode", "decode_horizon")


def _errors(findings):
    return [f for f in findings if f.severity == "error"]


@pytest.fixture(scope="module")
def kv8_graph():
    # build_graph defaults match the geometry the contracts were pinned under
    return build_graph("serve-w8a8-kv8")


def test_committed_contract_still_holds(kv8_graph):
    contract = load_contract("serve-w8a8-kv8")
    assert contract is not None, "contract file missing from the package"
    findings = run_rules(kv8_graph, contract)
    assert _errors(findings) == [], [f.format() for f in _errors(findings)]


def test_fresh_snapshot_matches_committed_contract(kv8_graph):
    # extraction is deterministic for a fixed jax version: a fresh snapshot
    # must diff clean against the checked-in JSON, byte-for-byte semantics
    assert diff_contracts(load_contract("serve-w8a8-kv8"),
                          snapshot(kv8_graph)) == []


def test_graph_covers_all_serve_paths(kv8_graph):
    for name in ENGINE_JITS:
        art = kv8_graph.jits[name]
        assert art.jaxpr is not None and art.module is not None
    kernels = [n for n, a in kv8_graph.jits.items() if a.kind == "kernel"]
    assert "qmatmul_w8a16" in kernels and "kv_attention_decode" in kernels


def test_donation_pins_every_pool_leaf(kv8_graph):
    # kv8 pool: k, k_scale, v, v_scale, v_err, lengths — all donated
    for name in ENGINE_JITS:
        art = kv8_graph.jits[name]
        assert len(art.module.alias) >= len(art.cache_leaves_local) == 6


def test_dispatch_shapes_closed_under_warmup(kv8_graph):
    assert set(kv8_graph.dispatch_shapes) <= set(kv8_graph.warmup_shapes)


def test_perturbed_contract_is_caught(kv8_graph):
    contract = copy.deepcopy(load_contract("serve-w8a8-kv8"))
    contract["warmup_shapes"] = contract["warmup_shapes"][:-1]
    contract["jits"]["decode"]["s8_converts"]["count"] += 1
    contract["known_debt"] = []          # un-pin the prefill cache dequants
    findings = _errors(run_rules(kv8_graph, contract))
    rules_fired = {f.rule for f in findings}
    assert "recompilation-guard" in rules_fired
    assert "dtype-ledger" in rules_fired


def test_w8a16_contract_has_no_debt():
    contract = load_contract("serve-w8a16")
    assert contract["known_debt"] == []


needs_8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@needs_8
def test_tp_contract_still_holds():
    graph = build_graph("serve-w8a16-tp", mesh_shape=(2, 4))
    contract = load_contract("serve-w8a16-tp.2x4")
    assert contract is not None
    findings = run_rules(graph, contract)
    assert _errors(findings) == [], [f.format() for f in _errors(findings)]
    # the PR-5 known-bad pooled take/.at[].set prefill gathers are pinned as
    # explicit debt — and the linter actually matched them (info findings)
    debt = [d for d in contract["known_debt"]
            if d["rule"] == "collective-budget"]
    assert debt and all("why" in d for d in debt)
    infos = [f for f in findings
             if f.rule == "collective-budget" and f.severity == "info"]
    assert infos, "pinned pool collectives should surface as info findings"
    # un-pinning the debt makes the same graph fail: removing the gather is
    # a ROADMAP win, silently re-growing it is a regression
    stripped = copy.deepcopy(contract)
    stripped["known_debt"] = [d for d in stripped["known_debt"]
                              if d["rule"] != "collective-budget"]
    errs = _errors(run_rules(graph, stripped))
    assert any(f.rule == "collective-budget" for f in errs)


def test_contract_roundtrip(tmp_path, monkeypatch, kv8_graph):
    from repro.analysis.lint import contracts as c

    monkeypatch.setattr(c, "CONTRACT_DIR", str(tmp_path))
    snap = snapshot(kv8_graph)
    c.save_contract("roundtrip", snap)
    assert c.load_contract("roundtrip") == snap
    assert c.load_contract("missing") is None
