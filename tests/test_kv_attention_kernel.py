"""int8-KV decode-attention kernel: bit-exact interpret-vs-ref property
sweeps (ragged lengths, GQA, non-multiple-of-blk S), accuracy vs an fp
cache, and the fused append-quantize decode op."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.kernels.kv_attention.ops import (
    append_quantize,
    kv_attention,
    kv_attention_decode,
    quantize_kv,
)
from repro.kernels.kv_attention.ref import kv_attention_ref, kv_attention_xla


def _inputs(B, S, Hkv, hd, seed=0, Hq=None, lengths=None):
    """Random fp K/V quantized per-token/per-head; positions at or past each
    row's ragged ``length`` get scale 0 (= masked, the op contract)."""
    Hq = Hq or Hkv
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    k_q, k_s = quantize_kv(k)
    v_q, v_s = quantize_kv(v)
    if lengths is not None:
        valid = jnp.arange(S)[None, :] < jnp.asarray(lengths)[:, None]
        k_s = jnp.where(valid[..., None], k_s, 0.0)
        v_s = jnp.where(valid[..., None], v_s, 0.0)
    return q, k, v, k_q, k_s, v_q, v_s


@pytest.mark.parametrize("B,S,H,hd", [
    (2, 256, 4, 64),
    (1, 1024, 8, 128),
    (4, 512, 2, 32),
])
def test_kernel_matches_ref(B, S, H, hd):
    q, k, v, k_q, k_s, v_q, v_s = _inputs(B, S, H, hd, seed=B + S)
    ref = kv_attention_ref(q, k_q, k_s, v_q, v_s, blk=min(256, S))
    out = kv_attention(q, k_q, k_s, v_q, v_s, blk=min(256, S),
                       backend="interpret")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_block_size_invariance():
    q, k, v, k_q, k_s, v_q, v_s = _inputs(2, 512, 4, 64, seed=7)
    outs = [np.asarray(kv_attention(q, k_q, k_s, v_q, v_s, blk=blk,
                                    backend="interpret"))
            for blk in (128, 256, 512)]
    for out in outs[1:]:
        np.testing.assert_allclose(out, outs[0], rtol=2e-5, atol=2e-5)


def _fp_oracle(q, k, v, lengths=None):
    """Plain masked softmax over the UNquantized cache — the accuracy
    anchor (GQA by explicit repeat)."""
    B, S, Hkv, hd = k.shape
    group = q.shape[1] // Hkv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q, k) / (hd ** 0.5)
    if lengths is not None:
        valid = jnp.arange(S)[None, :] < jnp.asarray(lengths)[:, None]
        s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhs,bshd->bhd", p, v)


def test_int8_noise_vs_fp_cache():
    """Quantized cache attention ≈ fp attention within int8 noise."""
    q, k, v, k_q, k_s, v_q, v_s = _inputs(2, 512, 4, 64, seed=9)
    fp = _fp_oracle(q, k, v)
    out = kv_attention(q, k_q, k_s, v_q, v_s, backend="interpret", blk=256)
    rel = float(jnp.linalg.norm(out - fp) / jnp.linalg.norm(fp))
    assert rel < 0.02


def test_gqa_matches_fp_oracle():
    """4 q heads over 1 kv head: the in-kernel reshape must agree with the
    explicit repeat-kv oracle (and the xla serving path with both)."""
    q, k, v, k_q, k_s, v_q, v_s = _inputs(2, 128, 1, 32, seed=11, Hq=4,
                                          lengths=[128, 40])
    fp = _fp_oracle(q, k, v, lengths=[128, 40])
    out = kv_attention(q, k_q, k_s, v_q, v_s, backend="interpret", blk=64)
    xla = kv_attention(q, k_q, k_s, v_q, v_s, backend="xla")
    rel = float(jnp.linalg.norm(out - fp) / jnp.linalg.norm(fp))
    assert rel < 0.02
    np.testing.assert_allclose(np.asarray(xla), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


def test_non_divisible_seq_padded():
    """S % blk != 0 no longer raises: the op pads with zero-scale (masked)
    positions and stays bit-exact with the ref."""
    q, k, v, k_q, k_s, v_q, v_s = _inputs(1, 300, 2, 32, seed=3)
    ref = kv_attention_ref(q, k_q, k_s, v_q, v_s, blk=256)
    out = kv_attention(q, k_q, k_s, v_q, v_s, blk=256, backend="interpret")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    fp = _fp_oracle(q, k, v)
    rel = float(jnp.linalg.norm(out - fp) / jnp.linalg.norm(fp))
    assert rel < 0.02


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 3),
    S=st.integers(1, 96),
    Hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    blk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2 ** 16),
    ragged=st.booleans(),
)
def test_property_interpret_bitexact_vs_ref(B, S, Hkv, group, blk, seed,
                                            ragged):
    """The acceptance pin: interpret backend == blocked ref BIT-exactly over
    ragged per-slot lengths, GQA ratios, and non-multiple-of-blk S
    (including rows with length 0 — fully masked)."""
    rng = np.random.RandomState(seed)
    lengths = rng.randint(0, S + 1, size=B).tolist() if ragged else None
    q, k, v, k_q, k_s, v_q, v_s = _inputs(B, S, Hkv, 16, seed=seed % 997,
                                          Hq=Hkv * group, lengths=lengths)
    ref = kv_attention_ref(q, k_q, k_s, v_q, v_s, blk=blk)
    out = kv_attention(q, k_q, k_s, v_q, v_s, blk=blk, backend="interpret")
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref),
        err_msg=f"B={B} S={S} Hkv={Hkv} G={group} blk={blk} lens={lengths}",
    )


# ------------------------------------------------- fused append-quantize

def test_fused_append_decode_matches_manual():
    """kv_attention_decode (quantize new token once → scatter → attend) ==
    quantizing/scattering by hand then attending; stale payload behind
    ``valid`` contributes nothing."""
    B, S, Hkv, Hq, hd = 2, 24, 2, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    k_fp = jax.random.normal(ks[0], (B, S, Hkv, hd))
    v_fp = jax.random.normal(ks[1], (B, S, Hkv, hd))
    ck, cks = quantize_kv(k_fp)
    cv, cvs = quantize_kv(v_fp)
    # garbage beyond position 10 — must be masked out by `valid`
    q = jax.random.normal(ks[2], (B, Hq, hd))
    k_new = jax.random.normal(ks[3], (B, 1, Hkv, hd))
    v_new = jax.random.normal(ks[0], (B, 1, Hkv, hd))
    idx = jnp.full((B, 1), 10, jnp.int32)
    valid = (jnp.arange(S) <= 10)[None, :].repeat(B, 0)

    out, leaves = kv_attention_decode(
        q, ck, cks, cv, cvs, k_new, v_new, idx, valid=valid,
        backend="interpret", blk=16)

    mk, mks, mv, mvs = append_quantize(ck, cks, cv, cvs, k_new, v_new, idx)
    ref = kv_attention(q, mk, jnp.where(valid[..., None], mks, 0.0),
                       mv, jnp.where(valid[..., None], mvs, 0.0),
                       backend="interpret", blk=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    for a, b in zip(leaves, (mk, mks, mv, mvs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the new token landed at idx, quantized exactly once
    kq10, ks10 = quantize_kv(k_new)
    np.testing.assert_array_equal(np.asarray(leaves[0][:, 10]),
                                  np.asarray(kq10[:, 0]))


def test_v_bias_correction_reduces_mean_error():
    """The optional V dequant-error correction (paper §4.2 on the KV stream)
    must remove the per-token mean component of the V quantization error."""
    B, S, Hkv, hd = 2, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (B, Hkv, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    # biased V: round-to-nearest error keeps a nonzero mean per token
    v = jax.random.normal(ks[2], (B, S, Hkv, hd)) + 0.8
    k_q, k_s = quantize_kv(k)
    v_q, v_s = quantize_kv(v)
    v_err = jnp.mean(v_q.astype(jnp.float32) * v_s[..., None] - v, axis=-1)

    fp = _fp_oracle(q, k, v)
    plain = kv_attention_xla(q, k_q, k_s, v_q, v_s)
    corrected = kv_attention_xla(q, k_q, k_s, v_q, v_s, v_err=v_err)
    err_plain = float(jnp.mean(jnp.abs(plain - fp)))
    err_corr = float(jnp.mean(jnp.abs(corrected - fp)))
    assert err_corr <= err_plain
    assert not np.allclose(np.asarray(plain), np.asarray(corrected))
