"""int8-KV decode-attention kernel: sweeps vs the jnp oracle + end-to-end
noise bound vs an fp cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kv_attention.ops import kv_attention
from repro.kernels.kv_attention.ref import kv_attention_ref


def _quantize_cache(x):
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _inputs(B, S, H, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    k_q, k_s = _quantize_cache(k)
    v_q, v_s = _quantize_cache(v)
    return q, k, v, k_q, k_s, v_q, v_s


@pytest.mark.parametrize("B,S,H,hd", [
    (2, 256, 4, 64),
    (1, 1024, 8, 128),
    (4, 512, 2, 32),
])
def test_kernel_matches_ref(B, S, H, hd):
    q, k, v, k_q, k_s, v_q, v_s = _inputs(B, S, H, hd, seed=B + S)
    ref = kv_attention_ref(q, k_q, k_s, v_q, v_s)
    out = kv_attention(q, k_q, k_s, v_q, v_s, blk=min(256, S),
                       backend="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_block_size_invariance():
    q, k, v, k_q, k_s, v_q, v_s = _inputs(2, 512, 4, 64, seed=7)
    ref = kv_attention_ref(q, k_q, k_s, v_q, v_s)
    for blk in (128, 256, 512):
        out = kv_attention(q, k_q, k_s, v_q, v_s, blk=blk, backend="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_int8_noise_vs_fp_cache():
    """Quantized cache attention ≈ fp attention within int8 noise."""
    q, k, v, k_q, k_s, v_q, v_s = _inputs(2, 512, 4, 64, seed=9)
    scale = 1.0 / (64 ** 0.5)
    s = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    p = jax.nn.softmax(s, -1)
    fp = jnp.einsum("bhs,bshd->bhd", p, v)
    out = kv_attention(q, k_q, k_s, v_q, v_s, backend="interpret", blk=256)
    rel = float(jnp.linalg.norm(out - fp) / jnp.linalg.norm(fp))
    assert rel < 0.02


def test_non_divisible_seq_rejected():
    q, k, v, k_q, k_s, v_q, v_s = _inputs(1, 300, 2, 32, seed=3)
    with pytest.raises(ValueError):
        kv_attention(q, k_q, k_s, v_q, v_s, blk=256, backend="interpret")
