"""kernels/dispatch.py registry: resolution order (explicit > env >
default), the REPRO_KERNEL_BACKEND override, unknown-op/-backend errors,
the one-pad-convention-per-op rule, and the registry-driven
``serving_kernel_specs`` enumeration."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, serving_kernel_specs
from repro.kernels.dispatch import (
    ENV_VAR,
    TIERS,
    _pad_to,
    register_impl,
    register_spec,
    resolve,
)


@pytest.fixture(autouse=True)
def _pristine_registry():
    """The registry is module-global state shared with the lint snapshot
    tests — scrub every dummy ``_t_*`` registration on the way out."""
    yield
    for d in (dispatch._REGISTRY, dispatch._PAD, dispatch._SPECS):
        for op in [op for op in d if op.startswith("_t_")]:
            del d[op]


def _register_dummy(op, tiers=TIERS, pad=None):
    impls = {}
    for t in tiers:
        @register_impl(op, t, pad=pad)
        def impl(*a, _t=t, **kw):
            return _t
        impls[t] = impl
    return impls


# ------------------------------------------------------------ resolution

def test_explicit_backend_wins_over_env(monkeypatch):
    _register_dummy("_t_explicit")
    monkeypatch.setenv(ENV_VAR, "ref")
    assert resolve("_t_explicit", "xla")() == "xla"


def test_env_override_wins_over_default(monkeypatch):
    _register_dummy("_t_env")
    monkeypatch.setenv(ENV_VAR, "ref")
    assert resolve("_t_env")() == "ref"
    monkeypatch.delenv(ENV_VAR)
    # no env, no explicit: the validation default (interpret on CPU)
    assert resolve("_t_env")() == dispatch.default_backend()


def test_serving_backend_honors_env(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    # CPU production default is the folded-scale XLA tier
    assert dispatch.serving_backend() == "xla"
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert dispatch.serving_backend() == "interpret"


# ----------------------------------------------------------------- errors

def test_unknown_op_raises_with_registered_list():
    with pytest.raises(KeyError, match="unknown kernel op"):
        resolve("_t_nonexistent_op")


def test_unknown_backend_tier_rejected_at_registration():
    with pytest.raises(ValueError, match="unknown backend tier"):
        register_impl("_t_bad_tier", "cuda")


def test_missing_tier_raises_naming_available():
    _register_dummy("_t_partial", tiers=("xla", "ref"))
    with pytest.raises(ValueError, match="no 'pallas' implementation"):
        resolve("_t_partial", "pallas")


def test_shadowing_refused():
    _register_dummy("_t_shadow", tiers=("xla",))
    with pytest.raises(ValueError, match="refusing to shadow"):
        @register_impl("_t_shadow", "xla")
        def other(*a, **kw):
            return None


# ------------------------------------------------------- pad conventions

def test_pad_convention_conflict_raises():
    _register_dummy("_t_pad", tiers=("xla",), pad="zero")
    with pytest.raises(ValueError, match="disagree on the pad convention"):
        @register_impl("_t_pad", "ref", pad="zero-scale")
        def other(*a, **kw):
            return None


def test_unknown_pad_convention_rejected():
    with pytest.raises(ValueError, match="unknown pad convention"):
        register_impl("_t_pad2", "xla", pad="nan")


def test_pad_to_is_right_zero_padding():
    x = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    y = _pad_to(x, 4, axis=1)
    assert y.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(y[:, :3]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(y[:, 3]), 0.0)
    assert _pad_to(x, 3, axis=1) is x          # already aligned: no copy


def test_real_ops_declare_their_conventions():
    serving_kernel_specs()                      # imports every op package
    assert dispatch.pad_convention("qmatmul_w8a8") == "zero"
    assert dispatch.pad_convention("qmatmul_w8a16") == "zero"
    assert dispatch.pad_convention("kv_attention") == "zero-scale"
    assert dispatch.pad_convention("fused_decode") == "zero-scale"


# ------------------------------------------------------------- enumeration

def test_serving_specs_enumerate_registry():
    specs = serving_kernel_specs()
    for op in ("qmatmul_w8a8", "qmatmul_w8a16", "quantize_act",
               "kv_attention_decode", "fused_decode"):
        assert op in specs, f"registry lost {op}"
        fn, args, kw = specs[op]
        assert callable(fn) and isinstance(args, tuple)


def test_register_spec_refuses_duplicates():
    @register_spec("_t_spec")
    def build(**kw):
        return (lambda: None, (), {})

    with pytest.raises(ValueError, match="already has a spec"):
        @register_spec("_t_spec")
        def build2(**kw):
            return (lambda: None, (), {})


def test_no_per_package_backend_selector_copies():
    """The redesign's point: dispatch.py is the ONLY place the backend
    ternary lives — no kernels/*/ops.py re-grows its own copy."""
    import pathlib

    import repro.kernels as K

    root = pathlib.Path(K.__file__).parent
    for ops_py in root.glob("*/ops.py"):
        text = ops_py.read_text()
        assert "def default_backend" not in text, f"{ops_py} regrew a selector"
        assert "def serving_backend" not in text, f"{ops_py} regrew a selector"
        assert "def _pad_to" not in text, f"{ops_py} regrew _pad_to"
