"""Chaos-harness tests: FaultPlan determinism, empty-plan equivalence,
seeded mixed-fault parity, a property test of pool bookkeeping under random
operation sequences, and the (slow-marked) chaos soak.

``CachePool.check_invariants`` is the oracle everywhere: refcount
conservation (every live page's count equals its slot mappings plus external
pins), free-heap consistency (free pages exactly once on the heap, never
mapped), and the slot partition. The property test drives random
allocate / COW-write / release / pin / unpin / reserve sequences against it;
the soak drives a real engine through hundreds of requests under a mixed
FaultPlan and requires zero leaked pages plus bit-identical unfaulted
tokens.
"""
import dataclasses

import jax
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    FaultPlan,
    PoolExhausted,
    ServingEngine,
    run_chaos,
    synthetic_trace,
)
from repro.serving.cache_pool import CachePool
from repro.serving.chaos import assert_unfaulted_parity

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def fp32_setup():
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _engine(model, params, cfg, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_len", 48)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_horizon", 4)
    kw.setdefault("page_size", 8)
    return ServingEngine(model, params, cfg, **kw)


def _trace(cfg, seed=0, n=10):
    return synthetic_trace(seed, n, vocab_size=cfg.vocab_size,
                           prompt_lens=(4, 16), gen_lens=(4, 16),
                           mean_interarrival=1.0, priority_levels=2)


# ------------------------------------------------------------------ FaultPlan

def test_fault_plan_seeded_is_deterministic_and_disjoint():
    rids = list(range(20))
    a = FaultPlan.seeded(7, rids, n_steps=50)
    b = FaultPlan.seeded(7, rids, n_steps=50)
    assert (a.exhaust, a.cancels, a.nans) == (b.exhaust, b.cancels, b.nans)
    c = FaultPlan.seeded(8, rids, n_steps=50)
    assert (a.exhaust, a.cancels, a.nans) != (c.exhaust, c.cancels, c.nans)
    cancel_rids = {r for _, r in a.cancels}
    nan_rids = {r for _, r in a.nans}
    assert not (cancel_rids & nan_rids), "fault victims must be disjoint"
    assert a.faulted_rids() == cancel_rids | nan_rids


def test_empty_plan_matches_fault_free_run(fp32_setup):
    """run_chaos with no faults is just a supervised run: every request ok,
    bit-identical, zero leaked pages, invariants green every step."""
    model, params, cfg = fp32_setup
    trace = _trace(cfg)
    clean = _engine(model, params, cfg).run(
        [dataclasses.replace(r) for r in trace])
    eng = _engine(model, params, cfg)
    report = run_chaos(eng, [dataclasses.replace(r) for r in trace],
                       FaultPlan())
    compared = assert_unfaulted_parity(report, clean, set())
    assert compared == len(trace)
    assert report.leaked_pages == 0 and not report.shed_rids
    assert report.counts["ok"] == len(trace)


def test_seeded_chaos_preserves_unfaulted_requests(fp32_setup):
    """The core chaos invariant on a starved pool: pool-exhaustion holds,
    cancels, and NaN injections must not perturb any unfaulted request."""
    model, params, cfg = fp32_setup
    trace = _trace(cfg, seed=3, n=12)
    clean = _engine(model, params, cfg).run(
        [dataclasses.replace(r) for r in trace])
    eng = _engine(model, params, cfg, num_pages=12)  # 2 slots' worth for 4
    plan = FaultPlan.seeded(3, [r.rid for r in trace], n_steps=30)
    report = run_chaos(eng, [dataclasses.replace(r) for r in trace], plan)
    compared = assert_unfaulted_parity(report, clean, plan.faulted_rids())
    assert compared >= len(trace) - len(plan.faulted_rids()) - \
        len(report.shed_rids)
    assert report.leaked_pages == 0
    faulted_statuses = {report.outcomes.get(r) for r in plan.faulted_rids()}
    assert faulted_statuses <= {"ok", "cancelled", "quarantined", "shed"}


def test_burst_and_exhaustion_faults(fp32_setup):
    """Bursts submitted mid-run and reservation windows must drain cleanly;
    burst requests count toward parity too (they're unfaulted)."""
    model, params, cfg = fp32_setup
    trace = _trace(cfg, seed=5, n=6)
    burst = [dataclasses.replace(r, rid=100 + r.rid)
             for r in _trace(cfg, seed=6, n=4)]
    eng = _engine(model, params, cfg, num_pages=12)
    plan = FaultPlan(exhaust=[(2, 6, 5)], bursts=[(4, burst)])
    report = run_chaos(eng, [dataclasses.replace(r) for r in trace], plan)
    assert report.leaked_pages == 0
    served = {r for r, s in report.outcomes.items() if s == "ok"}
    assert {r.rid for r in trace} <= served
    assert {r.rid for r in burst} <= served | set(report.shed_rids)


# ------------------------------------------------- pool bookkeeping property

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_pool_invariants_under_random_op_sequences(seed):
    """Drive a small paged pool through a random mix of allocate / COW /
    pin / unpin / reserve / release and assert full bookkeeping invariants
    after EVERY operation."""
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    pool = CachePool(model, num_slots=3, max_len=32, page_size=8,
                     num_pages=8)
    rng = np.random.RandomState(seed)
    slots: dict[int, int] = {}     # slot -> committed length
    pins: list[int] = []           # external (index-style) refs we hold
    reserved: list[list] = []

    def check():
        ext: dict[int, int] = {}
        for p in pins:
            ext[p] = ext.get(p, 0) + 1
        pool.check_invariants(external_refs=ext)

    for _ in range(60):
        op = rng.randint(0, 6)
        if op == 0 and len(slots) < 3:                   # allocate
            need = int(rng.randint(1, 33))
            shared, reuse = [], 0
            if pins and rng.rand() < 0.5:
                shared, reuse = [pins[0]], 8
                need = max(need, reuse + 1)
            if need > 32:
                need = 32
            try:
                s = pool.allocate_pages(need=need, shared=shared,
                                        reuse_len=reuse)
                slots[s] = need
            except PoolExhausted:
                pass
        elif op == 1 and slots:                          # COW write
            s = list(slots)[rng.randint(len(slots))]
            start = int(rng.randint(0, slots[s]))
            try:
                pool.ensure_writable(s, start, min(slots[s], start + 8))
            except PoolExhausted:
                pass                 # no free page for the copy — atomic no-op
        elif op == 2 and slots:                          # release slot
            s = list(slots)[rng.randint(len(slots))]
            pool.release(s)
            del slots[s]
        elif op == 3 and slots:                          # pin a live page
            s = list(slots)[rng.randint(len(slots))]
            pages = pool.slot_pages(s)
            p = pages[rng.randint(len(pages))]
            pool.ref_page(p)
            pins.append(p)
        elif op == 4 and pins:                           # unpin
            pool.deref_page(pins.pop(rng.randint(len(pins))))
        elif op == 5:                                    # reserve / return
            if reserved and rng.rand() < 0.5:
                pool.release_reserved(reserved.pop())
            else:
                got = pool.reserve_pages(int(rng.randint(1, 4)))
                if got:
                    reserved.append(got)
        check()

    for s in list(slots):
        pool.release(s)
    for p in pins:
        pool.deref_page(p)
    for pages in reserved:
        pool.release_reserved(pages)
    pool.check_invariants()
    assert pool.n_free_pages == pool.num_pages


def test_check_invariants_catches_corruption(fp32_setup):
    """The oracle itself must trip on planted corruption — otherwise the
    whole harness is vacuous."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=2, max_len=32, page_size=8)
    s = pool.allocate_pages(need=9)
    page = pool.slot_page(s, 0)
    pool._page_ref[page] += 1           # phantom ref nobody holds
    with pytest.raises(AssertionError):
        pool.check_invariants()
    pool._page_ref[page] -= 1
    pool.check_invariants()


# ------------------------------------------------------------------ the soak

@pytest.mark.slow
def test_chaos_soak(fp32_setup):
    """N >= 200 requests through a starved pool under a seeded mixed
    FaultPlan: invariants after every step, zero leaked pages at drain,
    every unfaulted request bit-identical to the fault-free run."""
    model, params, cfg = fp32_setup
    trace = synthetic_trace(11, 200, vocab_size=cfg.vocab_size,
                            prompt_lens=(4, 16), gen_lens=(4, 12),
                            mean_interarrival=0.5, priority_levels=3)
    clean = _engine(model, params, cfg).run(
        [dataclasses.replace(r) for r in trace])
    eng = _engine(model, params, cfg, num_pages=12)
    plan = FaultPlan.seeded(11, [r.rid for r in trace], n_steps=250,
                            n_exhaust=4, n_cancels=5, n_nans=5)
    report = run_chaos(eng, [dataclasses.replace(r) for r in trace], plan)
    compared = assert_unfaulted_parity(report, clean, plan.faulted_rids())
    assert compared >= 185
    assert report.leaked_pages == 0
    assert report.counts["preempted"] == report.counts["resumed"]
