"""int8 KV cache (beyond-paper: the paper's quantizer applied to the decode
memory wall): decode parity vs fp cache, ring-buffer behaviour, bytes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b"])
def test_int8_cache_decode_parity(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True), kv_cache_bits=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    full, _ = model.apply(params, toks)
    cache = model.init_cache(2, 24, dtype=jnp.float32)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    lp, cache = model.prefill(params, toks[:, :-1], cache)
    ld, cache = model.decode_step(params, toks[:, -1:], cache)
    denom = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9
    assert float(jnp.max(jnp.abs(ld - full[:, -1]))) / denom < 0.08


def test_int8_cache_bias_correct_decode_parity():
    """kv_bias_correct=True adds the v_err leaf and stays within the int8
    noise bound (the correction only removes the V error's mean component,
    it must never blow up the logits)."""
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True),
                              kv_cache_bits=8, kv_bias_correct=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    full, _ = model.apply(params, toks)
    cache = model.init_cache(2, 24, dtype=jnp.float32)
    assert "v_err" in cache
    _, cache = model.prefill(params, toks[:, :-1], cache)
    ld, cache = model.decode_step(params, toks[:, -1:], cache)
    denom = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9
    assert float(jnp.max(jnp.abs(ld - full[:, -1]))) / denom < 0.08


def test_int8_cache_halves_bytes():
    cfg8 = dataclasses.replace(get_config("yi-34b", smoke=True), kv_cache_bits=8)
    cfg16 = get_config("yi-34b", smoke=True)
    m8, m16 = build_model(cfg8), build_model(cfg16)
    c8 = jax.eval_shape(lambda: m8.init_cache(4, 128, jnp.bfloat16))
    c16 = jax.eval_shape(lambda: m16.init_cache(4, 128, jnp.bfloat16))

    def nbytes(tree, keys):
        return sum(np.prod(v.shape) * v.dtype.itemsize
                   for k, v in tree.items() if k in keys)

    b8 = nbytes(c8, ("k", "v", "k_scale", "v_scale"))
    b16 = nbytes(c16, ("k", "v"))
    assert b8 < 0.65 * b16  # payload halves; scales add hd/4 ≈ 25 % of that


def test_int8_cache_ring_buffer_swa():
    cfg = dataclasses.replace(get_config("mixtral-8x22b", smoke=True),
                              kv_cache_bits=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    T = 24  # > smoke window (16)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, T), 0, cfg.vocab_size)
    full, _ = model.apply(params, toks)
    cache = model.init_cache(1, T, dtype=jnp.float32)
    logits = None
    for t in range(T):
        logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
    denom = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9
    assert float(jnp.max(jnp.abs(logits - full[:, -1]))) / denom < 0.08
