"""Scheduler + cache-pool unit and property tests (no model forward).

The property tests drive FIFOScheduler + CachePool through randomized
admit/complete interleavings (hypothesis via tests/_hyp.py — exact-stub
fallback keeps them green without the dependency) and pin the engine's
bookkeeping invariants: FIFO admission order, no slot double-allocation,
every admitted request completes, pool fully free after drain.
"""
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st
from repro.serving import CachePool, FIFOScheduler, PoolExhausted, Request
from repro.serving.scheduler import Request as SchedRequest


class _StubModel:
    """Just enough of LMModel for CachePool: the per-slot bookkeeping."""

    def init_cache(self, batch, seq_len, dtype=None, per_slot=False):
        assert per_slot
        return {
            "k": jnp.zeros((1, batch, seq_len, 1, 1)),
            "kpos": jnp.full((batch, seq_len), -1, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }


def _pool(n=4, s=8):
    return CachePool(_StubModel(), n, s)


# ------------------------------------------------------------------ requests

def test_request_validation():
    with pytest.raises(ValueError, match="empty prompt"):
        Request(rid=0, prompt=[], max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(rid=0, prompt=[1], max_new_tokens=0)


# ----------------------------------------------------------------- scheduler

def test_fifo_pop_order():
    sched = FIFOScheduler()
    for i in range(5):
        sched.submit(Request(rid=i, prompt=[1], max_new_tokens=1))
    popped = [sched.pop_ready(now=0.0).rid for _ in range(5)]
    assert popped == [0, 1, 2, 3, 4]
    assert sched.pop_ready(now=0.0) is None
    assert list(sched.admitted_order) == [0, 1, 2, 3, 4]


def test_fifo_head_of_line_arrival_gating():
    """A not-yet-arrived head blocks everything behind it (strict FIFO)."""
    sched = FIFOScheduler()
    sched.submit(Request(rid=0, prompt=[1], max_new_tokens=1, arrival=5.0))
    sched.submit(Request(rid=1, prompt=[1], max_new_tokens=1, arrival=0.0))
    assert sched.pop_ready(now=0.0) is None
    assert sched.peek_arrival() == 5.0
    assert sched.pop_ready(now=5.0).rid == 0
    assert sched.pop_ready(now=5.0).rid == 1


# ---------------------------------------------------------------- cache pool

def test_pool_allocates_lowest_free_slot():
    pool = _pool(n=3)
    assert [pool.allocate() for _ in range(3)] == [0, 1, 2]
    pool.release(1)
    pool.release(0)
    assert pool.allocate() == 0  # lowest free, not LIFO
    assert pool.n_free == 1 and pool.n_allocated == 2


def test_pool_exhaustion_and_double_free():
    pool = _pool(n=2)
    pool.allocate(), pool.allocate()
    with pytest.raises(PoolExhausted):
        pool.allocate()
    pool.release(0)
    with pytest.raises(ValueError, match="not allocated"):
        pool.release(0)
    with pytest.raises(ValueError, match="not allocated"):
        pool.release(1 + 1)  # never claimed


def test_pool_reset_clears_only_the_claimed_slot():
    pool = _pool(n=3, s=4)
    # dirty every slot's bookkeeping
    pool.cache = {
        **pool.cache,
        "kpos": jnp.full((3, 4), 7, jnp.int32),
        "pos": jnp.full((3,), 9, jnp.int32),
    }
    slot = pool.allocate()
    assert slot == 0
    assert pool.cache["kpos"][0].tolist() == [-1] * 4
    assert int(pool.cache["pos"][0]) == 0
    assert pool.cache["kpos"][1].tolist() == [7] * 4  # untouched
    assert int(pool.cache["pos"][2]) == 9


# ----------------------------------------------------- property: full drain

@settings(max_examples=25, deadline=None)
@given(
    num_slots=st.integers(1, 6),
    n_requests=st.integers(0, 30),
    seed=st.integers(0, 2**16),
)
def test_admit_complete_drain_invariants(num_slots, n_requests, seed):
    """Randomized admit/complete interleaving of a FIFO queue over a pool:
    admission order == submission order, slots never double-allocated,
    every request completes, and the pool returns to fully-free."""
    import random

    rng = random.Random(seed)
    sched = FIFOScheduler()
    pool = _pool(n=num_slots)
    for i in range(n_requests):
        sched.submit(SchedRequest(
            rid=i, prompt=[1] * rng.randint(1, 5),
            max_new_tokens=rng.randint(1, 6),
            arrival=float(rng.randint(0, 10)),
        ))

    inflight = {}   # slot -> [rid, remaining_steps]
    completed = []
    now, max_ticks = 0.0, 10_000
    while (sched.pending() or inflight) and max_ticks:
        max_ticks -= 1
        while pool.n_free:
            req = sched.pop_ready(now)
            if req is None:
                break
            slot = pool.allocate()
            assert slot not in inflight, "slot double-allocated"
            assert pool.cache["kpos"][slot].tolist() == [-1] * pool.max_len
            inflight[slot] = [req.rid, req.max_new_tokens]
        assert pool.n_allocated == len(inflight) <= num_slots
        # advance a random subset (at least one) of in-flight requests
        for slot in sorted(inflight):
            if inflight and rng.random() < 0.7:
                inflight[slot][1] -= 1
        for slot in [s for s, (_, rem) in inflight.items() if rem <= 0]:
            completed.append(inflight.pop(slot)[0])
            pool.release(slot)
        now += 1.0

    assert max_ticks > 0, "simulation did not drain"
    assert sorted(completed) == list(range(n_requests))
    assert list(sched.admitted_order) == list(range(n_requests))  # strict FIFO
    assert pool.all_free()
