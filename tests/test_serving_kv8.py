"""int8-KV serving: the engine over the int8 pooled cache (kv_bits=8).

Pins: kv8 fast path == kv8 stepwise reference bit-for-bit (tokens AND
timeline), batch invariance under the int8 cache, quantize→save→load→serve
round trip with the serve-w8a16-kv8 recipe, kv8-vs-fp greedy agreement and
logits SQNR, the pool's bytes/slot accounting, and the CachePool dtype
default (model activation dtype)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs import get_config
from repro.models import build_model
from repro.serving import CachePool, Request, ServingEngine, synthetic_trace

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def fp32_setup():
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _mixed_trace(vocab):
    rng = np.random.RandomState(7)
    lens = [(5, 6), (12, 3), (3, 1), (9, 8)]  # includes a gen-at-prefill edge
    return [
        Request(rid=i, prompt=rng.randint(0, vocab, size=p).astype(np.int32),
                max_new_tokens=g)
        for i, (p, g) in enumerate(lens)
    ]


def _engine(model, params, cfg, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("kv_bits", 8)
    return ServingEngine(model, params, cfg, **kw)


# ------------------------------------------------------------------- parity

def test_kv8_fused_vs_stepwise_parity(fp32_setup):
    """The acceptance pin: kv8 fast-path tokens == kv8 stepwise tokens
    bit-exact, plus the admit/finish timeline, at several horizons."""
    model, params, cfg = fp32_setup
    trace = _mixed_trace(cfg.vocab_size)
    slow_eng = _engine(model, params, cfg, fast=False)
    assert slow_eng.pool.cache["k"].dtype == jnp.int8
    slow = slow_eng.run([dataclasses.replace(r) for r in trace])
    for horizon in (1, 3, 8):
        fast_eng = _engine(model, params, cfg, fast=True,
                           decode_horizon=horizon)
        fast = fast_eng.run([dataclasses.replace(r) for r in trace])
        for r in trace:
            assert fast[r.rid].tokens == slow[r.rid].tokens, (
                f"kv8: rid {r.rid} diverged at horizon {horizon}")
            assert fast[r.rid].admitted_at == slow[r.rid].admitted_at
            assert fast[r.rid].finished_at == slow[r.rid].finished_at
        assert fast_eng.pool.all_free()


def test_kv8_batch_invariance(fp32_setup):
    """Solo-decoded == mixed-batch tokens under the int8 cache: zero-scale
    masking makes recycled-slot stale payload exactly invisible."""
    model, params, cfg = fp32_setup
    trace = _mixed_trace(cfg.vocab_size)
    mixed = _engine(model, params, cfg).run(trace)
    solo_engine = _engine(model, params, cfg)
    for r in trace:
        solo = solo_engine.run([dataclasses.replace(r)])
        assert solo[r.rid].tokens == mixed[r.rid].tokens
        assert solo_engine.pool.all_free()


# ----------------------------------------------------------- kv8 vs fp model

def test_kv8_vs_fp_greedy_agreement_and_sqnr(fp32_setup):
    """Teacher-forced logits through a kv8 cache stay close to the fp cache:
    SQNR above threshold and greedy argmax agreement high. (Measured ~41 dB
    / 0.96 on this smoke config — thresholds leave margin.)"""
    model, params, cfg = fp32_setup
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 20), 0,
                              cfg.vocab_size)

    def roll(kv_bits):
        cache = model.init_cache(2, 24, dtype=jnp.float32, kv_bits=kv_bits)
        lg, cache = model.prefill(params, toks[:, :8], cache)
        outs = [lg]
        for t in range(8, 20):
            lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
            outs.append(lg)
        return jnp.stack(outs)

    lf, l8 = roll(16), roll(8)
    sqnr = 10 * np.log10(float(jnp.sum(lf ** 2) / jnp.sum((lf - l8) ** 2)))
    agree = float(jnp.mean(jnp.argmax(lf, -1) == jnp.argmax(l8, -1)))
    assert sqnr > 25.0, f"kv8 logits SQNR {sqnr:.1f} dB"
    assert agree >= 0.8, f"kv8 greedy agreement {agree:.2f}"


def test_kv8_vs_fp_first_token_agreement(fp32_setup):
    """Engine-level: the first generated token is a pure function of the
    prompt (no divergence cascade), so fp and kv8 engines must agree on
    nearly all of them over a mixed trace."""
    model, params, cfg = fp32_setup
    trace = synthetic_trace(3, 12, vocab_size=cfg.vocab_size,
                            prompt_lens=(2, 12), gen_lens=(1, 6),
                            mean_interarrival=0.3)
    fp = _engine(model, params, cfg, num_slots=4, kv_bits=None).run(
        [dataclasses.replace(r) for r in trace])
    k8 = _engine(model, params, cfg, num_slots=4).run(
        [dataclasses.replace(r) for r in trace])
    agree = sum(fp[r.rid].tokens[0] == k8[r.rid].tokens[0] for r in trace)
    assert agree >= 0.9 * len(trace), f"{agree}/{len(trace)} first tokens"


# ------------------------------------------------------- recipe round trip

def test_kv8_recipe_save_load_serve_round_trip(tmp_path):
    """quantize(serve-w8a16-kv8) → save → load → serve: the artifact records
    KV precision, the engine picks it up without flags, and tokens match the
    in-memory artifact bit-for-bit."""
    from repro.pipeline import QuantizedModel

    qm = repro.quantize(f"{ARCH}-smoke", recipe="serve-w8a16-kv8")
    assert qm.cfg.kv_cache_bits == 8
    trace = _mixed_trace(qm.cfg.vocab_size)
    eng = ServingEngine.from_quantized(qm, num_slots=2, max_len=32,
                                       prefill_chunk=8)
    assert eng.kv_bits == 8 and eng.pool.cache["k"].dtype == jnp.int8
    mem = eng.run(trace)

    qm.save(str(tmp_path / "artifact"))
    qm2 = QuantizedModel.load(str(tmp_path / "artifact"))
    assert qm2.cfg.kv_cache_bits == 8
    disk = ServingEngine.from_quantized(
        qm2, num_slots=2, max_len=32, prefill_chunk=8).run(
        _mixed_trace(qm.cfg.vocab_size))
    assert {r: v.tokens for r, v in mem.items()} == \
           {r: v.tokens for r, v in disk.items()}


# ----------------------------------------------------------- pool accounting

def test_cache_pool_dtype_defaults_to_model_dtype():
    """fp pools default to the model's activation dtype (bf16 halves cache
    bytes vs the old fp32 default); an explicit override still wins."""
    cfg = dataclasses.replace(get_config(ARCH, smoke=True), dtype="bfloat16")
    model = build_model(cfg)
    pool = CachePool(model, 2, 16)
    assert pool.cache["k"].dtype == jnp.bfloat16
    pool32 = CachePool(model, 2, 16, dtype=jnp.float32)
    assert pool32.cache["k"].dtype == jnp.float32


def test_kv8_pool_bytes_per_slot(fp32_setup):
    """int8 payload + per-token/per-head scales: bytes/slot ratio vs fp32 is
    4*hd/(hd+4) — 3.2x at the smoke head_dim of 16, 3.56x at hd=32."""
    model, params, cfg = fp32_setup
    fp = CachePool(model, 2, 16)                 # smoke dtype is float32
    k8 = CachePool(model, 2, 16, kv_bits=8)
    hd = cfg.head_dim
    assert fp.bytes_per_slot() / k8.bytes_per_slot() == pytest.approx(
        4 * hd / (hd + 4))


# -------------------------------------------------------------------- soak

@pytest.mark.slow
def test_kv8_soak_randomized_arrivals(fp32_setup):
    """N=200 randomized arrivals served through the int8 pooled cache:
    exact budgets, FIFO order, pool drains, and the first generated token
    agrees with the fp engine on >= 90% of requests."""
    model, params, cfg = fp32_setup
    trace = synthetic_trace(
        42, 200, vocab_size=cfg.vocab_size,
        prompt_lens=(2, 12), gen_lens=(1, 8), mean_interarrival=0.3,
    )
    eng = ServingEngine(model, params, cfg, num_slots=8, max_len=32,
                        prefill_chunk=8, kv_bits=8)
    res = eng.run([dataclasses.replace(r) for r in trace])
    assert sorted(res) == list(range(200))
    for r in trace:
        assert len(res[r.rid].tokens) == r.max_new_tokens
    assert eng.pool.all_free()

    fp_eng = ServingEngine(model, params, cfg, num_slots=8, max_len=32,
                           prefill_chunk=8)
    fp = fp_eng.run([dataclasses.replace(r) for r in trace])
    agree = sum(fp[r.rid].tokens[0] == res[r.rid].tokens[0] for r in trace)
    assert agree >= 0.9 * len(trace), f"{agree}/200 first tokens agree"