"""Partition-planner unit tests: serve-mode specs for quantized leaves.

Pins the sharding contract the TP serving path relies on:

  * a column-parallel int8 weight and its per-channel scale land on the SAME
    "model" axis (a TP shard dequantizes its own columns locally),
  * row-parallel weights shard their IN dim, so their scales replicate,
  * non-divisible dims replicate (graceful degradation),
  * kv8 cache scale / ``v_err`` leaves follow their payload tensor (same
    slot axis over "data", same head axis over "model").

Spec computation only reads ``mesh.shape``, so these run on a single device
(tier1) with a stub mesh; the multi-device CI job exercises the same specs
against a real mesh end-to-end in test_serving_sharded.py.
"""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_production_mesh
from repro.quantized.qtensor import QTensor
from repro.sharding import params_pspecs, serve_cache_pspecs
from repro.sharding.partition import spec_paths


class _StubMesh:
    """Just enough mesh for the planner: spec rules only read .shape."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = _StubMesh(data=2, model=4)
HEADS = {"n_q": 8, "n_kv": 2}


def _sds(*shape, dtype="float32"):
    return jax.ShapeDtypeStruct(shape, jax.numpy.dtype(dtype))


def _qt(k, n, *, per_channel=True, L=2):
    """Stacked [L, K, N] int8 QTensor shapes with [L, N] or [L, 1] scales."""
    return QTensor(
        _sds(L, k, n, dtype="int8"),
        _sds(L, n if per_channel else 1),
        "w8a16",
    )


def _specs(params):
    return params_pspecs(params, MESH, HEADS, mode="serve")


# ------------------------------------------------------- quantized weights

def test_column_parallel_weight_and_scale_co_shard():
    """wu [L, D, F]: out dim on "model" — and the per-channel scale's channel
    dim must land on the SAME axis."""
    spec = _specs({"blocks": {"mlp": {"wu": _qt(256, 512)}}})
    wu = spec["blocks"]["mlp"]["wu"]
    assert wu.q == P(None, None, "model")
    assert wu.scale == P(None, "model")


def test_row_parallel_weight_shards_in_dim_scale_replicates():
    """wd [L, F, D]: IN dim on "model" (row-parallel partial sums); the scale
    mirrors the OUT dim, which is unsharded — it must replicate."""
    spec = _specs({"blocks": {"mlp": {"wd": _qt(512, 256)}}})
    wd = spec["blocks"]["mlp"]["wd"]
    assert wd.q == P(None, "model", None)
    assert wd.scale == P(None, None)


def test_per_tensor_scale_replicates():
    """[L, 1] per-tensor scales are never divisible — replicate."""
    spec = _specs({"blocks": {"mlp": {"wu": _qt(256, 512, per_channel=False)}}})
    assert spec["blocks"]["mlp"]["wu"].q == P(None, None, "model")
    assert spec["blocks"]["mlp"]["wu"].scale == P(None, None)


def test_non_divisible_out_dim_replicates_weight_and_scale():
    """d_ff=100 doesn't divide model=4 (and is < MIN_SHARD_DIM): both the
    int8 payload and its scale replicate — no orphaned-scale mismatch."""
    spec = _specs({"blocks": {"mlp": {"wu": _qt(256, 100)}}})
    assert spec["blocks"]["mlp"]["wu"].q == P(None, None, None)
    assert spec["blocks"]["mlp"]["wu"].scale == P(None, None)


def test_attention_scale_respects_head_divisibility():
    """wq shards only when n_q divides model; wk/wv key off n_kv (2 % 4 != 0
    here) — their scale must follow the payload into replication."""
    spec = _specs({"blocks": {"attn": {
        "wq": _qt(256, 256), "wk": _qt(256, 256), "wv": _qt(256, 256),
    }}})
    attn = spec["blocks"]["attn"]
    assert attn["wq"].q == P(None, None, "model")      # n_q=8 % 4 == 0
    assert attn["wq"].scale == P(None, "model")
    for name in ("wk", "wv"):                          # n_kv=2 % 4 != 0
        assert attn[name].q == P(None, None, None)
        assert attn[name].scale == P(None, None)


def test_serve_mode_drops_fsdp_factor():
    """Serving weights stay resident: no "data" factor anywhere (train mode
    would shard the in dim over "data")."""
    params = {"blocks": {"mlp": {"wu": _sds(2, 256, 512)}}}
    train = params_pspecs(params, MESH, HEADS, mode="train")
    serve = params_pspecs(params, MESH, HEADS, mode="serve")
    assert train["blocks"]["mlp"]["wu"] == P(None, "data", "model")
    assert serve["blocks"]["mlp"]["wu"] == P(None, None, "model")


def test_train_mode_scale_still_replicates():
    """The co-sharding rule is serve-only; train/decode keep scales tiny and
    replicated (the pre-existing contract)."""
    spec = params_pspecs(
        {"blocks": {"mlp": {"wu": _qt(256, 512)}}}, MESH, HEADS, mode="train"
    )
    assert spec["blocks"]["mlp"]["wu"].scale == P()


# ------------------------------------------------------------ serving cache

def _kv8_cache(B, *, L=2, S=32, H=2, hd=16, v_err=True):
    c = {
        "k": _sds(L, B, S, H, hd, dtype="int8"),
        "v": _sds(L, B, S, H, hd, dtype="int8"),
        "k_scale": _sds(L, B, S, H),
        "v_scale": _sds(L, B, S, H),
        "kpos": _sds(B, S, dtype="int32"),
        "pos": _sds(B, dtype="int32"),
    }
    if v_err:
        c["v_err"] = _sds(L, B, S, H)
    return c


def test_serve_cache_slots_shard_over_data():
    spec = serve_cache_pspecs(_kv8_cache(4), MESH)
    assert spec["k"] == P(None, "data", None, None, None)
    assert spec["kpos"] == P("data", None)
    assert spec["pos"] == P("data")


def test_serve_cache_scales_follow_their_cache_tensor():
    """k_scale/v_scale/v_err [L, B, S, H] must mirror the payload's slot and
    head placement — here heads replicate (2 % 4 != 0), slots shard."""
    spec = serve_cache_pspecs(_kv8_cache(4), MESH)
    for leaf in ("k_scale", "v_scale", "v_err"):
        assert spec[leaf] == P(None, "data", None, None)
    # a model axis the heads DO divide: payload and scales move together
    spec = serve_cache_pspecs(_kv8_cache(4, H=4), _StubMesh(data=2, model=2))
    assert spec["k"] == P(None, "data", None, "model", None)
    for leaf in ("k_scale", "v_scale", "v_err"):
        assert spec[leaf] == P(None, "data", None, "model")


def test_serve_cache_non_divisible_slots_replicate():
    spec = serve_cache_pspecs(_kv8_cache(3), MESH)
    assert spec["k"] == P(None, None, None, None, None)
    assert spec["kpos"] == P(None, None)
    assert spec["pos"] == P(None)


def test_spec_paths_yields_qtensor_children_not_tuple_elements():
    """PartitionSpec subclasses tuple on some jax versions — the spec walker
    must yield whole specs at QTensor q/scale paths, not iterate into them."""
    spec = _specs({"blocks": {"mlp": {"wu": _qt(256, 512)}}})
    flat = dict(spec_paths(spec))
    assert flat["/blocks/mlp/wu/q"] == P(None, None, "model")
    assert flat["/blocks/mlp/wu/scale"] == P(None, "model")


# ---------------------------------------------------------------- mesh ctor

def test_make_production_mesh_rejects_bad_shapes():
    with pytest.raises(ValueError):
        make_production_mesh(shape=(8,))
    with pytest.raises(ValueError):
        make_production_mesh(shape=(2, 0))
    with pytest.raises(ValueError):
        make_production_mesh(shape=(1, 2, 3, 4))
