"""End-to-end DFQ integration + hypothesis property tests of the plan
executor on real model params."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.core import DFQConfig, apply_dfq, dfq_quantize, quantize_weights, sqnr_db
from repro.core.adversarial import hostile_rescale
from repro.data import calibration_tokens
from repro.models import build_model


def _logits(model, cfg, params, seed=0):
    toks = calibration_tokens(seed, 2, 16, cfg.vocab_size)
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(seed), (2, cfg.enc_seq, cfg.d_model))
        out, _ = model.apply(params, toks, frames)
    else:
        out, _ = model.apply(params, toks)
    return out


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma-7b", "mixtral-8x22b",
                                  "chameleon-34b", "zamba2-2.7b", "mamba2-2.7b"])
def test_apply_dfq_preserves_fp_function(arch):
    """CLE + norm-fold + absorption must not change the FP32 function
    (paper §4.1; exact pairs only — defaults skip approximate ones)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = model.dfq_plan()
    y0 = _logits(model, cfg, params)
    eq = apply_dfq(params, plan, DFQConfig())
    y1 = _logits(model, cfg, eq)
    scale = float(jnp.max(jnp.abs(y0))) + 1e-6
    assert float(jnp.max(jnp.abs(y1 - y0))) / scale < 5e-3


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b"])
def test_dfq_recovers_hostile_model(arch):
    """The paper's central claim at LM scale: per-tensor INT8 collapses on a
    hostile-ranged model; DFQ recovers near-FP logits."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = hostile_rescale(model.init(jax.random.PRNGKey(0)),
                             model.dfq_plan(), decades=1.2)
    plan = model.dfq_plan()
    y_fp = _logits(model, cfg, params)

    naive = quantize_weights(params, plan, DFQConfig(cle=False, bias_absorb=False))
    y_naive = _logits(model, cfg, naive)

    q = dfq_quantize(params, plan, DFQConfig(),
                     input_means_fn=lambda p: model.calibration_stats(
                         p, calibration_tokens(1, 2, 32, cfg.vocab_size)))
    y_dfq = _logits(model, cfg, q)

    snr_naive = float(sqnr_db(y_fp, y_naive))
    snr_dfq = float(sqnr_db(y_fp, y_dfq))
    assert snr_dfq > snr_naive + 10.0, (snr_naive, snr_dfq)
    agree = float(jnp.mean(jnp.argmax(y_fp, -1) == jnp.argmax(y_dfq, -1)))
    assert agree > 0.9


@settings(max_examples=5, deadline=None)
@given(decades=st.floats(0.3, 1.5), seed=st.integers(0, 100))
def test_hostile_rescale_is_function_preserving(decades, seed):
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    plan = model.dfq_plan()
    y0 = _logits(model, cfg, params)
    bad = hostile_rescale(params, plan, seed=seed, decades=decades)
    y1 = _logits(model, cfg, bad)
    scale = float(jnp.max(jnp.abs(y0))) + 1e-6
    assert float(jnp.max(jnp.abs(y1 - y0))) / scale < 5e-3


def test_dfq_idempotent_on_equalized_model():
    """Equalizing an already-equalized model is a no-op (fixed point of
    eq. 11: r1 == r2 → s == 1)."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = model.dfq_plan()
    once = apply_dfq(params, plan, DFQConfig())
    twice = apply_dfq(once, plan, DFQConfig())
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


def test_quantized_weight_sites_quantize_to_256_levels():
    cfg = get_config("gemma-7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = model.dfq_plan()
    q = quantize_weights(params, plan, DFQConfig())
    from repro.core.tree import get_path

    for site in plan.sites:
        w = np.asarray(get_path(q, site.w))
        n_unique = len(np.unique(w.reshape(-1)[:200000]))
        assert n_unique <= 256, f"{site.name}: {n_unique} levels"
