"""Bias absorption (§4.1.3) + bias correction (§4.2) + BN folding (§5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BNParams,
    QuantSpec,
    absorb_dense,
    absorption_amount,
    bias_correction_conv,
    bias_correction_dense,
    empirical_bias_correction_sequential,
    expected_input_analytic,
    fake_quant,
    fold_bn_conv,
    output_bias_error,
    weight_quant_error,
)


def test_absorption_amount_rule():
    beta = jnp.array([5.0, 0.5, -2.0])
    gamma = jnp.array([1.0, 1.0, 1.0])
    c = absorption_amount(beta, gamma, 3.0)
    np.testing.assert_allclose(np.asarray(c), [2.0, 0.0, 0.0])


def test_absorb_dense_preserves_function_when_preacts_high():
    """r(Wx+b−c) = r(Wx+b) − c holds when Wx+b > c (paper §4.1.3)."""
    key = jax.random.PRNGKey(0)
    d, n, out = 8, 16, 4
    w1 = jax.random.normal(key, (d, n)) * 0.1
    b1 = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) + 5.0  # big biases
    w2 = jax.random.normal(jax.random.PRNGKey(2), (n, out))
    b2 = jnp.zeros(out)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, d))
    c = jnp.minimum(b1 - 1.0, b1)  # guaranteed below pre-activations w.h.p.
    y0 = jax.nn.relu(x @ w1 + b1) @ w2 + b2
    res = absorb_dense(b1, w2, b2, c)
    y1 = (jax.nn.relu(x @ w1 + res.b1) + 0.0) @ w2 + res.b2
    # absorbed path: next layer consumes h - c; equality holds where preact>c
    mask = jnp.all(x @ w1 + b1 > c, axis=-1)
    np.testing.assert_allclose(
        np.asarray(y1[mask]), np.asarray(y0[mask]), rtol=1e-4, atol=1e-4
    )


def test_absorption_reduces_activation_range():
    b1 = jnp.array([10.0, 0.1])
    gamma = jnp.ones(2)
    c = absorption_amount(b1, gamma)
    assert float(c[0]) > 0 and float(c[1]) == 0.0
    b1_new = b1 - c
    assert float(jnp.max(b1_new)) < float(jnp.max(b1))


def test_weight_quant_error_is_quantization_residual():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 32))
    spec = QuantSpec(bits=8)
    eps = weight_quant_error(w, spec)
    np.testing.assert_allclose(
        np.asarray(w + eps), np.asarray(fake_quant(w, spec)), rtol=1e-6, atol=1e-7
    )


def test_bias_correction_zeroes_output_mean_shift():
    """Paper Fig. 3 / eq. 16-17: after BC, E[ỹ − y] ≈ 0 per channel."""
    key = jax.random.PRNGKey(0)
    d, out, N = 32, 16, 4096
    w = jax.random.normal(key, (d, out)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(1), (out,)) * 1.5
    )
    b = jnp.zeros(out)
    spec = QuantSpec(bits=4)  # coarse grid → strong bias
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (N, d))) + 0.5  # E[x] ≠ 0
    e_x = jnp.mean(x, axis=0)
    w_q = fake_quant(w, spec)
    bias_before = output_bias_error(x @ w + b, x @ w_q + b)
    b_corr = bias_correction_dense(w, b, e_x, spec)
    bias_after = output_bias_error(x @ w + b, x @ w_q + b_corr)
    assert float(jnp.max(jnp.abs(bias_after))) < 0.05 * float(
        jnp.max(jnp.abs(bias_before))
    )


def test_bias_correction_conv_matches_direct():
    key = jax.random.PRNGKey(5)
    w = jax.random.normal(key, (3, 3, 8, 4))
    spec = QuantSpec(bits=6)
    e_x = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (8,)))
    b = bias_correction_conv(w, None, e_x, spec)
    eps = weight_quant_error(w, spec)
    expected = -jnp.einsum("i,hwio->o", e_x, eps)
    np.testing.assert_allclose(np.asarray(b), np.asarray(expected), rtol=1e-5, atol=1e-7)


def test_expected_input_analytic_relu_matches_mc():
    beta = jnp.array([0.3, -0.8, 1.5])
    gamma = jnp.array([1.0, 0.4, 2.0])
    x = beta + gamma * jax.random.normal(jax.random.PRNGKey(0), (200000, 3))
    mc = jnp.mean(jax.nn.relu(x), axis=0)
    an = expected_input_analytic(beta, gamma, "relu")
    np.testing.assert_allclose(np.asarray(an), np.asarray(mc), rtol=2e-2, atol=5e-3)


def test_expected_input_analytic_gelu_quadrature():
    beta = jnp.array([0.0, 0.7, -1.2])
    gamma = jnp.array([1.0, 0.5, 1.5])
    x = beta + gamma * jax.random.normal(jax.random.PRNGKey(1), (400000, 3))
    mc = jnp.mean(jax.nn.gelu(x), axis=0)
    an = expected_input_analytic(beta, gamma, "gelu")
    np.testing.assert_allclose(np.asarray(an), np.asarray(mc), rtol=2e-2, atol=5e-3)


def test_bn_folding_preserves_inference_function():
    key = jax.random.PRNGKey(7)
    w = jax.random.normal(key, (3, 3, 4, 8))
    b = jax.random.normal(jax.random.PRNGKey(8), (8,)) * 0.1
    bn = BNParams(
        gamma=jnp.exp(jax.random.normal(jax.random.PRNGKey(9), (8,)) * 0.5),
        beta=jax.random.normal(jax.random.PRNGKey(10), (8,)),
        mean=jax.random.normal(jax.random.PRNGKey(11), (8,)),
        var=jnp.exp(jax.random.normal(jax.random.PRNGKey(12), (8,))),
    )
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 8, 8, 4))
    conv = lambda x, w: jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    y_bn = (conv(x, w) + b - bn.mean) / jnp.sqrt(bn.var + bn.eps) * bn.gamma + bn.beta
    folded = fold_bn_conv(w, b, bn)
    y_fold = conv(x, folded.w) + folded.b
    np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_bn), rtol=1e-4, atol=1e-4)


def test_empirical_sequential_bc_drives_residual_to_zero():
    """Appendix D: layer-by-layer correction leaves ~0 mean error per layer."""
    key = jax.random.PRNGKey(20)
    dims = [16, 32, 24, 8]
    ks = jax.random.split(key, 8)
    weights = [
        jax.random.normal(ks[i], (dims[i], dims[i + 1]))
        * jnp.exp(jax.random.normal(ks[i + 4], (dims[i + 1],)))
        for i in range(3)
    ]
    biases = [jnp.zeros(dims[i + 1]) for i in range(3)]
    x0 = jnp.abs(jax.random.normal(ks[7], (2048, dims[0])))
    spec = QuantSpec(bits=4)

    def layer_apply(i, x, w, b):
        h = x if i == 0 else jax.nn.relu(x)
        return h @ w + b

    res = empirical_bias_correction_sequential(
        layer_apply, weights, biases, x0, lambda w: fake_quant(w, spec)
    )
    for r in res.residual_bias:
        assert float(jnp.max(jnp.abs(r))) < 1e-3
