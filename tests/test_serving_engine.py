"""Continuous-batching engine tests.

The anchor is batch invariance: a request decoded solo must produce
bit-identical token ids to the same request served inside a mixed continuous
batch — for fp32 AND the serve-w8a16 recipe. The engine's default
device-resident fast path (fused decode horizons + batched multi-slot
prefill + donated pooled cache) must additionally match the ``fast=False``
stepwise reference bit-for-bit AND tick-for-tick, with a pinned reduction in
dispatches and host syncs. Plus: end-to-end regression through save/load,
engine bookkeeping, and a slow randomized soak.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

import repro
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine, synthetic_trace

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def fp32_setup():
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


@pytest.fixture(scope="module")
def w8a16_setup(fp32_setup):
    model, params, cfg = fp32_setup
    qm = repro.quantize(model, params=params, recipe="serve-w8a16")
    return qm


def _mixed_trace(vocab):
    rng = np.random.RandomState(7)
    lens = [(5, 6), (12, 3), (3, 1), (9, 8)]  # includes a gen-at-prefill edge
    return [
        Request(rid=i, prompt=rng.randint(0, vocab, size=p).astype(np.int32),
                max_new_tokens=g)
        for i, (p, g) in enumerate(lens)
    ]


def _engine(model, params, cfg, **kw):
    kw.setdefault("num_slots", 2)   # < len(trace): forces slot recycling
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(model, params, cfg, **kw)


# ------------------------------------------------------------------- parity

@pytest.mark.parametrize("variant", ["fp32", "serve-w8a16"])
def test_batch_invariance_parity(variant, fp32_setup, w8a16_setup, request):
    """Solo-decoded tokens == tokens from a mixed continuous batch, bit for
    bit (same slot pool, so identical compiled shapes either way)."""
    if variant == "fp32":
        model, params, cfg = fp32_setup
    else:
        qm = w8a16_setup
        model, params, cfg = qm.model, qm.params, qm.cfg
    trace = _mixed_trace(cfg.vocab_size)

    mixed = _engine(model, params, cfg).run(trace)
    assert sorted(mixed) == [0, 1, 2, 3]

    solo_engine = _engine(model, params, cfg)  # reused (drained) per request
    for r in trace:
        solo = solo_engine.run([dataclasses.replace(r)])
        assert solo[r.rid].tokens == mixed[r.rid].tokens, (
            f"{variant}: rid {r.rid} diverged between solo and mixed batch"
        )
        assert len(solo[r.rid].tokens) == r.max_new_tokens
        assert solo_engine.pool.all_free()


@pytest.mark.parametrize("variant", ["fp32", "serve-w8a16"])
def test_engine_matches_naive_prefill_decode_oracle(
        variant, fp32_setup, w8a16_setup):
    """Independent ground truth: the engine's tokens for each request must
    equal a plain whole-prompt ``model.prefill`` + scalar-pos ``decode_step``
    loop (the pre-engine serving path) — this anchors chunked prefill, pad
    invalidation, logits_at, and the decode bookkeeping rollback against a
    code path that shares none of them."""
    import jax.numpy as jnp

    if variant == "fp32":
        model, params, cfg = fp32_setup
    else:
        qm = w8a16_setup
        model, params, cfg = qm.model, qm.params, qm.cfg
    trace = _mixed_trace(cfg.vocab_size)
    served = _engine(model, params, cfg).run(trace)

    for r in trace:
        cache = model.init_cache(1, 32, dtype=jnp.float32)
        prompt = np.asarray(r.prompt, np.int32)[None, :]
        logits, cache = model.prefill(params, prompt, cache)
        toks = [int(jnp.argmax(logits, -1)[0])]
        while len(toks) < r.max_new_tokens:
            logits, cache = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
            toks.append(int(jnp.argmax(logits, -1)[0]))
        assert served[r.rid].tokens == toks, (
            f"{variant}: rid {r.rid} diverged from the naive serving oracle"
        )


# ------------------------------------------------- fast path vs stepwise ref

@pytest.mark.parametrize("variant", ["fp32", "serve-w8a16"])
def test_fused_vs_stepwise_parity(variant, fp32_setup, w8a16_setup):
    """The fused fast path (decode horizons + batched multi-slot prefill +
    donated cache + deferred slot reset) must be bit-identical to the
    stepwise reference — tokens AND the admit/finish timeline — at every
    horizon, including 1 (where only the dispatch batching differs)."""
    if variant == "fp32":
        model, params, cfg = fp32_setup
    else:
        qm = w8a16_setup
        model, params, cfg = qm.model, qm.params, qm.cfg
    trace = _mixed_trace(cfg.vocab_size)

    slow_eng = _engine(model, params, cfg, fast=False)
    slow = slow_eng.run([dataclasses.replace(r) for r in trace])
    for horizon in (1, 3, 8):
        fast_eng = _engine(model, params, cfg, fast=True,
                           decode_horizon=horizon)
        fast = fast_eng.run([dataclasses.replace(r) for r in trace])
        for r in trace:
            assert fast[r.rid].tokens == slow[r.rid].tokens, (
                f"{variant}: rid {r.rid} diverged at horizon {horizon}")
            assert fast[r.rid].admitted_at == slow[r.rid].admitted_at
            assert fast[r.rid].finished_at == slow[r.rid].finished_at
        # the trace includes a gen-at-prefill retire; occupancy accounting
        # across fused horizons must still match the stepwise timeline
        assert fast_eng.mean_occupancy() == pytest.approx(
            slow_eng.mean_occupancy()), f"occupancy drift at h={horizon}"


def test_fast_path_dispatch_and_sync_counts(fp32_setup):
    """Dispatch/sync-count regression: with jitted fns wrapped in counting
    shims, the fast path must make <= ceil(decode_tokens/horizon) decode
    round trips and exactly one prefill dispatch per engine step regardless
    of how many slots are prefilling."""
    model, params, cfg = fp32_setup
    H, G = 4, 9  # 1 token from prefill + 8 decode steps
    eng = ServingEngine(model, params, cfg, num_slots=4, max_len=32,
                        prefill_chunk=8, decode_horizon=H)
    counts = {"decode": 0, "prefill": 0}
    real_decode, real_prefill = eng._decode_horizon_fn, eng._prefill_multi_fn

    def counting_decode(*a, **kw):
        counts["decode"] += 1
        return real_decode(*a, **kw)

    def counting_prefill(*a, **kw):
        counts["prefill"] += 1
        return real_prefill(*a, **kw)

    eng._decode_horizon_fn = counting_decode
    eng._prefill_multi_fn = counting_prefill

    # 3 same-shape requests, all at t=0: prompts prefill together in ONE
    # dispatch, then decode in lockstep
    trace = [Request(rid=i, prompt=[1 + i] * 8, max_new_tokens=G)
             for i in range(3)]
    res = eng.run(trace)
    assert sorted(res) == [0, 1, 2]
    assert counts["prefill"] == 1, "3 prefilling slots must share 1 dispatch"
    assert counts["decode"] <= math.ceil((G - 1) / H)
    assert eng.stats["decode_dispatches"] == counts["decode"]
    assert eng.stats["prefill_dispatches"] == counts["prefill"]
    assert eng.stats["decode_steps"] == G - 1
    # sync accounting: one per decode horizon + one for the prefill round
    # that finished prompts (never one per token)
    assert eng.stats["host_syncs"] == counts["decode"] + 1


def test_host_sync_reduction_at_horizon_8(fp32_setup):
    """Acceptance pin: >= 4x fewer host syncs per generated token than the
    stepwise path at horizon 8 on a decode-heavy batch."""
    model, params, cfg = fp32_setup
    trace = [Request(rid=i, prompt=[3 + i] * 6, max_new_tokens=17)
             for i in range(4)]

    def run(fast):
        eng = ServingEngine(model, params, cfg, num_slots=4, max_len=32,
                            prefill_chunk=8, decode_horizon=8, fast=fast)
        res = eng.run([dataclasses.replace(r) for r in trace])
        return res, eng

    slow_res, slow = run(False)
    fast_res, fast = run(True)
    assert {r: v.tokens for r, v in fast_res.items()} == \
           {r: v.tokens for r, v in slow_res.items()}
    assert slow.syncs_per_token() >= 4 * fast.syncs_per_token(), (
        f"slow {slow.syncs_per_token():.3f} vs fast "
        f"{fast.syncs_per_token():.3f} syncs/token")


def test_horizon_capped_by_scheduled_arrival(fp32_setup):
    """peek_arrival feeds the adaptive horizon: a pending arrival must not
    wait behind a long decode horizon when a slot is free."""
    model, params, cfg = fp32_setup
    trace = [
        Request(rid=0, prompt=[1] * 4, max_new_tokens=20, arrival=0.0),
        Request(rid=1, prompt=[2] * 4, max_new_tokens=4, arrival=2.0),
    ]
    eng = ServingEngine(model, params, cfg, num_slots=2, max_len=32,
                        prefill_chunk=8, decode_horizon=16)
    res = eng.run(trace)
    # without the arrival cap the first horizon would run 16+ ticks and
    # admit rid 1 only at its end
    assert res[1].admitted_at == 2.0
    ref = ServingEngine(model, params, cfg, num_slots=2, max_len=32,
                        prefill_chunk=8, fast=False).run(
        [dataclasses.replace(r) for r in trace])
    assert res[1].tokens == ref[1].tokens
    assert res[1].admitted_at == ref[1].admitted_at


def test_pooled_cache_is_donated(fp32_setup):
    """The engine jits donate the cache argument: after a step, the buffer
    that went in must have been consumed in place (invalidated), not copied
    — holding a stale reference to ``pool.cache`` across a step is an error
    by design (README documents the caveat)."""
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg)
    before = eng.pool.cache["k"]
    eng.run([Request(rid=0, prompt=[5] * 4, max_new_tokens=4)])
    assert before.is_deleted(), "cache was copied, not donated"


# ------------------------------------------------------- e2e save/load serve

def test_quantize_save_load_engine_round_trip(w8a16_setup, tmp_path):
    """quantize() → save → QuantizedModel.load → engine serve must produce
    the same tokens as serving the in-memory artifact."""
    from repro.pipeline import QuantizedModel

    qm = w8a16_setup
    trace = _mixed_trace(qm.cfg.vocab_size)
    mem = ServingEngine.from_quantized(
        qm, num_slots=2, max_len=32, prefill_chunk=8).run(trace)

    qm.save(str(tmp_path / "artifact"))
    qm2 = QuantizedModel.load(str(tmp_path / "artifact"))
    disk = ServingEngine.from_quantized(
        qm2, num_slots=2, max_len=32, prefill_chunk=8).run(trace)

    assert {r: v.tokens for r, v in mem.items()} == \
           {r: v.tokens for r, v in disk.items()}


# -------------------------------------------------------------- bookkeeping

def test_engine_drains_and_tracks_occupancy(fp32_setup):
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg)
    res = eng.run(_mixed_trace(cfg.vocab_size))
    assert eng.pool.all_free()
    assert list(eng.scheduler.admitted_order) == [0, 1, 2, 3]
    assert 0.0 < eng.mean_occupancy() <= 1.0
    assert eng.stats["generated_tokens"] == sum(
        len(v.tokens) for v in res.values())
    assert all(v.finished_at >= v.admitted_at >= v.arrival
               for v in res.values())


def test_engine_rejects_oversized_request(fp32_setup):
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, max_len=16)
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(Request(rid=0, prompt=[1] * 12, max_new_tokens=8))


def test_engine_caps_capacity_at_sliding_window_ring():
    """init_cache shrinks the ring to the SWA window; admission must
    validate against the REAL ring, or padded prefill wrap-around would
    clobber keys still inside the attention window."""
    cfg = get_config("mixtral-8x22b", smoke=True)  # smoke window = 16
    eng = ServingEngine(build_model(cfg), None, cfg, num_slots=2,
                        max_len=64, prefill_chunk=8)
    assert eng.max_len == 16 == eng.pool.max_len
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(Request(rid=0, prompt=[1] * 10, max_new_tokens=10))


def test_engine_rejects_attention_free_families():
    cfg = get_config("mamba2-2.7b", smoke=True)
    with pytest.raises(ValueError, match="attention-family"):
        ServingEngine(None, None, cfg)


# -------------------------------------------------------------------- soak

@pytest.mark.slow
def test_engine_soak_randomized_arrivals(fp32_setup):
    """N=200 randomized arrivals through a small pool: every request
    completes with its exact token budget, FIFO order holds, pool drains."""
    model, params, cfg = fp32_setup
    trace = synthetic_trace(
        42, 200, vocab_size=cfg.vocab_size,
        prompt_lens=(2, 12), gen_lens=(1, 8), mean_interarrival=0.3,
    )
    eng = ServingEngine(model, params, cfg, num_slots=8, max_len=32,
                        prefill_chunk=8)
    res = eng.run(trace)
    assert sorted(res) == list(range(200))
    for r in trace:
        assert len(res[r.rid].tokens) == r.max_new_tokens
    assert list(eng.scheduler.admitted_order) == list(range(200))
    assert eng.pool.all_free()
    assert eng.mean_occupancy() > 0.3
