"""QuantLint rule-engine tests on hand-written miniature jaxprs/HLO.

Each of the five core rules gets a fixture that passes plus a
deliberately-broken twin (injected f32 cache dequant, dropped donation,
extra / unpinned all-gather, new post-warmup shape, decoupled scale
sharding) asserting the rule fires with an actionable message naming the
jit and instruction. The HLO parser and the repaired ``hlo_diag`` are
covered on the exact inputs the old regex dropped: layout-annotated types
(nested parens) and tuple-typed async collectives.
"""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo_diag
from repro.analysis.lint import parse_hlo_module, type_bytes
from repro.analysis.lint.contracts import diff_contracts
from repro.analysis.lint.extract import JitArtifact, LintGraph
from repro.analysis.lint.rules import (
    Finding,
    is_cache_dequant,
    register_rule,
    run_rules,
    s8_convert_records,
)

# miniature cache geometry: one ring is [S, H, hd] = [8, 2, 4]
CACHE_DIMS = (8, 2, 4)


def _graph(jits, mesh_shape=None, **kw):
    return LintGraph(recipe="mini", mesh_shape=mesh_shape, engine={},
                     jits=jits, **kw)


def _artifact(name, kind, **kw):
    kw.setdefault("cache_payload_dims", CACHE_DIMS)
    return JitArtifact(name=name, kind=kind, **kw)


# ------------------------------------------------------------ hlo_model
def test_parser_layout_annotated_types():
    mod = parse_hlo_module(
        "%f = f32[8,128]{1,0:T(8,128)} fusion(%a, %b), kind=kLoop\n"
    )
    (i,) = list(mod.instructions())
    assert i.opcode == "fusion"
    assert i.operands == ["a", "b"]
    dt, dims = i.result_shapes()[0]
    assert (dt, dims) == ("f32", (8, 128))
    assert i.result_bytes() == 8 * 128 * 4


def test_parser_async_tuple_collective():
    text = """
    ENTRY %main (p: f32[1024]) -> f32[1024] {
      %p = f32[1024]{0} parameter(0)
      %ar = (f32[1024]{0}, f32[1024]{0}) all-reduce-start(%p), to_apply=%add
      ROOT %ard = f32[1024]{0} all-reduce-done(%ar)
    }
    """
    mod = parse_hlo_module(text)
    colls = mod.collectives()
    assert [c.name for c in colls] == ["ar"]        # -done is skipped
    assert colls[0].base_opcode == "all-reduce"
    # (operands..., results...) tuple counts half: one payload per pair
    assert colls[0].result_bytes() == 1024 * 4


def test_parser_alias_map_and_entry_layout():
    text = (
        "HloModule m, input_output_alias={ {0}: (1, {}, may-alias), "
        "{1}: (2, {}, may-alias) }, entry_computation_layout="
        "{(f32[4]{0}, s8[2,8]{1,0}, f32[2]{0})->(s8[2,8]{1,0}, f32[2]{0})}\n"
    )
    mod = parse_hlo_module(text)
    assert mod.alias == {(0,): (1, ()), (1,): (2, ())}
    assert mod.aliased_param_types() == ["s8[2,8]{1,0}", "f32[2]{0}"]


def test_type_bytes_unknown_dtype_warns_not_skips():
    with pytest.warns(UserWarning, match="zz9"):
        assert type_bytes("zz9[4]{0}") == 0
    assert type_bytes("(f32[2]{0}, zz9[4]{0})", warn_unknown=False) == 8


# ------------------------------------------------------------- hlo_diag
def test_hlo_diag_counts_layout_and_tuple_collectives():
    # both shapes broke the old regex: nested layout parens, tuple result
    text = """
    %ag = s8[2,4,32,2,16]{4,3,2,1,0:T(8,128)} all-gather(%x), dimensions={1}
    %ar = (f32[256]{0}, f32[256]{0}) all-reduce-start(%y), to_apply=%add
    %ard = f32[256]{0} all-reduce-done(%ar)
    """
    rows = hlo_diag.top_collectives(text)
    by_op = {base: (b, n) for b, n, base, _ in rows}
    assert by_op["all-gather"] == (2 * 4 * 32 * 2 * 16, 1)
    assert by_op["all-reduce"] == (256 * 4, 1)      # start/done pair = once


def test_hlo_diag_shape_bytes_warns_on_unknown():
    with pytest.warns(UserWarning, match="qq7"):
        assert hlo_diag.shape_bytes("qq7[8]{0}") == 0


# ------------------------------------------------------------- registry
def test_registry_rejects_duplicates_and_unknown_rules():
    with pytest.raises(ValueError, match="already registered"):
        register_rule("dtype-ledger")(lambda g, c: [])
    with pytest.raises(ValueError, match="unknown lint rule"):
        run_rules(_graph({}), rules=["no-such-rule"])
    with pytest.raises(ValueError, match="severity"):
        Finding("r", "fatal", "j", "w", "m")


# ----------------------------------------------------------- dtype-ledger
def _fused_jaxpr():
    def f(x, k):                    # convert feeds the contraction directly
        return jax.lax.dot_general(
            x, k.astype(jnp.float32), (((1,), (0,)), ((), ())))

    return jax.make_jaxpr(f)(
        jnp.zeros((2, CACHE_DIMS[0])), jnp.zeros(CACHE_DIMS, jnp.int8))


def _materialized_jaxpr():
    def f(k, s):                    # dequant-multiply: full ring in f32
        return (k.astype(jnp.float32) * s).sum()

    return jax.make_jaxpr(f)(
        jnp.zeros(CACHE_DIMS, jnp.int8), jnp.ones(CACHE_DIMS[:-1] + (1,)))


def test_dtype_ledger_passes_on_fused_convert():
    g = _graph({"decode": _artifact("decode", "decode",
                                    jaxpr=_fused_jaxpr())})
    assert run_rules(g, rules=["dtype-ledger"]) == []


def test_dtype_ledger_flags_injected_decode_dequant():
    g = _graph({"decode": _artifact("decode", "decode",
                                    jaxpr=_materialized_jaxpr())})
    (f,) = run_rules(g, rules=["dtype-ledger"])
    assert f.severity == "error" and f.jit == "decode"
    assert "8x2x4" in f.where and "scale-fold" in f.message


def test_dtype_ledger_prefill_debt_channel():
    g = _graph({"prefill": _artifact("prefill", "prefill",
                                     jaxpr=_materialized_jaxpr())})
    # no contract entry: the dequant is an error demanding an explicit pin
    (f,) = run_rules(g, rules=["dtype-ledger"])
    assert f.severity == "error" and "known_debt" in f.message
    # pinned: same graph, same rule, now an info
    contract = {"known_debt": [{"rule": "dtype-ledger", "jit": "prefill",
                                "shape": list(CACHE_DIMS)}]}
    (f,) = run_rules(g, contract, rules=["dtype-ledger"])
    assert f.severity == "info"


def test_dtype_ledger_ignores_weight_shaped_dequant():
    # a [K, N] weight dequant (the w8a16 XLA-fallback scale-fold) is pinned
    # by the ledger totals, not an error — only cache-ring shapes hard-fail
    def f(w, s):
        return (w.astype(jnp.float32) * s).sum()

    jx = jax.make_jaxpr(f)(jnp.zeros((64, 128), jnp.int8),
                           jnp.ones((128,)))
    recs = s8_convert_records(jx)
    art = _artifact("decode", "decode", jaxpr=jx)
    assert recs and not is_cache_dequant(recs[0], art)
    assert run_rules(_graph({"decode": art}), rules=["dtype-ledger"]) == []


def test_dtype_ledger_drift_against_contract():
    g = _graph({"decode": _artifact("decode", "decode",
                                    jaxpr=_fused_jaxpr())})
    contract = {"jits": {"decode": {"s8_converts": {"count": 0, "bytes": 0}}}}
    findings = run_rules(g, contract, rules=["dtype-ledger"])
    assert any(f.severity == "error" and "ledger drift" in f.message
               for f in findings)


# ------------------------------------------------------ collective-budget
_POOL_AG_HLO = """
ENTRY %main (p: s8[2,4,8,2,4]) -> s8[2,4,8,2,4] {
  %p = s8[2,4,8,2,4]{4,3,2,1,0} parameter(0)
  %pool.ag = s8[2,4,8,2,4]{4,3,2,1,0} all-gather(%p), dimensions={1}
  ROOT %r = s8[2,4,8,2,4]{4,3,2,1,0} copy(%pool.ag)
}
"""


def _pool_artifact(name, hlo):
    return _artifact(
        name, "prefill", module=parse_hlo_module(hlo),
        cache_leaves_global=[("s8", (2, 4, 8, 2, 4))],
        cache_leaves_local=[("s8", (2, 2, 8, 2, 4))])


def test_collective_budget_flags_pool_gather_under_tp():
    g = _graph({"prefill": _pool_artifact("prefill", _POOL_AG_HLO)},
               mesh_shape=(2, 4))
    findings = run_rules(g, rules=["collective-budget"])
    (f,) = [f for f in findings if f.severity == "error"]
    assert f.jit == "prefill" and f.where == "pool.ag"
    assert "cache-pool leaf" in f.message and "s8[2,4,8,2,4]" in f.message


def test_collective_budget_known_debt_downgrades_to_info():
    g = _graph({"prefill": _pool_artifact("prefill", _POOL_AG_HLO)},
               mesh_shape=(2, 4))
    contract = {"known_debt": [{"rule": "collective-budget",
                                "jit": "prefill",
                                "type": "s8[2,4,8,2,4]"}]}
    findings = run_rules(g, contract, rules=["collective-budget"])
    assert [f.severity for f in findings] == ["info"]


def test_collective_budget_extra_collective_vs_contract():
    g = _graph({"prefill": _pool_artifact("prefill", _POOL_AG_HLO)},
               mesh_shape=(1, 1))           # not TP: only the budget applies
    contract = {"jits": {"prefill": {"collectives": {}}}}
    (f,) = run_rules(g, contract, rules=["collective-budget"])
    assert f.severity == "error" and f.where == "all-gather"
    assert "new collective traffic" in f.message


def test_collective_budget_win_still_requires_repin():
    g = _graph({"prefill": _artifact("prefill", "prefill",
                                     module=parse_hlo_module("ENTRY %e (x: f32[1]) -> f32[1] {\n ROOT %r = f32[1]{0} copy(%x)\n}"))},
               mesh_shape=(1, 1))
    contract = {"jits": {"prefill": {"collectives": {"all-gather": [1, 512]}}}}
    (f,) = run_rules(g, contract, rules=["collective-budget"])
    assert "a win" in f.message


# -------------------------------------------------------- donation-audit
_DONATED_HLO = (
    "HloModule m, input_output_alias={ {0}: (1, {}, may-alias), "
    "{1}: (2, {}, may-alias) }, entry_computation_layout="
    "{(f32[4]{0}, s8[2,8]{1,0}, f32[2]{0})->(s8[2,8]{1,0}, f32[2]{0})}\n"
)
_DROPPED_HLO = (
    "HloModule m, input_output_alias={ {0}: (1, {}, may-alias) }, "
    "entry_computation_layout="
    "{(f32[4]{0}, s8[2,8]{1,0}, f32[2]{0})->(s8[2,8]{1,0}, f32[2]{0})}\n"
)
_POOL_LEAVES = [("s8", (2, 8)), ("f32", (2,))]


def test_donation_audit_passes_when_all_leaves_aliased():
    art = _artifact("decode", "decode", module=parse_hlo_module(_DONATED_HLO),
                    cache_leaves_local=list(_POOL_LEAVES))
    assert run_rules(_graph({"decode": art}), rules=["donation-audit"]) == []


def test_donation_audit_flags_dropped_alias():
    art = _artifact("decode", "decode", module=parse_hlo_module(_DROPPED_HLO),
                    cache_leaves_local=list(_POOL_LEAVES))
    (f,) = run_rules(_graph({"decode": art}), rules=["donation-audit"])
    assert f.severity == "error" and f.jit == "decode"
    assert "f32[2]" in f.message and "input_output_alias" in f.where


# -------------------------------------------------- recompilation-guard
def test_recompilation_guard_closure():
    shapes = {("prefill_multi", 1), ("decode_horizon", 1),
              ("decode_horizon", 2)}
    g = _graph({}, warmup_shapes=set(shapes), dispatch_shapes=set(shapes))
    assert run_rules(g, rules=["recompilation-guard"]) == []
    g.dispatch_shapes.add(("decode_horizon", 3))    # a live-compile shape
    (f,) = run_rules(g, rules=["recompilation-guard"])
    assert f.severity == "error" and f.jit == "decode_horizon"
    assert "warmup" in f.message and "3" in f.where


def test_recompilation_guard_contract_set_equality():
    shapes = {("decode_horizon", 1)}
    g = _graph({}, warmup_shapes=set(shapes), dispatch_shapes=set(shapes))
    contract = {"warmup_shapes": [["decode_horizon", 1],
                                  ["decode_horizon", 2]]}
    (f,) = run_rules(g, contract, rules=["recompilation-guard"])
    assert f.severity == "error" and "no longer compiled" in f.message


# ------------------------------------------------------- scale-coupling
def _coupling_graph(q_spec, s_spec, s_shape=(128,)):
    leaves = {
        "/blocks/attn/wq/q": {"dtype": "s8", "shape": [64, 128],
                              "spec": q_spec},
        "/blocks/attn/wq/scale": {"dtype": "f32", "shape": list(s_shape),
                                  "spec": s_spec},
    }
    return _graph({}, param_leaves=leaves,
                  scale_pairs=[("/blocks/attn/wq/q",
                                "/blocks/attn/wq/scale")],
                  mesh_shape=(2, 4))


def test_scale_coupling_passes_on_cosharded_pair():
    g = _coupling_graph([None, "model"], ["model"])
    assert run_rules(g, rules=["scale-coupling"]) == []


def test_scale_coupling_flags_decoupled_scale():
    g = _coupling_graph([None, "model"], [None])
    (f,) = run_rules(g, rules=["scale-coupling"])
    assert f.severity == "error" and "wq/scale" in f.where
    assert "'model'" in f.message


def test_scale_coupling_flags_sharded_per_tensor_scale():
    g = _coupling_graph([None, None], ["model"], s_shape=(1,))
    (f,) = run_rules(g, rules=["scale-coupling"])
    assert "per-tensor scale" in f.message


def test_scale_coupling_missing_scale_leaf():
    g = _coupling_graph([None, "model"], ["model"])
    del g.param_leaves["/blocks/attn/wq/scale"]
    (f,) = run_rules(g, rules=["scale-coupling"])
    assert "no scale leaf" in f.message


def test_scale_coupling_cache_scale_follows_payload():
    cache = {
        "/k": {"dtype": "s8", "shape": [2, 4, 8, 2, 4],
               "spec": [None, "data", None, "model", None]},
        "/k_scale": {"dtype": "f32", "shape": [2, 4, 8, 2],
                     "spec": [None, "data", None, "model"]},
    }
    g = _graph({}, cache_spec_leaves=cache, mesh_shape=(2, 4))
    assert run_rules(g, rules=["scale-coupling"]) == []
    cache["/k_scale"]["spec"] = [None, "data", None, None]   # head decouple
    (f,) = run_rules(g, rules=["scale-coupling"])
    assert f.severity == "error" and "head axis" in f.message


# ------------------------------------------------------------ contracts
def test_diff_contracts_reports_drift_and_wins():
    old = {"recipe": "r", "mesh": None, "engine": {"num_slots": 4},
           "warmup_shapes": [["decode_horizon", 1]],
           "jits": {"decode": {"collectives": {"all-gather": [1, 512]},
                               "s8_converts": {"count": 2, "bytes": 64}}},
           "known_debt": [{"rule": "collective-budget", "jit": "prefill"}]}
    new = {"recipe": "r", "mesh": None, "engine": {"num_slots": 4},
           "warmup_shapes": [["decode_horizon", 1], ["decode_horizon", 2]],
           "jits": {"decode": {"collectives": {"all-gather": [2, 1024]},
                               "s8_converts": {"count": 2, "bytes": 64}}},
           "known_debt": []}
    lines = "\n".join(diff_contracts(old, new))
    assert "warmup shape added" in lines
    assert "all-gather [1, 512] -> [2, 1024]" in lines
    assert "REMOVED (a win)" in lines
    assert diff_contracts(old, old) == []
    assert diff_contracts(None, new) and "new contract" in \
        diff_contracts(None, new)[0]
