"""ServeConfig: the typed serving surface. Pins the argparse surface is
DERIVED from the dataclass (args -> config round trip), the artifact round
trip (config -> artifact -> config), and the single CLI-vs-artifact
precedence/mismatch rule (a kv_bits conflict raises naming both sides)."""
import dataclasses
import types

import pytest

from repro.launch.serve_config import (
    ServeConfig,
    ServeConfigError,
    build_parser,
    parse_mesh,
)


def _fake_artifact(recipe="serve-w8a8-kv8", kv_bits=8, arch="qwen2-0.5b-smoke",
                   mesh_shape=None):
    """Duck-typed QuantizedModel: just the fields from_artifact reads."""
    sharding = {"mode": "tp", "mesh_shape": list(mesh_shape)} \
        if mesh_shape else {}
    return types.SimpleNamespace(
        recipe=types.SimpleNamespace(name=recipe),
        cfg=types.SimpleNamespace(name=arch, kv_cache_bits=kv_bits),
        sharding=sharding,
        shard_mode=sharding.get("mode"),
    )


# ------------------------------------------------------- args <-> config

def test_defaults_round_trip_through_argparse():
    """Empty argv must produce exactly ServeConfig() — the parser is derived
    from the dataclass, so the two default sets CANNOT drift."""
    ns = build_parser().parse_args([])
    assert ServeConfig.from_args(ns) == ServeConfig()


def test_every_field_has_a_flag():
    ns = build_parser().parse_args([])
    for f in dataclasses.fields(ServeConfig):
        assert hasattr(ns, f.name), f"field {f.name} lost its CLI face"


def test_args_to_config_values():
    ns = build_parser().parse_args([
        "--arch", "qwen2-0.5b", "--smoke", "--quantize", "w8a8",
        "--kv-bits", "8", "--mesh", "2x4", "--slots", "8",
        "--no-prefix-reuse", "--page-size", "16", "--trace", "12",
        "--qps", "1.5", "--serve-async",
    ])
    c = ServeConfig.from_args(ns)
    assert c.smoke and c.quantize == "w8a8" and c.kv_bits == 8
    assert c.mesh == (2, 4) and c.mesh_str == "2x4"
    assert c.slots == 8 and not c.prefix_reuse and c.page_size == 16
    assert c.trace == 12 and c.serve_async and c.qps == 1.5


def test_validate_flag_combinations():
    with pytest.raises(ServeConfigError, match="--num-pages needs"):
        ServeConfig(num_pages=4).validate()
    with pytest.raises(ServeConfigError, match="--no-prefix-reuse needs"):
        ServeConfig(prefix_reuse=False).validate()
    with pytest.raises(ServeConfigError, match="--serve-async needs --trace"):
        ServeConfig(serve_async=True).validate()
    with pytest.raises(ServeConfigError, match="shed-pressure"):
        ServeConfig(shed_pressure=0.0).validate()
    with pytest.raises(ServeConfigError, match="wants DxM"):
        parse_mesh("banana")
    assert parse_mesh("2x2x2") == (2, 2, 2)
    # a valid config passes and returns itself for chaining
    c = ServeConfig(trace=4)
    assert c.validate() is c


# --------------------------------------------------- artifact round trip

def test_config_artifact_config_round_trip():
    """args -> config -> (recorded) artifact -> config: what the artifact
    records merges back losslessly when the CLI side left it unset."""
    art = ServeConfig.from_artifact(
        _fake_artifact(recipe="serve-w8a16-kv8", kv_bits=8,
                       mesh_shape=(2, 4)))
    assert art.recipe == "serve-w8a16-kv8"
    assert art.quantize == "w8a16" and art.kv_bits == 8
    assert art.mesh == (2, 4)

    merged, notes = ServeConfig().with_artifact(art)
    assert merged.kv_bits == 8 and merged.recipe == "serve-w8a16-kv8"
    assert merged.mesh == (2, 4)
    assert notes == []                       # nothing explicit = nothing to say
    # and a second round trip is a fixed point
    again, _ = merged.with_artifact(art)
    assert again == merged


def test_kv_bits_mismatch_raises_naming_both_sides():
    art = ServeConfig.from_artifact(_fake_artifact(kv_bits=16,
                                                   recipe="serve-w8a16"))
    with pytest.raises(ServeConfigError) as ei:
        ServeConfig(kv_bits=8).with_artifact(art)
    msg = str(ei.value)
    assert "--kv-bits 8" in msg              # the CLI side
    assert "kv_cache_bits=16" in msg         # the artifact side
    assert "re-quantize" in msg              # the remedy


def test_matching_kv_bits_is_fine():
    art = ServeConfig.from_artifact(_fake_artifact(kv_bits=8))
    merged, _ = ServeConfig(kv_bits=8).with_artifact(art)
    assert merged.kv_bits == 8


def test_cli_mesh_overrides_artifact_mesh():
    art = ServeConfig.from_artifact(_fake_artifact(mesh_shape=(2, 4)))
    merged, notes = ServeConfig(mesh=(2, 2)).with_artifact(art)
    assert merged.mesh == (2, 2)
    assert any("overrides" in n for n in notes)


def test_baked_fields_keep_artifact_value_with_note():
    art = ServeConfig.from_artifact(_fake_artifact(recipe="serve-w8a8-kv8"))
    merged, notes = ServeConfig(quantize="none").with_artifact(art)
    assert merged.quantize == "w8a8"         # the weights already ARE w8a8
    assert any("ignored" in n for n in notes)


def test_repro_exports_serve_surface():
    import repro

    assert repro.ServeConfig is ServeConfig
    assert repro.ServeConfigError is ServeConfigError
    assert callable(repro.serve)
