"""Fault-tolerance tests: typed admission errors, deadlines, cancellation,
preemption via page remapping, backpressure, warmup isolation, stragglers.

The anchors:

  * **preempt → resume parity** — a request preempted mid-decode (its pages
    released after remapping the covered prefix into the PrefixIndex) must,
    once resumed, finish with tokens bit-identical to an uncontended run;
  * **tick-exact deadlines** — the fast (horizon-scanned) path must expire a
    request at the same engine tick, with the same partial tokens, as the
    stepwise reference path;
  * **warmup isolation** — ``warmup()`` must leave pool contents, page
    bookkeeping (including free-heap order), the prefix index, and unclaimed
    results bit-identical to its pre-call state;
  * **typed errors** — the new taxonomy must stay catchable by the legacy
    ``ValueError`` / ``RuntimeError`` contracts.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.serving import (
    PoolExhausted,
    QueueFull,
    Request,
    RequestTooLarge,
    ServingEngine,
    ServingError,
)

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def fp32_setup():
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _engine(model, params, cfg, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_horizon", 4)
    return ServingEngine(model, params, cfg, **kw)


def _req(rid, p, g, **kw):
    rng = np.random.RandomState(100 + rid)
    return Request(rid=rid, prompt=rng.randint(0, 64, size=p).astype(np.int32),
                   max_new_tokens=g, **kw)


# ------------------------------------------------------------- typed errors

def test_error_taxonomy_and_legacy_compat(fp32_setup):
    """New typed errors subclass the legacy builtins their call sites used to
    raise, so pre-existing ``except ValueError`` / ``match='cache
    positions'`` contracts keep working."""
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg)
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(_req(0, 8, 99))
    with pytest.raises(RequestTooLarge):
        eng.submit(_req(0, 8, 99))

    paged = _engine(model, params, cfg, page_size=8, num_pages=2)
    with pytest.raises(ValueError, match="pages"):
        paged.submit(_req(0, 17, 4))

    assert issubclass(QueueFull, RuntimeError)
    assert issubclass(PoolExhausted, RuntimeError)
    assert issubclass(RequestTooLarge, ValueError)
    for exc in (QueueFull, PoolExhausted):
        assert exc("x").retryable, f"{exc.__name__} must be retryable"
    assert not RequestTooLarge("x").retryable
    assert issubclass(QueueFull, ServingError)


def test_backpressure_bounded_queue_and_shed_stat(fp32_setup):
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, max_queue=2)
    eng.submit(_req(0, 4, 2))
    eng.submit(_req(1, 4, 2))
    with pytest.raises(QueueFull, match="max_queue=2"):
        eng.submit(_req(2, 4, 2))
    assert eng.stats["shed"] == 1
    res = eng.run()
    assert sorted(res) == [0, 1]
    # queue drained — admission is open again
    eng.submit(_req(3, 4, 2))
    assert eng.run()[3].status == "ok"
    with pytest.raises(ValueError, match="max_queue"):
        _engine(model, params, cfg, max_queue=0)


def test_drain_stops_admission_but_finishes_inflight(fp32_setup):
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, num_slots=1)
    eng.submit(_req(0, 8, 4))
    eng.submit(_req(1, 8, 4))   # queued behind the single slot
    eng.step()                  # rid 0 admitted
    eng.request_drain()
    assert eng.draining
    with pytest.raises(QueueFull, match="draining"):
        eng.submit(_req(2, 4, 2))
    res = eng.run()
    assert 0 in res and res[0].status == "ok"
    assert 1 not in res, "queued request served during drain"
    assert eng.scheduler.pending() == 1


# ----------------------------------------------------------------- deadlines

@pytest.mark.parametrize("paged", [False, True])
def test_deadline_expiry_tick_exact_fast_vs_reference(fp32_setup, paged):
    """Both serve paths must reap an expiring request at the same engine
    tick with the same partial tokens — the fast path's horizon is capped at
    the nearest deadline so it can't overshoot."""
    model, params, cfg = fp32_setup
    kw = {"page_size": 8} if paged else {}
    outs = {}
    for fast in (True, False):
        eng = _engine(model, params, cfg, fast=fast, **kw)
        eng.submit(_req(0, 8, 12, deadline=5.0))
        eng.submit(_req(1, 8, 12))          # no deadline: runs to completion
        res = eng.run()
        outs[fast] = res
        assert res[0].status == "expired"
        assert len(res[0].tokens) < 12
        assert res[1].status == "ok" and len(res[1].tokens) == 12
        assert eng.stats["expired"] == 1
    assert list(outs[True][0].tokens) == list(outs[False][0].tokens)
    assert outs[True][0].finished_at == outs[False][0].finished_at
    assert list(outs[True][1].tokens) == list(outs[False][1].tokens)


def test_deadline_expired_in_queue_is_shed_without_admission(fp32_setup):
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, num_slots=1)
    eng.submit(_req(0, 8, 16))
    eng.submit(_req(1, 8, 12, deadline=3.0))  # will expire while queued
    res = eng.run()
    assert res[1].status == "expired" and res[1].tokens == []
    assert eng.stats["expired"] == 1
    assert res[0].status == "ok" and len(res[0].tokens) == 16


def test_deadline_must_follow_arrival():
    with pytest.raises(ValueError, match="deadline"):
        Request(rid=0, prompt=[1, 2], max_new_tokens=2,
                arrival=5.0, deadline=5.0)


# -------------------------------------------------------------- cancellation

def test_cancel_queued_inflight_and_unknown(fp32_setup):
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, num_slots=1)
    eng.submit(_req(0, 8, 8))
    eng.submit(_req(1, 8, 8))
    assert eng.cancel(1)                      # queued: dropped immediately
    assert eng.results[1].status == "cancelled"
    assert eng.results[1].tokens == []
    eng.step()
    assert eng.cancel(0)                      # inflight: reaped at boundary
    res = eng.run()
    assert res[0].status == "cancelled"
    assert not eng.cancel(999)
    assert eng.stats["cancelled"] == 2


# --------------------------------------------- preemption via page remapping

def test_manual_preempt_resume_is_bit_identical(fp32_setup):
    """The tentpole invariant: preempting an in-flight request (remapping
    its covered prefix into the index, releasing its pages) and resuming it
    later must reproduce the exact token stream of an uncontended run."""
    model, params, cfg = fp32_setup
    trace = [_req(0, 9, 10), _req(1, 5, 6)]

    baseline = _engine(model, params, cfg, page_size=8).run(
        [dataclasses.replace(r) for r in trace])

    eng = _engine(model, params, cfg, page_size=8)
    for r in trace:
        eng.submit(dataclasses.replace(r))
    for _ in range(20):                     # through prefill + first decode
        eng.step()
        if 0 in eng._inflight and eng._inflight[0].generated:
            break
    else:
        raise AssertionError("request never observed mid-decode")
    eng.preempt(0)
    assert eng.stats["preempted"] == 1
    assert 0 not in eng._inflight and len(eng._parked) == 1
    res = eng.run()
    assert eng.stats["resumed"] == 1
    for rid in (0, 1):
        assert res[rid].status == "ok"
        assert list(res[rid].tokens) == list(baseline[rid].tokens), (
            f"rid {rid} diverged after preempt/resume"
        )
    assert res[0].prompt_len == 9, "resume must report the ORIGINAL prompt"

    with pytest.raises(KeyError):
        eng.preempt(123)


def test_starved_pool_preempts_low_priority_and_stays_correct(fp32_setup):
    """Page exhaustion with a higher-priority arrival must walk the ladder
    to preemption, and every request must still finish bit-identical to an
    uncontended (full-pool) run."""
    model, params, cfg = fp32_setup
    trace = [_req(0, 9, 12, priority=0), _req(1, 9, 12, priority=0),
             _req(2, 9, 12, priority=1, arrival=2.0)]

    baseline = _engine(model, params, cfg, page_size=8, num_slots=3).run(
        [dataclasses.replace(r) for r in trace])

    # 8 pages: the two priority-0 requests consume 3 each as they decode,
    # leaving too few for rid 2 without preempting one of them.
    eng = _engine(model, params, cfg, page_size=8, num_slots=3, num_pages=8)
    res = eng.run([dataclasses.replace(r) for r in trace])
    assert eng.stats["preempted"] >= 1 and \
        eng.stats["resumed"] == eng.stats["preempted"]
    for rid in (0, 1, 2):
        assert res[rid].status == "ok"
        assert list(res[rid].tokens) == list(baseline[rid].tokens)
    eng.check_invariants()


def test_preempt_rejects_non_resumable(fp32_setup):
    """A request whose resume-prompt (prompt + generated) would no longer
    fit the ring must not be preemptible — parking it would strand it."""
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, max_len=10, page_size=8,
                  decode_horizon=1)
    # P=8, G=3 needs 10 positions; after 1 generated token the resume-prompt
    # is 9, which pads to 2 prefill chunks (16) — past the 10-position ring.
    eng.submit(_req(0, 8, 3))
    for _ in range(20):
        eng.step()
        if 0 in eng._inflight and eng._inflight[0].generated:
            break
    else:
        raise AssertionError("request never observed mid-decode")
    with pytest.raises(ValueError, match="resum"):
        eng.preempt(0)


# ---------------------------------------------------------- warmup isolation

@pytest.mark.parametrize("paged", [False, True])
def test_warmup_leaves_engine_state_bit_identical(fp32_setup, paged):
    """Regression: warmup() used to leave its compile probes in the cache
    pool and prefix index. It must now restore pool contents (bit-exact),
    page bookkeeping including free-heap ORDER, the index, stats, and any
    unclaimed results."""
    model, params, cfg = fp32_setup
    kw = {"page_size": 8} if paged else {}
    eng = _engine(model, params, cfg, **kw)
    # serve something first so there is real state to pollute
    eng.submit(_req(0, 9, 4))
    eng.submit(_req(1, 9, 4))
    while eng._inflight or eng.scheduler.pending():
        eng.step()

    before_cache = jax.tree.map(np.asarray, eng.pool.cache)
    before = dict(
        stats=dict(eng.stats), clock=eng.clock,
        free=set(eng.pool._free), allocated=set(eng.pool._allocated),
        results={r: res.tokens for r, res in eng.results.items()},
    )
    if paged:
        before.update(
            free_pages=list(eng.pool._free_pages),
            page_ref=list(eng.pool._page_ref),
            slot_pages={s: list(p) for s, p in eng.pool._slot_pages.items()},
            index_keys=set(eng.prefix_index._map),
        )

    eng.warmup()

    after_cache = jax.tree.map(np.asarray, eng.pool.cache)
    for a, b in zip(jax.tree.leaves(before_cache),
                    jax.tree.leaves(after_cache)):
        np.testing.assert_array_equal(a, b)
    assert dict(eng.stats) == before["stats"]
    assert eng.clock == before["clock"]
    assert set(eng.pool._free) == before["free"]
    assert set(eng.pool._allocated) == before["allocated"]
    assert {r: res.tokens for r, res in eng.results.items()} \
        == before["results"]
    if paged:
        assert list(eng.pool._free_pages) == before["free_pages"]
        assert list(eng.pool._page_ref) == before["page_ref"]
        assert {s: list(p) for s, p in eng.pool._slot_pages.items()} \
            == before["slot_pages"]
        assert set(eng.prefix_index._map) == before["index_keys"]
        eng.check_invariants()

    # and the engine still serves correctly afterwards
    res = eng.run([_req(2, 9, 4)])
    assert res[2].status == "ok" and len(res[2].tokens) == 4


# ------------------------------------------------------------- NaN quarantine

def test_injected_bad_logits_quarantine_without_poisoning_peers(fp32_setup):
    model, params, cfg = fp32_setup
    trace = [_req(0, 8, 6), _req(1, 8, 6)]
    baseline = _engine(model, params, cfg).run(
        [dataclasses.replace(r) for r in trace])

    eng = _engine(model, params, cfg)
    for r in trace:
        eng.submit(dataclasses.replace(r))
    eng.inject_bad(0)
    res = eng.run()
    assert res[0].status == "quarantined"
    assert eng.stats["quarantined"] == 1
    assert res[1].status == "ok"
    assert list(res[1].tokens) == list(baseline[1].tokens)


# ------------------------------------------------------------------ straggler

class _AlwaysSlow:
    def observe(self, step, dt):
        return True


def test_straggler_monitor_counts_slow_steps(fp32_setup):
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, straggler=_AlwaysSlow())
    eng.run([_req(0, 8, 4)])
    # one observation per step() call; engine_steps counts horizon TICKS,
    # so the flagged count is positive but never exceeds the tick count
    assert 0 < eng.stats["straggler_steps"] <= eng.stats["engine_steps"]

    # the real monitor: flags only multiples of the EMA past warmup
    mon = StragglerMonitor(threshold=2.0, warmup_steps=1)
    assert not mon.observe(0, 1.0)       # warmup
    assert not mon.observe(1, 1.0)       # seeds the EMA
    assert mon.observe(2, 10.0)          # 10x the EMA
    assert not mon.observe(3, 1.0)       # slow step didn't poison the EMA
