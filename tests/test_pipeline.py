"""Pipeline subsystem: recipe validation, stage-registry dispatch,
QuantizedModel save/load, and parity with the legacy call chains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs import get_config
from repro.core import DFQConfig, apply_dfq, bias_correct, dfq_quantize, quantize_weights
from repro.data import calibration_tokens
from repro.models import build_model
from repro.pipeline import (
    QuantizedModel,
    Recipe,
    RecipeError,
    RecipeStep,
    default_calibration,
    list_recipes,
    list_stages,
    quantize,
    register_stage,
    resolve_recipe,
    unregister_stage,
)
from repro.quantized import QTensor, quantize_for_serving


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _leaves(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, QTensor))


# ---------------------------------------------------------------- validation

def test_unknown_recipe_name_error():
    with pytest.raises(RecipeError, match="dfq-int8"):
        resolve_recipe("dfq-int9")


def test_unknown_stage_error_suggests_and_lists():
    r = Recipe("bad", (RecipeStep("clee", {}),))
    with pytest.raises(RecipeError) as e:
        r.validate()
    msg = str(e.value)
    assert "did you mean 'cle'" in msg
    assert "weight_quant" in msg  # lists the registered stages


def test_unknown_option_error_lists_allowed():
    r = Recipe("bad", (RecipeStep("pack", {"modee": "w8a16"}),))
    with pytest.raises(RecipeError, match="modee"):
        r.validate()
    with pytest.raises(RecipeError, match="mode"):
        r.validate()


def test_empty_recipe_error():
    with pytest.raises(RecipeError, match="no stages"):
        Recipe("empty", ()).validate()


def test_with_options_unknown_stage_error():
    r = resolve_recipe("serve-w8a16")
    with pytest.raises(RecipeError, match="weight_quant"):
        r.with_options({"weight_quant": {"bits": 4}})


def test_builtin_recipes_validate():
    for name in list_recipes():
        resolve_recipe(name).validate()


# ------------------------------------------------------------------ registry

def test_registry_dispatch_custom_stage(setup):
    cfg, model, params = setup

    @register_stage("test_tag_stage", tag="default")
    def test_tag_stage(state, ctx, *, tag):
        state.note(tag=tag)
        return state

    try:
        qm = quantize(
            model, params=params,
            recipe=[("test_tag_stage", {"tag": "hello"}), "weight_quant"],
            calibration=None,
        )
        rec = qm.stage_record("test_tag_stage")
        assert rec is not None and rec["metrics"]["tag"] == "hello"
        assert "test_tag_stage" in list_stages()
    finally:
        unregister_stage("test_tag_stage")
    assert "test_tag_stage" not in list_stages()


# -------------------------------------------------------------------- parity

def test_dfq_quantize_wrapper_delegates_to_pipeline(setup):
    """dfq_quantize (now a thin wrapper) ≡ quantize(recipe='dfq-int8')."""
    cfg, model, params = setup
    plan = model.dfq_plan()
    legacy = dfq_quantize(
        params, plan, DFQConfig(),
        input_means_fn=default_calibration(model, cfg),
    )
    qm = quantize(model, params=params, recipe="dfq-int8")
    for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(qm.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dfq_int8_matches_handrolled_chain(setup):
    """The acceptance bar: staged execution reproduces the original
    hand-assembled apply_dfq → bias_correct → quantize_weights chain
    bit-for-bit (this is the true legacy reference — dfq_quantize itself
    now delegates to the pipeline, so comparing against it would be
    circular)."""
    cfg, model, params = setup
    plan = model.dfq_plan()
    eq = apply_dfq(params, plan, DFQConfig())
    means = default_calibration(model, cfg)(eq)
    ref = quantize_weights(
        bias_correct(eq, plan, DFQConfig(), means), plan, DFQConfig()
    )
    qm = quantize(model, params=params, recipe="dfq-int8")
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(qm.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weight_quant_override_reaches_bias_correct_epsilon(setup):
    """A per-stage bits override must also drive the ε = fq(W) − W used by
    bias_correct — one quant spec for the whole recipe."""
    cfg, model, params = setup
    plan = model.dfq_plan()
    cfg4 = DFQConfig(weight_bits=4)
    eq = apply_dfq(params, plan, cfg4)
    means = default_calibration(model, cfg)(eq)
    ref = quantize_weights(bias_correct(eq, plan, cfg4, means), plan, cfg4)
    qm = quantize(
        model, params=params, recipe="dfq-int8",
        stage_options={"weight_quant": {"bits": 4}},
    )
    assert qm.stage_record("weight_quant")["metrics"]["bits"] == 4
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(qm.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_w8a16_matches_legacy_serving_path(setup):
    """'serve-w8a16' ≡ apply_dfq + quantize_for_serving."""
    cfg, model, params = setup
    plan = model.dfq_plan()
    legacy = quantize_for_serving(
        apply_dfq(params, plan, DFQConfig()), plan, mode="w8a16"
    )
    qm = quantize(model, params=params, recipe="serve-w8a16", calibration=None)
    for a, b in zip(_leaves(legacy), _leaves(qm.params)):
        if isinstance(a, QTensor):
            assert isinstance(b, QTensor) and a.mode == b.mode
            np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
            np.testing.assert_allclose(
                np.asarray(a.scale), np.asarray(b.scale), rtol=1e-6
            )
        else:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6
            )


def test_naive_int8_matches_quantize_weights(setup):
    cfg, model, params = setup
    plan = model.dfq_plan()
    ref = quantize_weights(
        params, plan, DFQConfig(cle=False, bias_absorb=False)
    )
    qm = quantize(model, params=params, recipe="naive-int8", calibration=None)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(qm.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- entrypoint

def test_quantize_by_arch_string():
    qm = repro.quantize("qwen2-0.5b-smoke", recipe="naive-int8",
                        calibration=None)
    assert isinstance(qm, QuantizedModel)
    assert qm.cfg.name == "qwen2-0.5b-smoke"
    assert [r["stage"] for r in qm.report] == ["weight_quant"]


def test_report_carries_per_site_weight_sqnr(setup):
    cfg, model, params = setup
    qm = quantize(model, params=params, recipe="dfq-int8")
    snr = qm.site_sqnr_db()
    assert set(snr) == {s.name for s in model.dfq_plan().sites}
    assert all(np.isfinite(v) for v in snr.values())


def test_act_ranges_stage_records_ranges(setup):
    cfg, model, params = setup
    qm = quantize(
        model, params=params,
        recipe=["fold_norm", "cle", "act_ranges"],
    )
    rec = qm.stage_record("act_ranges")
    assert rec is not None
    ranges = rec["metrics"]["ranges"]
    assert ranges, "expected at least one activation range"
    for lo, hi in ranges.values():
        assert lo < hi
    # the machine-readable QParams reach the artifact
    assert set(qm.act_qparams) == set(ranges)
    for qp in qm.act_qparams.values():
        assert float(jnp.min(qp.scale)) > 0


def test_quantized_model_serves_prefill_decode(setup):
    cfg, model, params = setup
    qm = quantize(model, params=params, recipe="serve-w8a16",
                  calibration=None)
    toks = calibration_tokens(0, 2, 8, cfg.vocab_size)
    cache = qm.init_cache(2, 16, dtype=jnp.float32)
    logits, cache = qm.prefill(toks, cache)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, _ = qm.decode_step(tok, cache)
    assert logits2.shape == (2, cfg.vocab_size)


# --------------------------------------------------------------- persistence

def test_save_load_roundtrip_preserves_outputs(setup, tmp_path):
    cfg, model, params = setup
    qm = quantize(model, params=params, recipe="serve-w8a16",
                  calibration=None)
    toks = calibration_tokens(0, 2, 16, cfg.vocab_size)
    y0, _ = qm.apply(toks)

    d = str(tmp_path / "artifact")
    qm.save(d)
    qm2 = QuantizedModel.load(d)

    assert qm2.recipe.name == "serve-w8a16"
    assert [r["stage"] for r in qm2.report] == [r["stage"] for r in qm.report]
    assert qm2.cfg == cfg
    y1, _ = qm2.apply(toks)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_save_load_roundtrip_fake_quant(setup, tmp_path):
    cfg, model, params = setup
    qm = quantize(model, params=params, recipe="dfq-int8")
    d = str(tmp_path / "fq")
    qm.save(d)
    qm2 = QuantizedModel.load(d)
    for a, b in zip(jax.tree.leaves(qm.params), jax.tree.leaves(qm2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_missing_dir_actionable_error(tmp_path):
    from repro.pipeline import PipelineError

    with pytest.raises(PipelineError, match="quantized_model.json"):
        QuantizedModel.load(str(tmp_path / "nope"))
