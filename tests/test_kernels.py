"""Kernel validation: shape/dtype sweeps against the pure-jnp oracles in
interpret mode (CPU executes the kernel body; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.qmatmul_w8a8.ops import qmatmul_w8a8
from repro.kernels.qmatmul_w8a8.ref import qmatmul_w8a8_ref
from repro.kernels.qmatmul_w8a16.ops import qmatmul_w8a16
from repro.kernels.qmatmul_w8a16.ref import qmatmul_w8a16_ref
from repro.kernels.quantize_act.ops import quantize_act
from repro.kernels.quantize_act.ref import quantize_act_ref


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -127, 128, dtype=jnp.int8)


W8A8_SHAPES = [
    (8, 64, 32),       # tiny, exercises padding (below block sizes)
    (128, 512, 128),   # exactly one block
    (256, 1024, 384),  # multi-block M/K/N
    (100, 300, 200),   # ragged everything
]


@pytest.mark.parametrize("M,K,N", W8A8_SHAPES)
def test_w8a8_matches_ref(M, K, N):
    ks = jax.random.split(jax.random.PRNGKey(M + K + N), 5)
    a_q = _rand_int8(ks[0], (M, K))
    w_q = _rand_int8(ks[1], (K, N))
    a_s = jax.random.uniform(ks[2], (M,), minval=0.01, maxval=0.1)
    w_s = jax.random.uniform(ks[3], (N,), minval=0.01, maxval=0.1)
    bias = jax.random.normal(ks[4], (N,))
    ref = qmatmul_w8a8_ref(a_q, w_q, a_s, w_s, bias)
    out = qmatmul_w8a8(a_q, w_q, a_s, w_s, bias, backend="interpret",
                       bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_w8a8_scalar_scales_and_no_bias():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a_q = _rand_int8(ks[0], (64, 256))
    w_q = _rand_int8(ks[1], (256, 128))
    ref = qmatmul_w8a8_ref(a_q, w_q, jnp.float32(0.02), jnp.float32(0.03))
    out = qmatmul_w8a8(a_q, w_q, 0.02, 0.03, backend="interpret", bk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_w8a8_int32_accumulation_exact():
    """Saturating inputs: accumulation must be exact int32, not fp."""
    a_q = jnp.full((128, 512), 127, jnp.int8)
    w_q = jnp.full((512, 128), 127, jnp.int8)
    out = qmatmul_w8a8(a_q, w_q, 1.0, 1.0, backend="interpret", bk=128)
    assert float(out[0, 0]) == 127 * 127 * 512


def test_w8a8_asymmetric_zero_point():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    M, K, N = 32, 128, 64
    a = jax.random.uniform(ks[0], (M, K), minval=0.0, maxval=4.0)  # all-positive
    w_q = _rand_int8(ks[1], (K, N))
    # asymmetric per-row quantization of a
    qmax = 255.0
    amin = jnp.zeros((M,))
    amax = jnp.max(a, axis=1)
    scale = amax / qmax
    zp = jnp.zeros((M,))
    a_q = jnp.clip(jnp.round(a / scale[:, None]), 0, 255) - 128  # shift to int8
    zp_eff = -128.0 * jnp.ones((M,))
    out = qmatmul_w8a8(a_q.astype(jnp.int8), w_q, scale, 0.05,
                       a_zero_point=zp_eff, backend="interpret", bk=128)
    direct = ((a_q - zp_eff[:, None]) * scale[:, None]) @ (
        w_q.astype(jnp.float32) * 0.05
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct), rtol=1e-4, atol=1e-4)


W8A16_SHAPES = [(1, 512, 256), (8, 1024, 512), (17, 300, 130), (128, 2048, 1024)]


@pytest.mark.parametrize("M,K,N", W8A16_SHAPES)
@pytest.mark.parametrize("adtype", [jnp.bfloat16, jnp.float32])
def test_w8a16_matches_ref(M, K, N, adtype):
    ks = jax.random.split(jax.random.PRNGKey(M * N), 3)
    a = jax.random.normal(ks[0], (M, K)).astype(adtype)
    w_q = _rand_int8(ks[1], (K, N))
    w_s = jax.random.uniform(ks[2], (N,), minval=0.001, maxval=0.05)
    ref = qmatmul_w8a16_ref(a, w_q, w_s, out_dtype=jnp.float32)
    out = qmatmul_w8a16(a, w_q, w_s, backend="interpret", out_dtype=jnp.float32)
    # blocked K accumulation reorders fp sums → rtol plus a small atol floor
    rtol, atol = (2e-2, 2.0) if adtype == jnp.bfloat16 else (1e-3, 1e-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=rtol, atol=atol)


@pytest.mark.parametrize("M,K", [(1, 128), (100, 896), (128, 4096), (7, 333)])
@pytest.mark.parametrize("bits", [8, 6])
def test_quantize_act_matches_ref(M, K, bits):
    x = jax.random.normal(jax.random.PRNGKey(M), (M, K)) * 3.0
    q_ref, s_ref = quantize_act_ref(x, bits)
    q, s = quantize_act(x, bits=bits, backend="interpret")
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))


def test_quantize_then_matmul_roundtrip_close_to_fp():
    """End-to-end dynamic W8A8 ≈ fp32 matmul within int8 noise."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = jax.random.normal(ks[0], (64, 512))
    w = jax.random.normal(ks[1], (512, 256)) * 0.05
    from repro.core import QuantSpec, compute_qparams, quantize

    wq_params = compute_qparams(w, QuantSpec(bits=8, symmetric=True))
    w_q = quantize(w, wq_params)
    a_q, a_s = quantize_act(x, backend="interpret")
    y = qmatmul_w8a8(a_q, w_q, a_s, wq_params.scale, backend="interpret", bk=128)
    y_fp = x @ w
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.02


def test_kernel_grid_block_sweep():
    """Sweep block shapes — any legal tiling must give identical results."""
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a_q = _rand_int8(ks[0], (256, 512))
    w_q = _rand_int8(ks[1], (512, 256))
    ref = qmatmul_w8a8_ref(a_q, w_q, 0.01, 0.02)
    for bm, bn, bk in [(64, 64, 128), (128, 256, 256), (256, 128, 512)]:
        out = qmatmul_w8a8(a_q, w_q, 0.01, 0.02, backend="interpret",
                           bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
