"""Appendix C closed forms vs Monte Carlo, including property-based sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import clipped_normal_mean, clipped_normal_var, relu_normal_mean


def _mc(mu, sigma, a, b, n=400000, seed=0):
    x = mu + sigma * jax.random.normal(jax.random.PRNGKey(seed), (n,))
    y = jnp.clip(x, a, b if b is not None else jnp.inf)
    return float(jnp.mean(y)), float(jnp.var(y))


@settings(max_examples=25, deadline=None)
@given(
    mu=st.floats(-3, 3),
    sigma=st.floats(0.1, 3),
    a=st.floats(-2, 0.5),
    width=st.floats(0.5, 6),
)
def test_clipped_moments_match_mc(mu, sigma, a, width):
    b = a + width
    m_cf = float(clipped_normal_mean(jnp.float32(mu), jnp.float32(sigma), a, b))
    v_cf = float(clipped_normal_var(jnp.float32(mu), jnp.float32(sigma), a, b))
    m_mc, v_mc = _mc(mu, sigma, a, b)
    assert abs(m_cf - m_mc) < 0.02 * max(1.0, abs(m_mc))
    assert abs(v_cf - v_mc) < 0.05 * max(0.05, v_mc)


@settings(max_examples=25, deadline=None)
@given(mu=st.floats(-3, 3), sigma=st.floats(0.1, 3))
def test_relu_case_matches_open_interval(mu, sigma):
    """b = ∞ limit equals eq. 19."""
    lhs = float(relu_normal_mean(jnp.float32(mu), jnp.float32(sigma)))
    rhs = float(clipped_normal_mean(jnp.float32(mu), jnp.float32(sigma), 0.0, None))
    assert abs(lhs - rhs) < 1e-5


def test_degenerate_limits():
    # far-left clip: mean → a
    m = float(clipped_normal_mean(jnp.float32(-100.0), jnp.float32(1.0), 0.0, 6.0))
    assert abs(m - 0.0) < 1e-4
    # far-right: mean → b
    m = float(clipped_normal_mean(jnp.float32(100.0), jnp.float32(1.0), 0.0, 6.0))
    assert abs(m - 6.0) < 1e-4
    # wide interval: mean → μ, var → σ²
    m = float(clipped_normal_mean(jnp.float32(0.3), jnp.float32(1.0), -50.0, 50.0))
    v = float(clipped_normal_var(jnp.float32(0.3), jnp.float32(1.0), -50.0, 50.0))
    assert abs(m - 0.3) < 1e-4 and abs(v - 1.0) < 1e-3


def test_variance_nonnegative_extremes():
    v = clipped_normal_var(jnp.float32(50.0), jnp.float32(0.1), 0.0, 6.0)
    assert float(v) >= 0.0
