"""Optional-import shim for hypothesis.

Uses the real library when installed; otherwise provides a tiny deterministic
fallback (seeded uniform sampling, capped example count) so property tests
still collect and run green without the dependency.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES,
                )
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            wrapper.hypothesis_fallback = True
            # pytest must not mistake the drawn arguments for fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn

        return deco
