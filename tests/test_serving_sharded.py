"""Tensor-parallel sharded serving tests (the tier1-multidevice CI job).

Anchor: on an 8-virtual-device CPU mesh the sharded ``ServingEngine`` must
produce the SAME tokens as the single-device engine — for fp32, the
serve-w8a16-tp recipe, and the full-int8 serve-w8a8-kv8-tp recipe. Slot
sharding is exact by construction (every slot's computation is
row-independent); TP's row-parallel psum reorders float reductions, so raw
logits carry a pinned tolerance (test_tp_logits_within_pinned_tolerance)
while greedy argmax — and therefore every generated token — must not move.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
tier1-multidevice job); skips, rather than fails, on a single-device host so
plain tier1 stays runnable anywhere.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.serving import Request, ServingEngine
from jax.sharding import PartitionSpec as P

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

ARCH = "qwen2-0.5b"
VARIANTS = ["fp32", "serve-w8a16-tp", "serve-w8a8-kv8-tp"]


@pytest.fixture(scope="module")
def mesh():
    return make_production_mesh(shape=(2, 4))


@pytest.fixture(scope="module")
def fp32_setup():
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


@pytest.fixture(scope="module")
def tp_artifacts(fp32_setup):
    model, params, _ = fp32_setup
    return {
        name: repro.quantize(model, params=params, recipe=name)
        for name in VARIANTS[1:]
    }


def _setup(variant, fp32_setup, tp_artifacts):
    if variant == "fp32":
        return fp32_setup
    qm = tp_artifacts[variant]
    return qm.model, qm.params, qm.cfg


def _mixed_trace(vocab):
    rng = np.random.RandomState(7)
    lens = [(5, 6), (12, 3), (3, 1), (9, 8)]  # includes a gen-at-prefill edge
    return [
        Request(rid=i, prompt=rng.randint(0, vocab, size=p).astype(np.int32),
                max_new_tokens=g)
        for i, (p, g) in enumerate(lens)
    ]


def _engine(model, params, cfg, **kw):
    kw.setdefault("num_slots", 2)   # < len(trace): forces slot recycling
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(model, params, cfg, **kw)


def _tokens(engine, trace):
    out = engine.run([dataclasses.replace(r) for r in trace])
    return {rid: r.tokens for rid, r in out.items()}


# ----------------------------------------------------- sharded-vs-single

@pytest.mark.parametrize("variant", VARIANTS)
def test_sharded_engine_token_parity(variant, fp32_setup, tp_artifacts, mesh):
    """The acceptance anchor: sharded == single-device, token for token,
    through slot recycling and the gen-at-prefill edge."""
    model, params, cfg = _setup(variant, fp32_setup, tp_artifacts)
    trace = _mixed_trace(cfg.vocab_size)
    single = _tokens(_engine(model, params, cfg), trace)
    sharded = _tokens(_engine(model, params, cfg, mesh=mesh), trace)
    assert sharded == single, f"{variant}: sharded tokens diverged"
    for r in trace:
        assert len(sharded[r.rid]) == r.max_new_tokens


@pytest.mark.parametrize("variant", ["fp32", "serve-w8a8-kv8-tp"])
def test_sharded_fast_vs_stepwise_parity(variant, fp32_setup, tp_artifacts,
                                         mesh):
    """The PR-3 fast-path contract survives sharding: fused horizons +
    batched prefill under the mesh == the sharded stepwise reference."""
    model, params, cfg = _setup(variant, fp32_setup, tp_artifacts)
    trace = _mixed_trace(cfg.vocab_size)
    fast = _tokens(_engine(model, params, cfg, mesh=mesh, fast=True), trace)
    slow = _tokens(_engine(model, params, cfg, mesh=mesh, fast=False), trace)
    assert fast == slow


def test_sharded_non_divisible_slots_replicate_and_match(fp32_setup, mesh):
    """num_slots=3 doesn't divide data=2: the pool replicates (graceful
    degradation) and tokens still match the single-device engine."""
    model, params, cfg = fp32_setup
    trace = _mixed_trace(cfg.vocab_size)
    kw = dict(num_slots=3)
    single = _tokens(_engine(model, params, cfg, **kw), trace)
    eng = _engine(model, params, cfg, mesh=mesh, **kw)
    assert eng.pool.cache["k"].sharding.spec == P(None, None, None, None, None)
    assert _tokens(eng, trace) == single


def test_tp_logits_within_pinned_tolerance(fp32_setup, mesh):
    """Where TP legitimately differs: the row-parallel wo/wd psum reorders
    float reductions, so sharded prefill logits wobble at float precision.
    Pin the tolerance — and that the greedy argmax does not move."""
    model, params, cfg = fp32_setup
    heads = {"n_q": cfg.n_heads, "n_kv": cfg.n_kv_heads}
    from repro.sharding import named_shardings, params_pspecs

    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    sharded_params = jax.device_put(
        params, named_shardings(params_pspecs(shapes, mesh, heads,
                                              mode="serve"), mesh))
    tokens = jnp.asarray(
        np.random.RandomState(3).randint(0, cfg.vocab_size, size=(1, 8)),
        jnp.int32)

    def prefill(p):
        cache = model.init_cache(1, 16, dtype=jnp.float32, per_slot=True)
        logits, _ = model.prefill(p, tokens, cache)
        return logits

    ref = np.asarray(jax.jit(prefill)(params))
    got = np.asarray(jax.jit(prefill)(sharded_params))
    np.testing.assert_allclose(got, ref, atol=2e-5, rtol=1e-5)
    assert np.array_equal(np.argmax(got, -1), np.argmax(ref, -1))


# ------------------------------------------------------ placement contracts

def test_sharded_pool_and_param_placement(tp_artifacts, mesh):
    """End-to-end placement over a REAL mesh: kv8 scale/v_err leaves follow
    their payload, slots shard over data, int8 weights TP over model with
    tied embeddings vocab-parallel."""
    qm = tp_artifacts["serve-w8a8-kv8-tp"]
    eng = _engine(qm.model, qm.params, qm.cfg, mesh=mesh, num_slots=4)
    cache = eng.pool.cache
    assert cache["k"].sharding.spec == P(None, "data", None, None, None)
    for leaf in ("k_scale", "v_scale"):
        assert cache[leaf].sharding.spec == P(None, "data", None, None)
    assert cache["kpos"].sharding.spec == P("data", None)
    assert cache["pos"].sharding.spec == P("data")
    wu = eng.params["blocks"]["mlp"]["wu"]
    assert wu.q.sharding.spec == P(None, None, "model")     # column-parallel
    wd = eng.params["blocks"]["mlp"]["wd"]
    assert wd.q.sharding.spec == P(None, "model", None)     # row-parallel
    assert eng.params["embed"].sharding.spec == P("model", None)


def test_sharded_cache_donation_preserved(fp32_setup, mesh):
    """Donation must survive the pinned out_shardings: after a run, the
    pre-run pooled cache buffer has been consumed in place, not copied."""
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, mesh=mesh)
    pre = eng.pool.cache["k"]
    eng.run(_mixed_trace(cfg.vocab_size))
    assert pre.is_deleted()


# ------------------------------------------------------- artifact round trip

def test_tp_artifact_save_load_serve_round_trip(tp_artifacts, mesh, tmp_path):
    """quantize → save(mesh) → load → serve: the artifact records the
    parallelism plan + concrete specs, and the restored engine reproduces
    the pre-save tokens on the recorded topology."""
    qm = tp_artifacts["serve-w8a16-tp"]
    trace = _mixed_trace(qm.cfg.vocab_size)
    before = _tokens(_engine(qm.model, qm.params, qm.cfg, mesh=mesh), trace)

    from repro.pipeline import QuantizedModel

    qm.save(str(tmp_path), mesh=mesh)
    loaded = QuantizedModel.load(str(tmp_path))
    assert loaded.shard_mode == "tp"
    assert loaded.sharding["mesh_shape"] == [2, 4]
    assert loaded.sharding["mesh_axes"] == ["data", "model"]
    specs = loaded.sharding["specs"]
    # int8 payload and scale recorded on the same TP axis
    assert "'model'" in specs["/blocks/mlp/wu/q"]
    assert specs["/blocks/attn/wo/scale"] == "PartitionSpec(None, None)"

    restored_mesh = make_production_mesh(
        shape=tuple(loaded.sharding["mesh_shape"]))
    eng = ServingEngine.from_quantized(
        loaded, mesh=restored_mesh, num_slots=2, max_len=32, prefill_chunk=8)
    assert _tokens(eng, trace) == before


# ------------------------------------------------- shard_map decode kernel

def test_shard_map_decode_engages_and_matches(fp32_setup, monkeypatch):
    """On a mesh whose model axis divides BOTH head counts (2x2: Hq=4,
    Hkv=2), the int8-KV decode hot path routes through the shard_map'd fused
    kernel (head-local attention, zero collectives in the body) — and the
    tokens still match the single-device engine bit for bit."""
    from repro.models import layers

    model, params, cfg = fp32_setup
    assert cfg.n_heads % 2 == 0 and cfg.n_kv_heads % 2 == 0
    trace = _mixed_trace(cfg.vocab_size)
    single = _tokens(_engine(model, params, cfg, kv_bits=8), trace)

    calls = []
    real = layers._fused_decode_tp

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(layers, "_fused_decode_tp", counting)
    small = make_production_mesh(shape=(2, 2))
    sharded = _tokens(_engine(model, params, cfg, mesh=small, kv_bits=8),
                      trace)
    assert calls, "shard_map decode path never engaged on the 2x2 mesh"
    assert sharded == single


def test_shard_map_decode_guard_disengages_on_indivisible_heads(fp32_setup,
                                                                monkeypatch,
                                                                mesh):
    """model=4 does not divide n_kv_heads=2: the guard must fall back to the
    replicated decode path rather than shard_map a ragged head split."""
    from repro.models import layers

    model, params, cfg = fp32_setup
    assert cfg.n_kv_heads % mesh.shape["model"] != 0
    calls = []
    real = layers._fused_decode_tp

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(layers, "_fused_decode_tp", counting)
    trace = _mixed_trace(cfg.vocab_size)
    _tokens(_engine(model, params, cfg, mesh=mesh, kv_bits=8), trace)
    assert not calls


# ---------------------------------------------------------------- mesh ctor

def test_make_production_mesh_shape_override():
    m = make_production_mesh(shape=(1, 8))
    assert m.axis_names == ("data", "model")
    assert dict(m.shape) == {"data": 1, "model": 8}
    m3 = make_production_mesh(shape=(2, 2, 2))
    assert m3.axis_names == ("pod", "data", "model")
