"""Integration test of the distribution stack: lower + compile sharded
train/prefill/decode programs on a multi-device mesh (8 fake CPU devices,
(2, 4) data×model mesh) for representative smoke archs.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import ShapeConfig
from repro.models.model import input_specs
from repro.launch.steps import (
    configure_sharding_hints, make_decode_step, make_train_step, shardings_for)

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
for arch in ["qwen2-0.5b", "mixtral-8x22b", "mamba2-2.7b", "whisper-tiny"]:
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, d_model=128, d_ff=256, vocab_size=512,
                              n_heads=4, n_kv_heads=2, head_dim=32)
    train_shape = ShapeConfig("t", 32, 8, "train")
    sh = shardings_for(cfg, train_shape, mesh)
    configure_sharding_hints(cfg, mesh)
    model, train_step = make_train_step(cfg)
    specs = input_specs(cfg, train_shape)
    batch = {"tokens": specs["tokens"], "labels": specs["labels"]}
    if cfg.is_encdec:
        batch["frames"] = specs["frames"]
    with mesh:
        c = jax.jit(train_step, in_shardings=(
            sh["params"], sh["opt"],
            {k: (sh["frames"] if k == "frames" else sh["batch"]) for k in batch},
        )).lower(sh["params_shape"], sh["opt_shape"], batch).compile()
    ma = c.memory_analysis()
    out[arch + ".train"] = int(ma.temp_size_in_bytes)

    dec_shape = ShapeConfig("d", 64, 8, "decode")
    sh = shardings_for(cfg, dec_shape, mesh)
    model, decode_step = make_decode_step(cfg)
    specs = input_specs(cfg, dec_shape)
    with mesh:
        c = jax.jit(decode_step, in_shardings=(
            sh["params"], sh["cache"], sh["batch"])).lower(
            sh["params_shape"], sh["cache_shape"], specs["token"]).compile()
    out[arch + ".decode"] = int(c.memory_analysis().temp_size_in_bytes)
print(json.dumps(out))
"""


@pytest.mark.slow
def test_multidevice_lower_compile():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(out) == 8
    for k, v in out.items():
        assert v >= 0
