import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantSpec,
    channel_precision,
    channel_ranges,
    compute_qparams,
    dequantize,
    fake_quant,
    qparams_from_range,
    quantize,
    sqnr_db,
)


@pytest.mark.parametrize("bits", [4, 6, 8])
@pytest.mark.parametrize("symmetric", [True, False])
def test_roundtrip_error_bounded(bits, symmetric):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 32)) * 3.0
    spec = QuantSpec(bits=bits, symmetric=symmetric)
    qp = compute_qparams(x, spec)
    err = jnp.abs(dequantize(quantize(x, qp), qp) - x)
    # every in-range value must be within half a quantization step
    assert float(jnp.max(err)) <= float(jnp.max(qp.scale)) * 0.5 + 1e-6


def test_asymmetric_grid_contains_zero():
    x = jnp.linspace(2.0, 5.0, 100)  # all-positive tensor
    spec = QuantSpec(bits=8, symmetric=False)
    qp = compute_qparams(x, spec)
    zero_hat = dequantize(quantize(jnp.zeros(()), qp), qp)
    assert abs(float(zero_hat)) < 1e-6


def test_per_channel_beats_per_tensor_on_spread_ranges():
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (64, 16)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(2), (16,)) * 2.0
    )
    pt = fake_quant(w, QuantSpec(bits=8))
    pc = fake_quant(w, QuantSpec(bits=8, per_channel_axis=-1))
    assert float(sqnr_db(w, pc)) > float(sqnr_db(w, pt)) + 5.0


def test_int8_symmetric_dtype_and_range():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    spec = QuantSpec(bits=8, symmetric=True)
    q = quantize(x, compute_qparams(x, spec))
    assert q.dtype == jnp.int8
    assert int(jnp.min(q)) >= -128 and int(jnp.max(q)) <= 127


def test_qparams_from_range_matches_minmax():
    x = jax.random.normal(jax.random.PRNGKey(3), (1000,))
    spec = QuantSpec(bits=8, symmetric=False)
    a = compute_qparams(x, spec)
    b = qparams_from_range(jnp.min(x), jnp.max(x), spec)
    np.testing.assert_allclose(np.asarray(a.scale), np.asarray(b.scale), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a.zero_point), np.asarray(b.zero_point))


def test_channel_ranges_and_precision():
    w = jnp.array([[1.0, -4.0], [2.0, 0.5]])
    r = channel_ranges(w, -1)
    np.testing.assert_allclose(np.asarray(r), [2.0, 4.0])
    p = channel_precision(w, -1)
    np.testing.assert_allclose(np.asarray(p), [0.5, 1.0])


def test_bitwidth_monotonic_sqnr():
    x = jax.random.normal(jax.random.PRNGKey(4), (256, 64))
    snrs = [float(sqnr_db(x, fake_quant(x, QuantSpec(bits=b)))) for b in (4, 6, 8, 12)]
    assert snrs == sorted(snrs)
