"""End-to-end pin of the ``serving.errors`` retryable contract.

The async client branches on exactly one bit — ``ServingError.retryable`` —
so this file pins that bit for every class in the taxonomy and proves the
client honors it: every retryable class round-trips through the retry path
(rejection → backoff → resubmission → success), every non-retryable class
fails fast on the first raise, and exhausted retries surface as a ``shed``
outcome. A scripted in-memory server stands in for the engine so each error
class can be injected directly at the admission surface; the real-engine
round trips (QueueFull under a bounded queue, breaker trips, overload
sheds) live in ``test_serving_async.py``.
"""
import asyncio
import dataclasses

import numpy as np
import pytest

from repro.serving import (
    AsyncClient,
    CircuitOpen,
    DeadlineExceeded,
    PoolExhausted,
    QueueFull,
    Request,
    RequestCancelled,
    RequestStream,
    RequestTooLarge,
    RetryPolicy,
    ServerOverloaded,
    ServingError,
    taxonomy,
)

# THE pin: adding an error class, or flipping a retryable flag, must fail
# here and be updated deliberately — the client's behavior hangs off it
EXPECTED_TAXONOMY = {
    "ServingError": False,
    "RequestTooLarge": False,
    "QueueFull": True,
    "PoolExhausted": True,
    "RequestCancelled": False,
    "DeadlineExceeded": False,
    "CircuitOpen": True,
    "ServerOverloaded": True,
}

BY_NAME = {
    "ServingError": ServingError,
    "RequestTooLarge": RequestTooLarge,
    "QueueFull": QueueFull,
    "PoolExhausted": PoolExhausted,
    "RequestCancelled": RequestCancelled,
    "DeadlineExceeded": DeadlineExceeded,
    "CircuitOpen": CircuitOpen,
    "ServerOverloaded": ServerOverloaded,
}


def test_taxonomy_pinned_exactly():
    assert taxonomy() == EXPECTED_TAXONOMY


def test_legacy_isa_compat():
    """The pre-taxonomy engine raised bare builtins; the IS-A bridges are
    load-bearing for external callers and old tests."""
    assert issubclass(RequestTooLarge, ValueError)
    assert issubclass(QueueFull, RuntimeError)
    assert issubclass(PoolExhausted, RuntimeError)
    assert issubclass(CircuitOpen, RuntimeError)
    assert issubclass(ServerOverloaded, RuntimeError)
    for cls in BY_NAME.values():
        assert issubclass(cls, ServingError)


# ------------------------------------------------------- scripted round trip
@dataclasses.dataclass
class _Result:
    rid: int
    status: str
    tokens: list
    finished_at: float


class _ScriptedServer:
    """Admission surface double: raises a scripted error sequence, then
    serves a one-token stream. Tick bookkeeping mirrors AsyncServer's
    (clock advances only through the wait_* calls the client makes)."""

    def __init__(self, errors):
        self.errors = list(errors)
        self.clock = 0.0
        self.submits = 0

    def submit(self, request, *, timeout=None):
        self.submits += 1
        if self.errors:
            raise self.errors.pop(0)
        stream = RequestStream(request.rid)
        stream._push(self.clock, 7)
        stream._finish(_Result(rid=request.rid, status="ok", tokens=[7],
                               finished_at=self.clock))
        return stream

    async def wait_until(self, tick):
        self.clock = max(self.clock, tick)

    async def wait_ticks(self, n):
        assert n >= 0
        self.clock += n


def _req(rid=0):
    return Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=1)


@pytest.mark.parametrize("name", sorted(k for k, v in EXPECTED_TAXONOMY.items()
                                        if v))
def test_every_retryable_error_round_trips(name):
    """reject once with the retryable class → the client backs off and
    resubmits → success on attempt 2."""
    server = _ScriptedServer([BY_NAME[name](f"scripted {name}")])
    client = AsyncClient(server, RetryPolicy(max_attempts=3), seed=0)
    out = asyncio.run(client.run(_req()))
    assert out.ok and out.tokens == [7]
    assert out.attempts == 2 and server.submits == 2
    assert server.clock > 0.0    # a backoff sleep actually happened


@pytest.mark.parametrize("name", sorted(k for k, v in EXPECTED_TAXONOMY.items()
                                        if not v))
def test_every_nonretryable_error_fails_fast(name):
    """one raise of a non-retryable class → no resubmission, outcome
    ``rejected`` carrying the class name."""
    server = _ScriptedServer([BY_NAME[name](f"scripted {name}")])
    client = AsyncClient(server, RetryPolicy(max_attempts=3), seed=0)
    out = asyncio.run(client.run(_req()))
    assert not out.ok
    assert out.status == "rejected" and out.error == name
    assert out.attempts == 1 and server.submits == 1
    assert server.clock == 0.0   # fail fast: no backoff sleep


def test_retries_exhausted_is_shed():
    server = _ScriptedServer([QueueFull("full")] * 10)
    client = AsyncClient(server, RetryPolicy(max_attempts=4), seed=0)
    out = asyncio.run(client.run(_req()))
    assert out.status == "shed" and out.error == "QueueFull"
    assert out.attempts == 4 and server.submits == 4


def test_backoff_is_seeded_and_capped():
    """The jitter schedule depends only on (seed, rid) — never on wall clock
    or interleaving — and every sleep respects the exponential cap."""
    policy = RetryPolicy(max_attempts=8, base_backoff=4.0, multiplier=2.0,
                         max_backoff=16.0)
    a = AsyncClient(_ScriptedServer([]), policy, seed=3)
    b = AsyncClient(_ScriptedServer([]), policy, seed=3)
    sched_a = [policy.backoff(k, a._rng(5)) for k in range(6)]
    sched_b = [policy.backoff(k, b._rng(5)) for k in range(6)]
    assert sched_a == sched_b
    assert sched_a != [policy.backoff(k, a._rng(6)) for k in range(6)]
    for k, delay in enumerate(sched_a):
        assert 0.0 <= delay <= min(4.0 * 2.0 ** k, 16.0)
