"""Decode megakernel (fused append-quantize + int8 attention + quantize-out
epilogue): interpret-mode bit parity against the composed oracles, the q8
GEMM epilogue parity, dispatch-count reduction, and the engine-level
fused-vs-unfused token battery (fp32 + w8a16 + w8a8-kv8, contiguous and
paged) behind the REPRO_FUSED_DECODE routing flag."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_decode.ops import fused_decode, fusion_enabled
from repro.kernels.kv_attention.ops import kv_attention_decode, quantize_kv
from repro.kernels.quantize_act.ops import quantize_act


def _decode_inputs(B=2, S=64, Hq=4, Hkv=2, hd=16, seed=3):
    """Mid-generation ragged cache state: row i holds lengths[i] live tokens,
    the new token appends at offset lengths[i] (= the ring position)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    k_q, k_s = quantize_kv(k)
    v_q, v_s = quantize_kv(v)
    lengths = jnp.asarray([5, S - 7][:B])
    live = jnp.arange(S)[None, :] < lengths[:, None]
    k_s = jnp.where(live[..., None], k_s, 0.0)
    v_s = jnp.where(live[..., None], v_s, 0.0)
    k_new = jax.random.normal(ks[3], (B, 1, Hkv, hd))
    v_new = jax.random.normal(ks[4], (B, 1, Hkv, hd))
    idx = lengths[:, None].astype(jnp.int32)
    valid = jnp.arange(S)[None, :] <= lengths[:, None]   # incl. the new token
    return q, k_q, k_s, v_q, v_s, k_new, v_new, idx, valid


@pytest.mark.parametrize("quantize_out", [False, True])
def test_fused_interpret_bitexact_vs_ref(quantize_out):
    """The TPU lowering's interpret-mode twin == the composed blocked
    oracles, bit for bit — out, epilogue outputs, AND every cache leaf."""
    args = _decode_inputs()
    q, kq, ksc, vq, vsc, kn, vn, idx, valid = args
    res_i = fused_decode(q, kq, ksc, vq, vsc, kn, vn, idx, valid=valid,
                         blk=32, backend="interpret",
                         quantize_out=quantize_out)
    res_r = fused_decode(q, kq, ksc, vq, vsc, kn, vn, idx, valid=valid,
                         blk=32, backend="ref", quantize_out=quantize_out)
    outs_i = res_i[0] if quantize_out else (res_i[0],)
    outs_r = res_r[0] if quantize_out else (res_r[0],)
    for a, b in zip(outs_i, outs_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(res_i[1], res_r[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_xla_is_the_stepwise_composition():
    """The xla tier IS the pre-megakernel serving graph: CPU serving (and
    its lint contracts) are unchanged by construction."""
    q, kq, ksc, vq, vsc, kn, vn, idx, valid = _decode_inputs(seed=9)
    (out, oq, os_), upd = fused_decode(
        q, kq, ksc, vq, vsc, kn, vn, idx, valid=valid, blk=32,
        backend="xla", quantize_out=True)
    out2, upd2 = kv_attention_decode(q, kq, ksc, vq, vsc, kn, vn, idx,
                                     valid=valid, blk=32, backend="xla")
    oq2, os2 = quantize_act(out2.astype(jnp.float32).reshape(out2.shape[0], -1),
                            backend="xla")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(oq), np.asarray(oq2))
    np.testing.assert_array_equal(np.asarray(os_), np.asarray(os2))
    for a, b in zip(upd, upd2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_cache_verr_requires_xla():
    q, kq, ksc, vq, vsc, kn, vn, idx, valid = _decode_inputs()
    verr = jnp.zeros(ksc.shape, jnp.float32)
    with pytest.raises(ValueError, match="XLA composition"):
        fused_decode(q, kq, ksc, vq, vsc, kn, vn, idx, valid=valid,
                     backend="interpret", cache_verr=verr)


def test_fused_decode_is_one_dispatch():
    """The megakernel's reason to exist: append-quantize + attention +
    quantize-out collapse from 2 kernel launches to 1."""
    from repro.kernels.dispatch import count_pallas_calls

    q, kq, ksc, vq, vsc, kn, vn, idx, valid = _decode_inputs()
    fused = count_pallas_calls(
        fused_decode, q, kq, ksc, vq, vsc, kn, vn, idx,
        valid=valid, blk=32, backend="interpret", quantize_out=True)
    def stepwise(*a):
        out, upd = kv_attention_decode(*a, valid=valid, blk=32,
                                       backend="interpret")
        oq, os_ = quantize_act(out.reshape(out.shape[0], -1),
                               backend="interpret")
        return out, oq, os_, upd
    unfused = count_pallas_calls(stepwise, q, kq, ksc, vq, vsc, kn, vn, idx)
    assert fused == 1
    assert unfused == 2


def test_q8_gemm_epilogue_bitexact():
    """quantize_out on the GEMMs: (int8, row scale) out of the epilogue ==
    the GEMM's fp32 accumulator followed by a standalone quantize_act. The
    w8a8 path is int32-exact so every tier matches bit for bit; for w8a16
    the interpret kernel matches its own fp32 output bit for bit, while the
    blocked ref accumulates in K-block order (equal int8 payload, scale to
    fp32 rounding)."""
    from repro.kernels.qmatmul_w8a8.ops import qmatmul_w8a8
    from repro.kernels.qmatmul_w8a16.ops import qmatmul_w8a16

    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    M, K, N = 24, 96, 80
    a_q = jax.random.randint(ks[0], (M, K), -127, 128, dtype=jnp.int8)
    a_s = jax.random.uniform(ks[1], (M,), minval=0.005, maxval=0.05)
    w_q = jax.random.randint(ks[2], (K, N), -127, 128, dtype=jnp.int8)
    w_s = jax.random.uniform(ks[3], (N,), minval=0.005, maxval=0.05)
    bias = jax.random.normal(ks[0], (N,))

    for backend in ("interpret", "ref"):
        y = qmatmul_w8a8(a_q, w_q, a_s, w_s, bias, backend=backend)
        yq, ysc = qmatmul_w8a8(a_q, w_q, a_s, w_s, bias, backend=backend,
                               quantize_out=True)
        rq, rsc = quantize_act(y.astype(jnp.float32), backend=backend)
        np.testing.assert_array_equal(np.asarray(yq), np.asarray(rq))
        np.testing.assert_array_equal(np.asarray(ysc), np.asarray(rsc))

    a = jax.random.normal(ks[1], (8, K))
    for backend in ("interpret", "ref"):
        y = qmatmul_w8a16(a, w_q, w_s, bias, backend=backend,
                          out_dtype=jnp.float32)
        yq, ysc = qmatmul_w8a16(a, w_q, w_s, bias, backend=backend,
                                quantize_out=True)
        rq, rsc = quantize_act(y, backend=backend)
        np.testing.assert_array_equal(np.asarray(yq), np.asarray(rq))
        if backend == "interpret":
            np.testing.assert_array_equal(np.asarray(ysc), np.asarray(rsc))
        else:
            np.testing.assert_allclose(np.asarray(ysc), np.asarray(rsc),
                                       rtol=1e-5)


# ------------------------------------------- engine fused-vs-unfused battery

def test_fusion_flag(monkeypatch):
    monkeypatch.delenv("REPRO_FUSED_DECODE", raising=False)
    assert fusion_enabled()
    monkeypatch.setenv("REPRO_FUSED_DECODE", "0")
    assert not fusion_enabled()


@pytest.fixture(scope="module")
def _setups():
    """{name: (model, params, cfg, kv_bits)} for the three serving modes."""
    import repro
    from repro.configs import get_config
    from repro.models import build_model

    out = {}
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg)
    out["fp32"] = (model, model.init(jax.random.PRNGKey(0)), cfg, None)
    for recipe in ("serve-w8a16", "serve-w8a8-kv8"):
        qm = repro.quantize(build_model(cfg), recipe=recipe)
        out[recipe] = (qm.model, qm.params, qm.cfg,
                       qm.cfg.kv_cache_bits if "kv8" in recipe else None)
    return out


def _serve_tokens(setup, monkeypatch, fused, paged):
    from repro.serving import Request, ServingEngine

    model, params, cfg, kv_bits = setup
    monkeypatch.setenv("REPRO_FUSED_DECODE", "1" if fused else "0")
    rng = np.random.RandomState(5)
    trace = [Request(rid=i,
                     prompt=rng.randint(0, cfg.vocab_size, size=p)
                     .astype(np.int32),
                     max_new_tokens=g)
             for i, (p, g) in enumerate([(5, 6), (12, 3), (9, 8)])]
    kw = dict(num_slots=2, max_len=32, prefill_chunk=8, kv_bits=kv_bits)
    if paged:
        kw.update(page_size=8)
    eng = ServingEngine(model, params, cfg, **kw)
    res = eng.run([dataclasses.replace(r) for r in trace])
    return {r.rid: (res[r.rid].tokens, res[r.rid].admitted_at,
                    res[r.rid].finished_at) for r in trace}


@pytest.mark.parametrize("mode", ["fp32", "serve-w8a16", "serve-w8a8-kv8"])
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
def test_engine_fused_matches_unfused(_setups, monkeypatch, mode, paged):
    """The acceptance pin: REPRO_FUSED_DECODE=1 serves bit-identical tokens
    (and admission timeline) to the stepwise =0 path, across fp32 / w8a16 /
    w8a8-kv8, contiguous and paged pools."""
    fused = _serve_tokens(_setups[mode], monkeypatch, fused=True, paged=paged)
    unfused = _serve_tokens(_setups[mode], monkeypatch, fused=False,
                            paged=paged)
    assert fused == unfused
