"""Paged KV-cache pool + copy-on-write prefix-reuse tests.

The anchor is layout invariance: the paged engine (fixed page pool +
per-slot page tables) must produce bit-identical tokens to the contiguous
engine — fp32 and quantized, through slot recycling, COW writes, and the
gen-at-prefill edge. With ``prefix_reuse=False`` the admit/finish timeline
must ALSO match tick for tick (same pool capacity, same admission order);
with reuse on, requests may legitimately finish EARLIER (shared prefill
pages skip whole prefill chunks) but never later, and never with different
tokens. Plus the slot-lifecycle bugfix sweep (stale deferred resets,
double-release, allocate-after-exhaustion), the fused-reset dispatch pin,
``bytes_per_slot`` leaf accounting, and PrefixIndex unit semantics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs import get_config
from repro.models import build_model
from repro.serving import PrefixIndex, Request, ServingEngine
from repro.serving.cache_pool import CachePool, PoolExhausted

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def fp32_setup():
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


@pytest.fixture(scope="module")
def w8a16_setup(fp32_setup):
    model, params, cfg = fp32_setup
    return repro.quantize(model, params=params, recipe="serve-w8a16")


@pytest.fixture(scope="module")
def kv8_setup(fp32_setup):
    model, params, cfg = fp32_setup
    return repro.quantize(model, params=params, recipe="serve-w8a8-kv8")


def _mixed_trace(vocab):
    rng = np.random.RandomState(7)
    lens = [(5, 6), (12, 3), (3, 1), (9, 8)]  # includes a gen-at-prefill edge
    return [
        Request(rid=i, prompt=rng.randint(0, vocab, size=p).astype(np.int32),
                max_new_tokens=g)
        for i, (p, g) in enumerate(lens)
    ]


def _engine(model, params, cfg, **kw):
    kw.setdefault("num_slots", 2)   # < len(trace): forces slot recycling
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(model, params, cfg, **kw)


def _run(engine, trace):
    return engine.run([dataclasses.replace(r) for r in trace])


def _setup(variant, fp32_setup, w8a16_setup, kv8_setup):
    if variant == "fp32":
        return fp32_setup
    qm = {"serve-w8a16": w8a16_setup, "serve-w8a8-kv8": kv8_setup}[variant]
    return qm.model, qm.params, qm.cfg


# ------------------------------------------------------------ layout parity

@pytest.mark.parametrize("variant", ["fp32", "serve-w8a16", "serve-w8a8-kv8"])
def test_paged_token_and_timeline_parity(variant, fp32_setup, w8a16_setup,
                                         kv8_setup):
    """The acceptance anchor: with prefix reuse OFF the paged engine is
    indistinguishable from the contiguous one — tokens AND the admit/finish
    timeline — through slot recycling (2 slots, 4 requests)."""
    model, params, cfg = _setup(variant, fp32_setup, w8a16_setup, kv8_setup)
    trace = _mixed_trace(cfg.vocab_size)
    flat = _run(_engine(model, params, cfg), trace)
    paged = _run(_engine(model, params, cfg, page_size=8,
                         prefix_reuse=False), trace)
    for r in trace:
        assert paged[r.rid].tokens == flat[r.rid].tokens, (
            f"{variant}: rid {r.rid} tokens diverged under the paged layout")
        assert paged[r.rid].admitted_at == flat[r.rid].admitted_at
        assert paged[r.rid].finished_at == flat[r.rid].finished_at


@pytest.mark.parametrize("variant", ["fp32", "serve-w8a8-kv8"])
def test_paged_with_reuse_matches_tokens_never_later(
        variant, fp32_setup, w8a16_setup, kv8_setup):
    """Prefix reuse on (the default): tokens stay bit-identical; shared
    prefill pages may only make requests finish EARLIER, never later."""
    model, params, cfg = _setup(variant, fp32_setup, w8a16_setup, kv8_setup)
    trace = _mixed_trace(cfg.vocab_size)
    flat = _run(_engine(model, params, cfg), trace)
    eng = _engine(model, params, cfg, page_size=8)
    paged = _run(eng, trace)
    for r in trace:
        assert paged[r.rid].tokens == flat[r.rid].tokens
        assert paged[r.rid].finished_at <= flat[r.rid].finished_at
    assert eng.pool.all_free()
    assert eng.pool.n_free_pages == eng.pool.num_pages - len(eng.prefix_index)


@pytest.mark.parametrize("fast", [True, False])
def test_paged_fast_vs_stepwise_parity(fast, fp32_setup):
    """The PR-3 fused fast path survives the paged layout: horizons + batched
    multi-slot prefill over the page tables == the stepwise paged reference,
    and both == the contiguous engine."""
    model, params, cfg = fp32_setup
    trace = _mixed_trace(cfg.vocab_size)
    ref = _run(_engine(model, params, cfg, fast=False), trace)
    got = _run(_engine(model, params, cfg, page_size=8, fast=fast,
                       prefix_reuse=False), trace)
    for r in trace:
        assert got[r.rid].tokens == ref[r.rid].tokens
        assert got[r.rid].finished_at == ref[r.rid].finished_at


def test_paged_cache_is_donated(fp32_setup):
    """Donation must cover the page pool and the page table: after a run the
    pre-run buffers were consumed in place, not copied."""
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, page_size=8)
    before_k = eng.pool.cache["k"]
    before_pt = eng.pool.cache["page_table"]
    eng.run([Request(rid=0, prompt=[5] * 4, max_new_tokens=4)])
    assert before_k.is_deleted(), "page pool was copied, not donated"
    assert before_pt.is_deleted(), "page table was copied, not donated"


# --------------------------------------------------- copy-on-write sharing

def test_cow_prefix_reuse_shares_then_copies(fp32_setup):
    """A second request whose prompt IS a published page must admit with the
    shared page mapped, copy it on write (reuse splits the page: R=4 inside
    the 8-token page), and still produce exactly the contiguous tokens."""
    model, params, cfg = fp32_setup
    rng = np.random.RandomState(11)
    shared = rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
    trace = [
        Request(rid=0, prompt=shared, max_new_tokens=4, arrival=0.0),
        # prompt == the donor's first page exactly: 1 matched page, reuse
        # aligned DOWN to the chunk boundary (C=4) inside it -> COW
        Request(rid=1, prompt=shared[:8], max_new_tokens=4, arrival=6.0),
    ]
    flat = _run(_engine(model, params, cfg, prefill_chunk=4), trace)
    eng = _engine(model, params, cfg, prefill_chunk=4, page_size=8)
    paged = _run(eng, trace)
    assert eng.pool.cow_copies >= 1, "boundary page was never copied"
    assert eng.prefix_index.hits >= 1
    for r in trace:
        assert paged[r.rid].tokens == flat[r.rid].tokens
        assert paged[r.rid].finished_at <= flat[r.rid].finished_at
    # rid 1 skipped at least one prefill chunk via the shared page
    assert paged[1].finished_at < flat[1].finished_at


def test_concurrent_requests_share_published_prefix(fp32_setup):
    """Publish happens at prefill COMPLETION, not retire: requests admitted
    while the donor is still decoding already share its prompt pages."""
    model, params, cfg = fp32_setup
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, size=16).astype(np.int32)
    # the donor's 16-token prompt prefills in 2 chunks; the followers arrive
    # at tick 3 — donor still holds its slot, decoding, pages published
    trace = [Request(rid=i, prompt=prompt, max_new_tokens=6,
                     arrival=0.0 if i == 0 else 3.0)
             for i in range(4)]
    eng = _engine(model, params, cfg, num_slots=4, page_size=8)
    paged = _run(eng, trace)
    flat = _run(_engine(model, params, cfg, num_slots=4), trace)
    assert {r: v.tokens for r, v in paged.items()} == \
           {r: v.tokens for r, v in flat.items()}
    assert eng.prefix_index.hits >= 1, "followers never hit the donor's pages"


def test_tight_page_pool_blocks_then_recovers(fp32_setup):
    """num_pages below full capacity: admission HOL-blocks on pages (with
    LRU eviction of index entries) instead of deadlocking or corrupting —
    every request still completes with contiguous-identical tokens."""
    model, params, cfg = fp32_setup
    trace = _mixed_trace(cfg.vocab_size)
    flat = _run(_engine(model, params, cfg), trace)
    eng = _engine(model, params, cfg, page_size=8, num_pages=6)
    paged = _run(eng, trace)
    assert {r: v.tokens for r, v in paged.items()} == \
           {r: v.tokens for r, v in flat.items()}
    assert eng.pool.all_free()


def test_paged_submit_rejects_unservable_request(fp32_setup):
    """A request needing more pages than the POOL has can never be admitted:
    submit must reject it up front instead of deadlocking the FIFO line."""
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg, page_size=8, num_pages=2)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(rid=0, prompt=[1] * 20, max_new_tokens=8))


# ---------------------------------------------------------------- TP twin

@pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)
def test_paged_sharded_token_parity(fp32_setup):
    """The -tp twin on the 2x4 CI mesh: the paged sharded engine (page pool
    replicated over data, heads TP over model) matches the single-device
    contiguous engine token for token."""
    from repro.launch.mesh import make_production_mesh
    from jax.sharding import PartitionSpec as P

    model, params, _ = fp32_setup
    qm = repro.quantize(model, params=params, recipe="serve-w8a16-tp")
    trace = _mixed_trace(qm.cfg.vocab_size)
    mesh = make_production_mesh(shape=(2, 4))
    single = _run(_engine(qm.model, qm.params, qm.cfg), trace)
    eng = _engine(qm.model, qm.params, qm.cfg, mesh=mesh, page_size=8)
    # page axis and tables replicate (the smoke arch's 2 KV heads don't
    # divide model=4 either, so the whole pool is replicated here); the
    # engine-level point is token parity through page-table addressing
    assert eng.pool.cache["k"].sharding.spec == P(None, None, None, None,
                                                  None)
    assert eng.pool.cache["page_table"].sharding.spec == P(None, None)
    sharded = _run(eng, trace)
    assert {r: v.tokens for r, v in sharded.items()} == \
           {r: v.tokens for r, v in single.items()}


# ------------------------------------------------------ pool slot lifecycle

def test_release_before_deferred_reset_commits_repairs_bookkeeping(
        fp32_setup):
    """The slot-lifecycle bug this PR fixes: a slot allocated with
    ``reset=False`` (deferred fresh-mask reset) and released BEFORE any
    prefill committed the reset used to hand the PREVIOUS occupant's
    kpos/pos to its next claimant. Release must repair the bookkeeping."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=1, max_len=32)
    s = pool.allocate()
    pool._reset_slot(s, reuse=5)        # simulate a request's occupancy
    pool.release(s)

    s2 = pool.allocate(reset=False)     # deferred: stale kpos/pos by design
    assert int(np.asarray(pool.cache["pos"])[s2]) == 5  # stale, pre-commit
    pool.release(s2)                    # ...released before any commit

    s3 = pool.allocate(reset=False)     # next claimant also defers: nothing
    kpos = np.asarray(pool.cache["kpos"])[s3]           # else would clean it
    assert (kpos == -1).all(), "stale kpos leaked through an early release"
    assert int(np.asarray(pool.cache["pos"])[s3]) == 0


def test_note_reset_committed_clears_pending(fp32_setup):
    """Once the engine's first jitted prefill commits the fresh-mask reset,
    release must NOT redundantly re-reset (the commit is the reset)."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=1, max_len=32)
    s = pool.allocate(reset=False)
    assert s in pool._pending_reset
    pool.note_reset_committed(s)
    calls = {"n": 0}
    real = pool._reset_fn
    pool._reset_fn = lambda *a: (calls.__setitem__("n", calls["n"] + 1)
                                 or real(*a))
    pool.release(s)
    assert calls["n"] == 0, "release re-reset a slot whose reset committed"


def test_double_release_and_exhaustion_recovery(fp32_setup):
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=1, max_len=32)
    s = pool.allocate()
    with pytest.raises(PoolExhausted):
        pool.allocate()
    pool.release(s)
    with pytest.raises(ValueError, match="not allocated"):
        pool.release(s)
    s2 = pool.allocate()                # exhaustion is recoverable
    assert s2 == s
    pool.release(s2)
    assert pool.all_free()


def test_paged_pool_exhaustion_is_atomic(fp32_setup):
    """A PoolExhausted admission must leave the pool untouched: no slot
    claimed, no page leaked, refcounts unchanged."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=2, max_len=32, page_size=8, num_pages=3)
    a = pool.allocate_pages(need=17)               # 3 pages -> pool drained
    assert pool.n_free_pages == 0
    with pytest.raises(PoolExhausted):
        pool.allocate_pages(need=9)                # needs 2 fresh pages
    assert pool.n_free == 1 and pool.n_allocated == 1
    pool.release(a)
    assert pool.n_free_pages == 3 and pool.all_free()
    b = pool.allocate_pages(need=9)
    assert len(pool.slot_pages(b)) == 2
    with pytest.raises(ValueError, match="not allocated"):
        pool.release(a if a != b else a + 1)


def test_paged_refcounts_and_cow(fp32_setup):
    """Page-level unit semantics: shared pages pin until every reference
    drops; a write into a shared page copies it (COW) and repoints only the
    writer's table entry."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=2, max_len=32, page_size=8)
    donor = pool.allocate_pages(need=9)            # 2 pages
    first = pool.slot_page(donor, 0)
    pool.ref_page(first)                           # the prefix index pins it
    assert pool.page_ref(first) == 2
    sharer = pool.allocate_pages(need=9, shared=[first], reuse_len=8)
    assert pool.slot_page(sharer, 0) == first and pool.page_ref(first) == 3
    assert pool.ensure_writable(sharer, 8, 9) == 0  # page 1 is exclusive
    copied = pool.ensure_writable(sharer, 0, 8)     # page 0 is shared
    assert copied == 1 and pool.cow_copies == 1
    assert pool.slot_page(sharer, 0) != first
    assert pool.page_ref(first) == 2               # donor + index
    assert pool.slot_page(donor, 0) == first       # donor untouched
    pool.release(sharer)
    pool.release(donor)
    assert pool.page_ref(first) == 1               # index still pins it
    pool.deref_page(first)
    assert pool.n_free_pages == pool.num_pages
    with pytest.raises(ValueError, match="over-released"):
        pool.deref_page(first)
    with pytest.raises(ValueError, match="free"):
        pool.ref_page(first)


def test_allocate_pages_validates_arguments(fp32_setup):
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=2, max_len=32, page_size=8)
    with pytest.raises(ValueError, match="reuse_len"):
        pool.allocate_pages(need=8, reuse_len=8)
    with pytest.raises(ValueError, match="shared pages"):
        pool.allocate_pages(need=17, shared=[], reuse_len=9)
    with pytest.raises(ValueError, match="slot table"):
        pool.allocate_pages(need=33)
    assert pool.all_free() and pool.n_free_pages == pool.num_pages


# --------------------------------------------- fused-reset dispatch fusion

def test_allocate_reset_is_one_fused_dispatch(fp32_setup):
    """The satellite fix: allocate(reset=True) used to dispatch kpos and pos
    updates eagerly one .at[].set at a time; now the whole bookkeeping reset
    is ONE jitted call (and reset=False is zero)."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=2, max_len=32)
    calls = {"n": 0}
    real = pool._reset_fn

    def counting(*a):
        calls["n"] += 1
        return real(*a)

    pool._reset_fn = counting
    pool.allocate()
    assert calls["n"] == 1, "fresh reset must be exactly one fused dispatch"
    pool.allocate(reset=False)
    assert calls["n"] == 1, "deferred admission must dispatch nothing"


def test_paged_admission_is_one_fused_dispatch(fp32_setup):
    """Paged admission (kpos seed + pos + page-table row) is ONE dispatch;
    a page-splitting reuse adds exactly one COW dispatch."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=2, max_len=32, page_size=8)
    counts = {"admit": 0, "cow": 0}
    real_admit, real_cow = pool._admit_fn, pool._cow_fn
    pool._admit_fn = lambda *a: (counts.__setitem__("admit",
                                 counts["admit"] + 1) or real_admit(*a))
    pool._cow_fn = lambda *a: (counts.__setitem__("cow", counts["cow"] + 1)
                               or real_cow(*a))
    donor = pool.allocate_pages(need=9)
    assert counts == {"admit": 1, "cow": 0}
    page = pool.slot_page(donor, 0)
    pool.ref_page(page)
    pool.allocate_pages(need=9, shared=[page], reuse_len=4)  # splits page 0
    assert counts == {"admit": 2, "cow": 1}


# ------------------------------------------------------ byte accounting

def test_bytes_per_slot_counts_every_payload_leaf(fp32_setup):
    """bytes_per_slot must count EVERY non-bookkeeping leaf (so new slot
    state is never silently dropped from the roofline) and refuse to guess
    about unrecognized integer leaves."""
    model, _, _ = fp32_setup
    pool = CachePool(model, num_slots=2, max_len=32)
    base = pool.bytes_per_slot()
    assert base * pool.num_slots == pool.cache_bytes()

    extra = jnp.zeros((4, 2, 32, 3), jnp.float32)   # e.g. a v_err-like leaf
    pool.cache["extra"] = extra
    grown = pool.bytes_per_slot()
    assert grown == base + extra.size * 4 // pool.num_slots

    pool.cache["mystery"] = jnp.zeros((2, 32), jnp.int32)
    with pytest.raises(ValueError, match="bookkeeping"):
        pool.bytes_per_slot()


def test_paged_and_contiguous_slot_bytes_match_at_full_capacity(fp32_setup):
    """At the default page-pool size (every slot can map a full ring) the
    paged layout pays the same payload bytes per slot as contiguous — paging
    wins by ALLOCATING less, not by shrinking the worst case."""
    model, _, _ = fp32_setup
    flat = CachePool(model, num_slots=2, max_len=32)
    paged = CachePool(model, num_slots=2, max_len=32, page_size=8)
    assert paged.bytes_per_slot() == flat.bytes_per_slot()
    assert paged.cache_bytes() == flat.cache_bytes()


# ------------------------------------------------------- serve CLI guards

def test_serve_cli_rejects_kv_bits_artifact_mismatch(w8a16_setup, tmp_path,
                                                     capsys):
    """--kv-bits against a --load artifact recorded at another KV precision
    must hard-error naming BOTH values (the artifact's kv_cache stage
    calibrated for its recorded precision; silently serving at another one
    would ship a cache the calibration never saw)."""
    from repro.launch import serve

    w8a16_setup.save(str(tmp_path / "art"))    # records kv_cache_bits=16
    with pytest.raises(SystemExit):
        serve.main(["--load", str(tmp_path / "art"), "--kv-bits", "8"])
    err = capsys.readouterr().err
    assert "--kv-bits 8" in err and "kv_cache_bits=16" in err
    assert "re-quantize" in err


def test_serve_cli_page_flags_need_page_size(capsys):
    from repro.launch import serve

    with pytest.raises(SystemExit):
        serve.main(["--arch", "qwen2-0.5b", "--smoke", "--num-pages", "8"])
    assert "--num-pages needs --page-size" in capsys.readouterr().err


# ------------------------------------------------------------- PrefixIndex

class _FakePool:
    """Just enough of CachePool's page API for index unit tests."""

    def __init__(self, pages):
        self.refs = dict.fromkeys(range(pages), 1)
        self.slots = {}

    def slot_page(self, slot, idx):
        return self.slots[slot][idx]

    def ref_page(self, page):
        assert self.refs[page] >= 1
        self.refs[page] += 1

    def deref_page(self, page):
        self.refs[page] -= 1
        assert self.refs[page] >= 0


def test_prefix_index_keys_by_full_prefix():
    """Two prompts sharing page-1 TOKENS but different page-0 history must
    not share page 1 — KV content is a function of the whole prefix."""
    pool = _FakePool(4)
    idx = PrefixIndex(page_size=2)
    pool.slots[0] = [0, 1]
    idx.publish([1, 2, 3, 4], pool, 0)
    pool.slots[1] = [2, 3]
    idx.publish([9, 9, 3, 4], pool, 1)       # same page-1 tokens (3, 4)
    assert idx.lookup([1, 2, 3, 4]) == [0, 1]
    assert idx.lookup([9, 9, 3, 4]) == [2, 3]
    assert idx.lookup([1, 2, 9, 9]) == [0]   # walk stops at first miss
    assert idx.lookup([5, 5]) == []
    assert len(idx) == 4


def test_prefix_index_publish_pins_and_skips_partial_pages():
    pool = _FakePool(2)
    idx = PrefixIndex(page_size=4)
    pool.slots[0] = [0, 1]
    added = idx.publish([1, 2, 3, 4, 5], pool, 0)  # page 1 only partly
    assert added == 1 and len(idx) == 1            # covered by the prompt
    assert pool.refs[0] == 2 and pool.refs[1] == 1
    # a second donor with the same prefix adds nothing (first donor wins)
    pool.slots[1] = [1, 0]
    assert idx.publish([1, 2, 3, 4], pool, 1) == 0
    assert pool.refs == {0: 2, 1: 1}


def test_prefix_index_lru_eviction_respects_protect():
    pool = _FakePool(3)
    idx = PrefixIndex(page_size=1)
    pool.slots[0] = [0, 1, 2]
    idx.publish([7, 8, 9], pool, 0)
    idx.lookup([7])                     # touch page 0: LRU order 1, 2, 0
    assert idx.evict_lru(pool, protect={1}) is True
    assert pool.refs[2] == 1            # page 2 went, not the protected 1
    assert idx.evict_lru(pool, protect={0, 1}) is False
    idx.clear(pool)
    assert len(idx) == 0 and all(r == 1 for r in pool.refs.values())
