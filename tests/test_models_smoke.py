"""Per-arch smoke tests: reduced same-family configs, one forward + one
train-loss + one prefill/decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import SHAPE_BY_NAME, build_model, shape_applicable
from repro.models.model import input_specs

ARCHS = list_archs()


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(rng)
    B, T = 2, 16
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    if cfg.is_encdec:
        frames = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model))
        logits, _ = model.apply(params, tokens, frames)
    else:
        logits, _ = model.apply(params, tokens)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encdec:
        batch["frames"] = frames
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(V)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(rng)
    B, T = 2, 16
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model))
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch, rng):
    """Greedy next-token logits from (prefill + decode_step) must match the
    teacher-forced forward — validates the cache machinery per family."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(rng)
    B, T = 2, 12
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)

    if cfg.is_encdec:
        frames = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model))
        full_logits, _ = model.apply(params, tokens, frames)
        cache = model.init_cache(B, 32, dtype=jnp.float32)
        cache = model.warm_cache(params, frames, cache)
    else:
        full_logits, _ = model.apply(params, tokens)
        cache = model.init_cache(B, 32, dtype=jnp.float32)

    logits_p, cache = model.prefill(params, tokens[:, :-1], cache)
    logits_d, cache = model.decode_step(params, tokens[:, -1:], cache)

    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, -2]), rtol=2e-2, atol=2e-2
    )
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(full_logits[:, -1]), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("arch", ["mixtral-8x22b"])
def test_sliding_window_ring_buffer(arch, rng):
    """Cache shorter than the sequence (ring buffer) still matches the
    windowed full forward."""
    cfg = get_config(arch, smoke=True)  # window = 16 in smoke
    model = build_model(cfg)
    params = model.init(rng)
    B, T = 1, 24  # T > window
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    full_logits, _ = model.apply(params, tokens)
    cache = model.init_cache(B, T, dtype=jnp.float32)
    assert cache["k"].shape[2] == cfg.sliding_window  # bounded KV
    logits = None
    for t in range(T):
        logits, cache = model.decode_step(params, tokens[:, t : t + 1], cache)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, -1]), rtol=2e-2, atol=2e-2
    )


def test_shape_applicability_rules():
    long = SHAPE_BY_NAME["long_500k"]
    assert shape_applicable(get_config("mamba2-2.7b"), long)[0]
    assert shape_applicable(get_config("zamba2-2.7b"), long)[0]
    assert shape_applicable(get_config("mixtral-8x22b"), long)[0]
    ok, why = shape_applicable(get_config("yi-34b"), long)
    assert not ok and "full-attention" in why


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_complete(arch):
    cfg = get_config(arch)
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        specs = input_specs(cfg, SHAPE_BY_NAME[shape_name])
        assert all(hasattr(v, "shape") for v in specs.values())


def test_param_counts_match_public_sizes():
    """Sanity-check the configs reproduce the advertised model scales."""
    expect = {
        "qwen2-0.5b": (0.35e9, 0.8e9),
        "yi-34b": (30e9, 38e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "gemma-7b": (7e9, 10e9),
        "mixtral-8x22b": (120e9, 150e9),
        "chameleon-34b": (30e9, 40e9),
        "whisper-tiny": (25e6, 80e6),
        "zamba2-2.7b": (2e9, 3.5e9),
        "mamba2-2.7b": (2e9, 3.5e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9},{hi/1e9}]"
