"""Async streaming front-end tests: token streaming parity, timeouts wired
to engine deadlines, retry round trips against a real bounded queue, the
circuit breaker, the priority-aware shedding ladder, graceful drain, and
whole-run determinism.

Everything runs on the engine-tick clock (no wall-clock timers anywhere in
the server), so every assertion here is exact — including the comparison of
two complete open-loop runs, retries and all, byte for byte.
"""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.serving import (
    AsyncClient,
    AsyncServer,
    CircuitBreaker,
    CircuitOpen,
    QueueFull,
    Request,
    RetryPolicy,
    ServerOverloaded,
    ServingEngine,
    ShedPolicy,
    open_loop_trace,
    run_open_loop,
)

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def fp32_setup():
    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _engine(model, params, cfg, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_horizon", 4)
    return ServingEngine(model, params, cfg, **kw)


def _req(rid, p, g, **kw):
    rng = np.random.RandomState(100 + rid)
    return Request(rid=rid, prompt=rng.randint(0, 64, size=p).astype(np.int32),
                   max_new_tokens=g, **kw)


# ------------------------------------------------------------ breaker (unit)

def test_circuit_breaker_lifecycle():
    br = CircuitBreaker(window=8, failure_threshold=0.5, min_volume=4,
                        cooldown=10.0)
    assert br.state == "closed"
    # below min_volume nothing trips, even at 100% failures
    for t in range(3):
        assert br.allow(t)
        br.record(False, t)
    assert br.state == "closed"
    br.record(False, 3.0)
    assert br.state == "open" and br.opens == 1
    # open: shed until the cooldown elapses
    assert not br.allow(4.0) and not br.allow(12.9)
    # cooldown over → half-open, the allowed submission is the probe
    assert br.allow(13.0) and br.state == "half_open"
    br.record(False, 13.0)               # failed probe → re-open
    assert br.state == "open" and br.opens == 2
    assert br.allow(23.0) and br.state == "half_open"
    br.record(True, 23.0)                # successful probe → closed
    assert br.state == "closed"
    # the window was cleared: old failures don't linger into the new epoch
    br.record(False, 24.0)
    assert br.state == "closed"


def test_breaker_trips_on_real_queue_rejections(fp32_setup):
    """Sustained QueueFull from a bounded queue feeds the breaker window
    until it opens; the server then sheds with CircuitOpen at its own door
    (the engine queue is never touched while open)."""
    model, params, cfg = fp32_setup
    engine = _engine(model, params, cfg, max_queue=1)
    server = AsyncServer(engine,
                         breaker=CircuitBreaker(window=8,
                                                failure_threshold=0.5,
                                                min_volume=4, cooldown=16.0),
                         shed=ShedPolicy(refuse_pressure=10.0,
                                         shed_pressure=9.0,
                                         tighten_pressure=9.5))
    server.submit(_req(0, 4, 2))
    rejected = 0
    with pytest.raises(CircuitOpen):
        for rid in range(1, 20):
            try:
                server.submit(_req(rid, 4, 2))
            except QueueFull:
                rejected += 1
    assert rejected >= 3   # fails to fill min_volume alongside the 1 success
    assert server.breaker.state == "open" and server.breaker.opens == 1
    submits_before = server.stats["shed_queue"]
    with pytest.raises(CircuitOpen):
        server.submit(_req(99, 4, 2))
    assert server.stats["shed_queue"] == submits_before  # breaker shed first


# ------------------------------------------------------------ shedding ladder

def test_priority_shedding_ladder(fp32_setup):
    """Rungs in documented order as queue pressure climbs: shed the lowest
    priority class, then tighten accepted deadlines, then refuse all."""
    model, params, cfg = fp32_setup
    engine = _engine(model, params, cfg, max_queue=4)
    server = AsyncServer(engine, breaker=CircuitBreaker(min_volume=100),
                         shed=ShedPolicy(shed_pressure=0.5,
                                         tighten_pressure=0.75,
                                         refuse_pressure=1.0,
                                         tightened_slack=64.0))
    server.submit(_req(0, 4, 2))
    server.submit(_req(1, 4, 2))
    # pressure now 0.5 — rung 1: lowest class shed, higher class admitted
    with pytest.raises(ServerOverloaded):
        server.submit(_req(2, 4, 2, priority=0))
    assert server.stats["shed_priority"] == 1
    server.submit(_req(3, 4, 2, priority=1))
    # pressure 0.75 — rung 2: still admitted, deadline shrunk to now + slack
    server.submit(_req(4, 4, 2, priority=1))
    assert server.stats["deadlines_tightened"] == 1
    queued = {r.rid: r for r in engine.scheduler._queue}
    assert queued[4].deadline == engine.clock + 64.0
    assert queued[3].deadline is None        # rung 2 hadn't engaged yet
    # pressure 1.0 — rung 3: refuse everything, any priority
    with pytest.raises(ServerOverloaded):
        server.submit(_req(5, 4, 2, priority=5))
    assert server.stats["shed_refused"] == 1


# ----------------------------------------------------- streaming + timeouts

def test_streaming_matches_batch_engine(fp32_setup):
    """Per-token streams must be byte-identical (values AND order) to the
    batch engine's results, with monotonically increasing token ticks."""
    model, params, cfg = fp32_setup
    trace = open_loop_trace(3, 8, 0.5, vocab_size=cfg.vocab_size,
                            prompt_lens=(4, 12), gen_lens=(4, 12))
    ref = _engine(model, params, cfg).run(
        [dataclasses.replace(r) for r in trace])

    engine = _engine(model, params, cfg)
    server = AsyncServer(engine)
    client = AsyncClient(server, RetryPolicy(), seed=0)
    outcomes = asyncio.run(run_open_loop(
        server, client, [dataclasses.replace(r) for r in trace]))
    assert len(outcomes) == len(trace)
    for o in outcomes:
        assert o.ok
        assert list(o.tokens) == list(ref[o.rid].tokens)
        assert o.token_ticks == sorted(o.token_ticks)
        assert o.ttft is not None and o.ttft >= 0
        assert o.finished_tick >= o.token_ticks[-1]


def test_timeout_wires_to_engine_deadline(fp32_setup):
    """A client timeout becomes the engine's deadline: the request expires
    tick-exactly inside the engine (status 'expired'), streams only the
    tokens produced before the cut, and is NOT retried (DeadlineExceeded
    semantics — the deadline does not reset)."""
    model, params, cfg = fp32_setup
    engine = _engine(model, params, cfg)
    server = AsyncServer(engine)
    client = AsyncClient(server, RetryPolicy(max_attempts=4), seed=0)

    async def drive():
        server.start()
        out = await client.run(_req(0, 8, 20), timeout=6.0)
        await server.aclose()
        return out

    out = asyncio.run(drive())
    assert out.status == "expired"
    assert out.attempts == 1                 # terminal, not retried
    assert 0 < len(out.tokens) < 20
    assert engine.results[0].status == "expired"
    assert list(engine.results[0].tokens) == list(out.tokens)


def test_queuefull_retry_roundtrip_real_engine(fp32_setup):
    """Open-loop burst against a 1-deep queue: clients see real QueueFull,
    back off in engine ticks, and every request still completes ok."""
    model, params, cfg = fp32_setup
    engine = _engine(model, params, cfg, max_queue=1)
    server = AsyncServer(engine, breaker=CircuitBreaker(min_volume=1000),
                         shed=ShedPolicy(shed_pressure=8.0,
                                         tighten_pressure=9.0,
                                         refuse_pressure=10.0))
    client = AsyncClient(server, RetryPolicy(max_attempts=10,
                                             base_backoff=2.0), seed=1)
    trace = [_req(i, 4, 4) for i in range(5)]     # all arrive at tick 0
    outcomes = asyncio.run(run_open_loop(server, client, trace))
    assert all(o.ok for o in outcomes)
    assert max(o.attempts for o in outcomes) > 1  # retries actually happened
    assert server.stats["shed_queue"] > 0


# ----------------------------------------------------------- drain + determinism

def test_drain_finishes_inflight_rejects_new(fp32_setup):
    model, params, cfg = fp32_setup
    engine = _engine(model, params, cfg)
    server = AsyncServer(engine)

    async def drive():
        server.start()
        s1 = server.submit(_req(0, 8, 6))
        await server.wait_ticks(1)           # let prefill begin
        server.drain()
        with pytest.raises(QueueFull):       # admission closed for good
            server.submit(_req(1, 4, 2))
        r1 = await s1.drain()
        await server.aclose()
        return r1

    r1 = asyncio.run(drive())
    assert r1.status == "ok" and len(r1.tokens) == 6
    assert engine.draining


def test_open_loop_run_is_deterministic(fp32_setup):
    """Two full open-loop runs — arrivals, retries, backoff jitter, breaker
    state, shed decisions, streamed ticks — must be bit-identical."""
    model, params, cfg = fp32_setup

    def run_once():
        trace = open_loop_trace(7, 12, 1.5, vocab_size=cfg.vocab_size,
                                prompt_lens=(4, 12), gen_lens=(4, 12),
                                priority_levels=2)
        engine = _engine(model, params, cfg, max_queue=4)
        server = AsyncServer(engine,
                             breaker=CircuitBreaker(window=8,
                                                    failure_threshold=0.5,
                                                    min_volume=4,
                                                    cooldown=8.0))
        client = AsyncClient(server, RetryPolicy(max_attempts=3), seed=2)
        outcomes = asyncio.run(run_open_loop(server, client, trace))
        stats = {k: v for k, v in server.stats.items() if k != "results"}
        return ([(o.rid, o.status, o.attempts, tuple(o.tokens),
                  tuple(o.token_ticks)) for o in outcomes],
                stats, server.breaker.opens)

    assert run_once() == run_once()


# ------------------------------------------------------- straggler threshold

def test_straggler_threshold_surfaced_in_stats(fp32_setup):
    """The monitor's slow-step threshold is an engine constructor input
    (wired from launch/serve.py --straggler-threshold) and echoes through
    ``engine.stats`` for the final report."""
    model, params, cfg = fp32_setup
    eng = _engine(model, params, cfg,
                  straggler=StragglerMonitor(threshold=3.5))
    assert eng.stats["straggler_threshold"] == 3.5
    assert _engine(model, params, cfg).stats["straggler_threshold"] == 2.0
