"""CLE invariants (paper §4.1, appendix A): function preservation, range
matching r_i^(1) = r_i^(2), eq. 10 argmax condition, chain convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConvLayer,
    QuantSpec,
    equalization_scales,
    equalize_conv_chain,
    equalize_dense_pair,
    equalize_qk,
    equalize_vo,
    fake_quant,
    fold_norm,
    sqnr_db,
)


def _bad_ranges(key, shape, axis=-1, decades=2.0):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, shape)
    n = shape[axis]
    s = jnp.exp(jax.random.normal(k2, (n,)) * decades)
    shape_b = [1] * len(shape)
    shape_b[axis] = n
    return w * s.reshape(shape_b)


def test_dense_pair_preserves_relu_function():
    key = jax.random.PRNGKey(0)
    w1 = _bad_ranges(key, (24, 48))
    b1 = jax.random.normal(jax.random.PRNGKey(1), (48,))
    w2 = jax.random.normal(jax.random.PRNGKey(2), (48, 16))
    x = jax.random.normal(jax.random.PRNGKey(3), (128, 24))
    y0 = jax.nn.relu(x @ w1 + b1) @ w2
    res = equalize_dense_pair(w1, b1, w2)
    y1 = jax.nn.relu(x @ res.w1 + res.b1) @ res.w2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-4, atol=1e-4)


def test_dense_pair_preserves_gated_mlp_exactly():
    """up↔down CLE through a SwiGLU gate is exact for ANY scales (linear path)."""
    key = jax.random.PRNGKey(10)
    d, f = 16, 64
    wg = jax.random.normal(key, (d, f))
    wu = _bad_ranges(jax.random.PRNGKey(11), (d, f), decades=3.0)
    wd = jax.random.normal(jax.random.PRNGKey(12), (f, d))
    x = jax.random.normal(jax.random.PRNGKey(13), (32, d))
    y0 = (jax.nn.silu(x @ wg) * (x @ wu)) @ wd
    res = equalize_dense_pair(wu, None, wd)
    y1 = (jax.nn.silu(x @ wg) * (x @ res.w1)) @ res.w2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-4, atol=1e-4)


def test_ranges_match_after_equalization():
    key = jax.random.PRNGKey(20)
    w1 = _bad_ranges(key, (8, 32))
    w2 = _bad_ranges(jax.random.PRNGKey(21), (32, 8), axis=0)
    res = equalize_dense_pair(w1, None, w2)
    r1 = jnp.max(jnp.abs(res.w1), axis=0)
    r2 = jnp.max(jnp.abs(res.w2), axis=1)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-5)
    # eq. 10: the limiting channel is shared
    assert int(jnp.argmax(r1)) == int(jnp.argmax(r2))


def test_equalization_improves_per_tensor_quantization():
    key = jax.random.PRNGKey(30)
    w1 = _bad_ranges(key, (64, 128), decades=2.5)
    w2 = jax.random.normal(jax.random.PRNGKey(31), (128, 64))
    spec = QuantSpec(bits=8)
    res = equalize_dense_pair(w1, None, w2)
    x = jax.random.normal(jax.random.PRNGKey(32), (256, 64))
    y_fp = jax.nn.relu(x @ w1) @ w2
    y_q_orig = jax.nn.relu(x @ fake_quant(w1, spec)) @ fake_quant(w2, spec)
    y_q_eq = jax.nn.relu(x @ fake_quant(res.w1, spec)) @ fake_quant(res.w2, spec)
    assert float(sqnr_db(y_fp, y_q_eq)) > float(sqnr_db(y_fp, y_q_orig)) + 5.0


def test_scales_closed_form_eq11():
    r1 = jnp.array([1.0, 4.0, 0.25])
    r2 = jnp.array([1.0, 1.0, 4.0])
    s = equalization_scales(r1, r2)
    np.testing.assert_allclose(np.asarray(s), [1.0, 2.0, 0.25], rtol=1e-6)


def test_dead_channel_scale_is_one():
    s = equalization_scales(jnp.array([0.0, 1.0]), jnp.array([1.0, 1.0]))
    np.testing.assert_allclose(np.asarray(s), [1.0, 1.0])


def test_stacked_layers_broadcast():
    """Leading scan dims [L, ...] equalize in one call, layerwise independent."""
    L = 3
    key = jax.random.PRNGKey(40)
    w1 = _bad_ranges(key, (L, 8, 16), axis=-1)
    w2 = jax.random.normal(jax.random.PRNGKey(41), (L, 16, 8))
    res = equalize_dense_pair(w1, None, w2)
    for l in range(L):
        ref = equalize_dense_pair(w1[l], None, w2[l])
        np.testing.assert_allclose(np.asarray(res.w1[l]), np.asarray(ref.w1), rtol=1e-6)


class TestAttention:
    B, T, D, NQ, NKV, HD = 2, 8, 32, 8, 2, 16

    def _rope(self, v, T):
        *lead, n = v.shape
        hd = self.HD
        v = v.reshape(*lead, n // hd, hd)
        half = hd // 2
        ang = jnp.arange(T)[:, None] * (1.0 / (10000 ** (jnp.arange(half) / half)))
        cos, sin = jnp.cos(ang), jnp.sin(ang)
        v1, v2 = v[..., :half], v[..., half:]
        out = jnp.concatenate(
            [v1 * cos[:, None, :] - v2 * sin[:, None, :],
             v2 * cos[:, None, :] + v1 * sin[:, None, :]], -1)
        return out.reshape(*lead, n)

    def _attn(self, x, wq, wk, wv, bv, wo, bo):
        B, T, NQ, NKV, HD = self.B, self.T, self.NQ, self.NKV, self.HD
        q = self._rope(x @ wq, T).reshape(B, T, NQ, HD)
        k = self._rope(x @ wk, T).reshape(B, T, NKV, HD)
        v = (x @ wv + bv).reshape(B, T, NKV, HD)
        g = NQ // NKV
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        w = jax.nn.softmax(jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(HD), -1)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, T, NQ * HD)
        return o @ wo + bo

    def _params(self, seed=0, spread=1.5):
        ks = jax.random.split(jax.random.PRNGKey(seed), 8)
        D, NQ, NKV, HD = self.D, self.NQ, self.NKV, self.HD
        noise = jnp.exp(jax.random.normal(ks[7], (NKV * HD,)) * spread)
        wq = jax.random.normal(ks[0], (D, NQ * HD))
        wk = jax.random.normal(ks[1], (D, NKV * HD)) * noise
        wv = jax.random.normal(ks[2], (D, NKV * HD)) * noise
        wo = jax.random.normal(ks[3], (NQ * HD, D))
        bv = jax.random.normal(ks[4], (NKV * HD,))
        bo = jnp.zeros(D)
        x = jax.random.normal(ks[5], (self.B, self.T, D))
        return x, wq, wk, wv, bv, wo, bo

    def test_vo_pair_exact(self):
        x, wq, wk, wv, bv, wo, bo = self._params()
        y0 = self._attn(x, wq, wk, wv, bv, wo, bo)
        res = equalize_vo(wv, bv, wo, n_q=self.NQ, n_kv=self.NKV, head_dim=self.HD)
        y1 = self._attn(x, wq, wk, res.w1, res.b1, res.w2, bo)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-3, atol=1e-4)

    def test_vo_ranges_match(self):
        x, wq, wk, wv, bv, wo, bo = self._params()
        res = equalize_vo(wv, bv, wo, n_q=self.NQ, n_kv=self.NKV, head_dim=self.HD)
        r1 = jnp.max(jnp.abs(res.w1), axis=0)
        wo_g = res.w2.reshape(self.NKV, self.NQ // self.NKV, self.HD, self.D)
        r2 = jnp.max(jnp.abs(wo_g), axis=(1, 3)).reshape(-1)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-5)

    def test_qk_pair_exact_with_rope(self):
        x, wq, wk, wv, bv, wo, bo = self._params(seed=3)
        y0 = self._attn(x, wq, wk, wv, bv, wo, bo)
        res = equalize_qk(wq, None, wk, None, n_q=self.NQ, n_kv=self.NKV,
                          head_dim=self.HD, rope=True)
        y1 = self._attn(x, res.wq, res.wk, wv, bv, wo, bo)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-3, atol=1e-4)

    def test_qk_reduces_joint_range_product(self):
        x, wq, wk, wv, bv, wo, bo = self._params(seed=4, spread=2.0)
        res = equalize_qk(wq, None, wk, None, n_q=self.NQ, n_kv=self.NKV,
                          head_dim=self.HD, rope=True)
        def worst(w):
            return float(jnp.max(jnp.abs(w)))
        # total tensor range (the quantization grid) shrinks on the bad side
        assert worst(res.wk) * worst(res.wq) <= worst(wk) * worst(wq) * 1.01


def test_norm_fold_preserves_function():
    key = jax.random.PRNGKey(50)
    d, out = 16, 8
    g = jnp.exp(jax.random.normal(key, (d,)))
    w = jax.random.normal(jax.random.PRNGKey(51), (d, out))
    b = jax.random.normal(jax.random.PRNGKey(52), (out,))
    x = jax.random.normal(jax.random.PRNGKey(53), (32, d))

    def rms(x):
        return x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)

    y0 = (rms(x) * g) @ w + b
    ones, _, (w2,), (b2,) = fold_norm(g, [w], None, [b])
    y1 = (rms(x) * ones) @ w2 + b2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-5, atol=1e-6)


def test_layernorm_fold_with_shift():
    key = jax.random.PRNGKey(60)
    d, out = 12, 6
    g = jnp.exp(jax.random.normal(key, (d,)) * 0.3)
    beta = jax.random.normal(jax.random.PRNGKey(61), (d,))
    w = jax.random.normal(jax.random.PRNGKey(62), (d, out))
    x = jax.random.normal(jax.random.PRNGKey(63), (32, d))

    def ln(x):
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.var(x, -1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-6)

    y0 = (ln(x) * g + beta) @ w
    ones, zeros, (w2,), (b2,) = fold_norm(g, [w], beta, [None])
    y1 = (ln(x) * ones + zeros) @ w2 + b2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-5, atol=1e-5)


class TestConvChain:
    def _apply(self, x, layers):
        import jax.lax as lax

        h = x
        for i, layer in enumerate(layers):
            if layer.kind == "dense":
                h = h.reshape(h.shape[0], -1) @ layer.w
            else:
                groups = layer.w.shape[-1] if layer.kind == "depthwise" else 1
                h = lax.conv_general_dilated(
                    h, layer.w, (1, 1), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    feature_group_count=groups)
            if layer.b is not None:
                h = h + layer.b
            if i < len(layers) - 1:
                h = jax.nn.relu(h)
        return h

    def _chain(self, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 8)
        c0, c1, c2 = 8, 16, 8
        spread = jnp.exp(jax.random.normal(ks[6], (c1,)) * 2.0)
        expand = ConvLayer(jax.random.normal(ks[0], (1, 1, c0, c1)) * spread,
                           jax.random.normal(ks[1], (c1,)) * 0.1, "conv")
        dw = ConvLayer(jax.random.normal(ks[2], (3, 3, 1, c1)),
                       jax.random.normal(ks[3], (c1,)) * 0.1, "depthwise")
        proj = ConvLayer(jax.random.normal(ks[4], (1, 1, c1, c2)), None, "conv")
        x = jax.random.normal(ks[5], (2, 8, 8, c0))
        return x, [expand, dw, proj]

    def test_chain_preserves_function(self):
        x, layers = self._chain()
        y0 = self._apply(x, layers)
        new_layers, _ = equalize_conv_chain(layers)
        y1 = self._apply(x, new_layers)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=5e-4, atol=5e-4)

    def test_chain_converges_ranges(self):
        x, layers = self._chain(seed=1)
        new_layers, _ = equalize_conv_chain(layers, iterations=50)
        from repro.core.cle import _in_ranges, _out_ranges
        for i in range(len(new_layers) - 1):
            r1 = _out_ranges(new_layers[i])
            r2 = _in_ranges(new_layers[i + 1])
            np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-2)

    def test_chain_improves_quantized_sqnr(self):
        x, layers = self._chain(seed=2)
        spec = QuantSpec(bits=8)
        y_fp = self._apply(x, layers)

        def q(ls):
            return [l._replace(w=fake_quant(l.w, spec)) for l in ls]

        new_layers, _ = equalize_conv_chain(layers)
        snr_before = float(sqnr_db(y_fp, self._apply(x, q(layers))))
        snr_after = float(sqnr_db(y_fp, self._apply(x, q(new_layers))))
        assert snr_after > snr_before + 6.0
