"""SLO goodput benchmark for the async streaming front-end.

Two scenarios over the overload-safe server (``serving.AsyncServer`` +
``AsyncClient`` retry loop), all in engine-tick time so the numbers are
bit-deterministic for a seed and immune to CI wall noise:

  * **QPS sweep** — an open-loop Poisson trace offered at each rate in the
    sweep (arrivals never wait on completions), recording TTFT and
    per-token p50/p99 plus **goodput-under-SLO** (completed ok AND met the
    TTFT/per-token bounds) vs offered QPS. The acceptance shape is the
    knee: goodput tracks offered load below saturation, then flattens and
    degrades past it — and must NEVER collapse to zero while the circuit
    breaker is shedding (the breaker + priority rungs keep admitted work
    finishable instead of letting the queue death-spiral).
  * **Chaos under load** — the same open-loop client fleet with a seeded
    ``FaultPlan`` firing mid-load through the server's step hooks (page
    exhaustion holds, cancels, NaN injections), pool invariants checked
    after every step. Asserts: goodput degrades during the fault window
    and recovers after it (per-arrival-window SLO fractions), ZERO leaked
    pages once holds drain, and every unfaulted request's tokens
    bit-identical to a fault-free twin run.

Results persist to ``BENCH_slo.json``; the slo-smoke CI job regenerates the
smoke variant and diffs it against ``BENCH_slo.smoke.json`` on the PR page.

    PYTHONPATH=src python benchmarks/serve_slo.py          # full dims
    PYTHONPATH=src python benchmarks/serve_slo.py --smoke  # CI smoke
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    SLO,
    AsyncClient,
    AsyncServer,
    ChaosReport,
    CircuitBreaker,
    FaultInjector,
    FaultPlan,
    RetryPolicy,
    ServingEngine,
    ShedPolicy,
    assert_unfaulted_parity,
    count_leaked_pages,
    open_loop_trace,
    run_open_loop,
    summarize,
)

DEFAULT_JSON = pathlib.Path(__file__).resolve().parent / "BENCH_slo.json"


def make_setup(smoke: bool) -> dict:
    """Engine dims + sweep. The engine is the chaos-smoke config (4 slots,
    paged pool) whose decode capacity is ~0.35 req/tick on the 4..16-token
    trace, so the sweep brackets saturation from ~0.4x to ~2.5x."""
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b", smoke=True),
        name="qwen2-slo-bench" + ("-smoke" if smoke else ""),
    )
    base = {
        "cfg": cfg,
        "engine": dict(num_slots=4, max_len=48, prefill_chunk=8,
                       decode_horizon=4, page_size=8, max_queue=8),
        "prompt_lens": (4, 16), "gen_lens": (4, 16),
        "slo": SLO(ttft=32.0, per_token=4.0),
    }
    if smoke:
        base.update(rates=(0.15, 0.5, 2.0), n_requests=24,
                    chaos_rate=0.15, chaos_n=24)
    else:
        base.update(rates=(0.1, 0.25, 0.5, 1.0, 2.0), n_requests=48,
                    chaos_rate=0.15, chaos_n=36)
    return base


def _server(engine, **kw) -> AsyncServer:
    return AsyncServer(
        engine,
        breaker=CircuitBreaker(window=16, failure_threshold=0.5,
                               min_volume=4, cooldown=12.0),
        shed=ShedPolicy(),
        **kw,
    )


def _drive(model, params, setup, trace, *, seed, engine_kw=None,
           pre_step=(), post_step=(), timeout=None):
    """One open-loop run on a fresh engine; returns (outcomes, server,
    engine results, wall seconds)."""
    kw = dict(setup["engine"])
    kw.update(engine_kw or {})
    engine = ServingEngine(model, params, setup["cfg"], **kw)
    server = _server(engine, pre_step=pre_step, post_step=post_step)
    client = AsyncClient(server, RetryPolicy(max_attempts=4), seed=seed)
    t0 = time.perf_counter()
    outcomes = asyncio.run(run_open_loop(
        server, client, [dataclasses.replace(r) for r in trace],
        timeout=timeout))
    dt = time.perf_counter() - t0
    return outcomes, server, dict(engine.results), dt


# ----------------------------------------------------------------- sweep
def bench_sweep(model, params, setup: dict, *, seed: int = 0) -> list[dict]:
    """Goodput / latency percentiles vs offered QPS, with the knee and
    never-to-zero assertions from the module docstring."""
    slo = setup["slo"]
    points = []
    for rate in setup["rates"]:
        trace = open_loop_trace(
            seed, setup["n_requests"], rate, vocab_size=setup["cfg"].vocab_size,
            prompt_lens=setup["prompt_lens"], gen_lens=setup["gen_lens"],
            priority_levels=2)
        outcomes, server, _, dt = _drive(model, params, setup, trace,
                                         seed=seed)
        row = {"label": f"qps_{rate:g}", "offered_qps_nominal": rate,
               "wall_seconds": dt,
               **summarize(outcomes, slo=slo),
               "breaker_opens": server.breaker.opens,
               "admission": {k: v for k, v in server.stats.items()
                             if k != "results"}}
        points.append(row)
        print(f"  qps {rate:g}: offered {row['offered_qps']:.3f} → goodput "
              f"{row['goodput_qps']:.3f} req/tick "
              f"({row['goodput_fraction']:.0%}), ttft p50/p99 "
              f"{row['ttft_p50']:.1f}/{row['ttft_p99']:.1f}, per-token "
              f"p50/p99 {row['per_token_p50']:.2f}/"
              f"{row['per_token_p99']:.2f}, breaker opens "
              f"{row['breaker_opens']}, attempts {row['mean_attempts']:.2f}")

    # --- acceptance shape -------------------------------------------------
    assert len(points) >= 3, "sweep needs >= 3 offered-QPS points"
    first, last = points[0], points[-1]
    assert first["goodput_fraction"] >= 0.9, (
        f"below saturation goodput should track offered load, got "
        f"{first['goodput_fraction']:.2f} at {first['label']}")
    assert last["goodput_fraction"] < first["goodput_fraction"], (
        "no knee: goodput fraction did not decline past saturation")
    assert last["goodput_qps"] < 0.9 * last["offered_qps"], (
        "no knee: goodput still tracks offered load at the top rate")
    for row in points:
        assert row["goodput_qps"] > 0, (
            f"{row['label']}: goodput collapsed to zero"
            + (" while the breaker was shedding"
               if row["breaker_opens"] else ""))
    peak = max(p["goodput_qps"] for p in points)
    assert last["goodput_qps"] > 0.25 * peak, (
        "past-saturation goodput collapsed to "
        f"{last['goodput_qps']:.3f} vs peak {peak:.3f} — overload control "
        "is supposed to degrade gracefully, not fall off a cliff")
    return points


# ----------------------------------------------------------- chaos-under-load
def bench_chaos_under_load(model, params, setup: dict, *,
                           seed: int = 0) -> dict:
    """Seeded ``FaultPlan`` mid-load through the server's step hooks.

    Victims are drawn (seeded) from the middle third of the trace by
    arrival, so faults land inside the load and the pre/during/post
    windows all carry traffic. The fault window is measured in engine
    ticks from when exhaustion holds first activate to when the last one
    releases; outcomes are bucketed by arrival tick."""
    cfg, slo = setup["cfg"], setup["slo"]
    trace = open_loop_trace(
        seed + 1, setup["chaos_n"], setup["chaos_rate"],
        vocab_size=cfg.vocab_size, prompt_lens=setup["prompt_lens"],
        gen_lens=setup["gen_lens"], priority_levels=2)
    # headroom run: unbounded queue, rate well under capacity — every
    # unfaulted request must finish ok in BOTH runs for parity to be exact
    engine_kw = dict(max_queue=None)

    clean_outcomes, clean_server, clean_results, _ = _drive(
        model, params, setup, trace, seed=seed, engine_kw=engine_kw)
    assert all(o.ok for o in clean_outcomes), (
        "chaos baseline must run fault-free below saturation")
    total_steps = clean_server.steps

    # the plan: one long page-exhaustion window opening a third of the way
    # in (half the pool withheld), with seeded cancel + NaN victims from
    # the middle third of arrivals firing inside it
    rng = np.random.RandomState(seed)
    by_arrival = sorted(trace, key=lambda r: r.arrival)
    third = len(by_arrival) // 3
    mid = [r.rid for r in by_arrival[third:2 * third]]
    victims = [int(mid[i]) for i in
               rng.choice(len(mid), size=min(4, len(mid)), replace=False)]
    t0_step = max(1, total_steps // 3)
    num_pages = (setup["engine"]["num_slots"]
                 * setup["engine"]["max_len"] // setup["engine"]["page_size"])
    plan = FaultPlan(
        exhaust=[(t0_step, num_pages // 2, max(8, total_steps // 4))],
        cancels=[(t0_step + 2, rid) for rid in victims[:2]],
        nans=[(t0_step + 4, rid) for rid in victims[2:]],
    )

    window = {"start": None, "end": None}
    injector_box = {}

    def pre(step):
        inj = injector_box["inj"]
        inj.apply_due(step)
        if inj.holds_active() and window["start"] is None:
            window["start"] = injector_box["engine"].clock

    def post(step):
        inj = injector_box["inj"]
        was = inj.holds_active()
        inj.release_due(step)
        if was and not inj.holds_active():
            window["end"] = injector_box["engine"].clock
        injector_box["engine"].check_invariants()

    kw = dict(setup["engine"])
    kw.update(engine_kw)
    engine = ServingEngine(model, params, cfg, **kw)
    injector_box["inj"] = FaultInjector(engine, plan)
    injector_box["engine"] = engine
    server = _server(engine, pre_step=[pre], post_step=[post])
    client = AsyncClient(server, RetryPolicy(max_attempts=4), seed=seed)
    outcomes = asyncio.run(run_open_loop(
        server, client, [dataclasses.replace(r) for r in trace]))
    injector_box["inj"].drain()
    leaked = count_leaked_pages(engine)
    assert leaked == 0, f"{leaked} pages leaked after the fault window"

    faulted = plan.faulted_rids()
    report = ChaosReport(results=dict(engine.results),
                         outcomes={o.rid: o.status for o in outcomes},
                         counts={}, steps=server.steps,
                         leaked_pages=leaked, shed_rids=[])
    compared = assert_unfaulted_parity(report, clean_results, faulted)

    lo, hi = window["start"], window["end"]
    assert lo is not None and hi is not None and hi > lo, (
        f"fault window never materialized (start={lo}, end={hi})")

    def bucket(preds):
        rows = [o for o in outcomes if preds(o.arrival)]
        met = sum(1 for o in rows if slo.met(o))
        return {"n": len(rows), "n_slo_met": met,
                "goodput_fraction": met / len(rows) if rows else float("nan")}

    windows = {
        "pre": bucket(lambda a: a < lo),
        "during": bucket(lambda a: lo <= a <= hi),
        "post": bucket(lambda a: a > hi),
    }
    for name, w in windows.items():
        assert w["n"] > 0, f"no arrivals in the {name!r} window — the plan " \
            f"must land mid-load (window [{lo:.0f}, {hi:.0f}] ticks)"
    pre_f, dur_f, post_f = (windows[k]["goodput_fraction"]
                            for k in ("pre", "during", "post"))
    assert dur_f <= pre_f, (
        f"goodput did not degrade inside the fault window "
        f"(pre {pre_f:.2f} vs during {dur_f:.2f})")
    assert post_f >= dur_f, (
        f"goodput did not recover after the fault window "
        f"(during {dur_f:.2f} vs post {post_f:.2f})")
    assert post_f >= 0.9 * pre_f, (
        f"post-fault goodput {post_f:.2f} never returned to the pre-fault "
        f"level {pre_f:.2f}")

    out = {
        "n_requests": len(trace),
        "offered_qps_nominal": setup["chaos_rate"],
        "fault_window_ticks": [lo, hi],
        "plan": {"exhaust": plan.exhaust, "cancels": plan.cancels,
                 "nans": plan.nans},
        "unfaulted_parity_compared": compared,
        "leaked_pages": leaked,
        "windows": windows,
        "statuses": {s: sum(1 for o in outcomes if o.status == s)
                     for s in {o.status for o in outcomes}},
    }
    print(f"  chaos: fault window [{lo:.0f}, {hi:.0f}] ticks, goodput "
          f"pre/during/post {pre_f:.2f}/{dur_f:.2f}/{post_f:.2f}, "
          f"{compared} unfaulted requests bit-identical, 0 leaked pages")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for the CI slo-smoke job")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=str(DEFAULT_JSON), metavar="PATH")
    args = ap.parse_args(argv)

    setup = make_setup(args.smoke)
    cfg = setup["cfg"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    slo = setup["slo"]
    print(f"SLO: ttft <= {slo.ttft:g} ticks, per-token <= "
          f"{slo.per_token:g} ticks; sweep rates {setup['rates']} req/tick "
          f"x {setup['n_requests']} requests")
    sweep = bench_sweep(model, params, setup, seed=args.seed)
    print(f"chaos under load ({setup['chaos_rate']:g} req/tick x "
          f"{setup['chaos_n']} requests):")
    chaos = bench_chaos_under_load(model, params, setup, seed=args.seed)

    payload = {
        "benchmark": "serve_slo",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "smoke": args.smoke,
        "seed": args.seed,
        "slo": {"ttft_ticks": slo.ttft, "per_token_ticks": slo.per_token},
        "engine": setup["engine"],
        "sweep": sweep,
        "chaos": chaos,
    }
    p = pathlib.Path(args.json)
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {p}")
    return payload


if __name__ == "__main__":
    main()
