"""Diff fresh benchmark JSONs against committed baselines → CI step summary.

The bench-smoke job regenerates ``BENCH_serve.json`` / ``BENCH_kernels.json``
on every PR; this script compares them with the baselines committed under
``benchmarks/`` and writes a markdown tok/s delta table to
``$GITHUB_STEP_SUMMARY`` (stdout when unset), so perf regressions surface on
the PR page instead of only inside downloaded artifacts. Non-blocking by
design: it always exits 0 — regressions beyond the threshold are flagged in
the table, not enforced (CPU-runner wall noise would make a hard gate flaky).

    python benchmarks/diff_bench.py \
        --pair BENCH_serve.json benchmarks/BENCH_serve.smoke.json \
        --pair BENCH_kernels.json benchmarks/BENCH_kernels.smoke.json
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib

# wall-time noise on shared CI runners: only call out deltas beyond this
FLAG_PCT = 10.0
# drop pure wall-second counters and token dumps; keep rates and ratios
_SKIP = ("seconds", "tokens")


def _flatten(node, prefix="") -> dict:
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            if k in _SKIP:
                continue
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            # benchmark result lists carry a "label" — key on it so rows
            # stay comparable when list order changes between runs
            key = v["label"] if isinstance(v, dict) and "label" in v else str(i)
            out.update(_flatten(v, f"{prefix}.{key}" if prefix else key))
    elif isinstance(node, bool):
        pass
    elif isinstance(node, (int, float)):
        out[prefix] = float(node)
    return out


def _is_throughput(key: str) -> bool:
    """Headline rows only — the full payload rides in the uploaded artifact.
    tok/s is limited to the stepwise reference and the top-horizon fast path
    (the two ends of the sweep); ratios/speedups always make the table. For
    the SLO bench (BENCH_slo.json) the headline is goodput and the tail
    latencies per offered-QPS point, all in deterministic engine ticks."""
    if "speedup" in key or "reduction" in key or "sharded_vs_single" in key:
        return True
    if key.endswith(".tok_s"):
        return "variants.slow" in key or "variants.fast_h8" in key
    if ("goodput" in key or key.endswith(("ttft_p50", "ttft_p99",
                                          "per_token_p50", "per_token_p99"))):
        return True
    return key.endswith("tok_s_sharded") or key.endswith("tok_s_single")


def diff_table(fresh: dict, base: dict, name: str) -> list[str]:
    f_flat, b_flat = _flatten(fresh), _flatten(base)
    shared = sorted(k for k in f_flat if k in b_flat)
    rows = [k for k in shared if _is_throughput(k)]
    lines = [f"### {name}", ""]
    if fresh.get("smoke") != base.get("smoke"):
        lines += ["> baseline and fresh run used different dims "
                  "(smoke flag mismatch) — deltas are not comparable", ""]
    if not rows:
        lines += ["_no shared throughput metrics to compare_", ""]
        return lines
    lines += ["| metric | baseline | fresh | Δ |", "|---|---:|---:|---:|"]
    flagged = 0
    for k in rows:
        b, f = b_flat[k], f_flat[k]
        pct = (f - b) / b * 100 if b else float("nan")
        mark = " ⚠️" if abs(pct) > FLAG_PCT else ""
        flagged += bool(mark)
        lines.append(f"| `{k}` | {b:.3f} | {f:.3f} | {pct:+.1f}%{mark} |")
    only_f = sorted(k for k in f_flat if k not in b_flat and _is_throughput(k))
    if only_f:
        lines += ["", "new metrics (no baseline): "
                  + ", ".join(f"`{k}`={f_flat[k]:.3f}" for k in only_f)]
    lines += ["", f"{len(rows)} metrics compared, {flagged} beyond "
              f"±{FLAG_PCT:.0f}% (informative — wall noise on shared "
              f"runners; the trajectory lives in the committed baselines)",
              ""]
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", nargs=2, action="append", required=True,
                    metavar=("FRESH", "BASELINE"),
                    help="fresh-run JSON and its committed baseline")
    args = ap.parse_args(argv)

    lines = ["## Benchmark deltas vs committed baselines", ""]
    for fresh_path, base_path in args.pair:
        fp, bp = pathlib.Path(fresh_path), pathlib.Path(base_path)
        if not fp.exists() or not bp.exists():
            missing = fp if not fp.exists() else bp
            lines += [f"### {fp.name}", "", f"_skipped: {missing} missing_", ""]
            continue
        lines += diff_table(json.loads(fp.read_text()),
                            json.loads(bp.read_text()), fp.name)

    text = "\n".join(lines)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text + "\n")
    print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
