"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Accuracy tables emit their
metric in the ``derived`` column with us_per_call as the wall time of the
full table evaluation. The serving benchmark additionally persists a
machine-readable ``BENCH_serve.json`` (tok/s, speedups, occupancy, host-sync
and dispatch counts per token) so the serving-perf trajectory is tracked
across PRs — CI uploads it as an artifact.
"""
from __future__ import annotations

import argparse
import functools
import time


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names to run")
    ap.add_argument("--skip-lm", action="store_true",
                    help="skip the (slower) LM-family DFQ and serving "
                         "benchmarks")
    ap.add_argument("--serve-json", default=None, metavar="PATH",
                    help="where serve_engine persists BENCH_serve.json "
                         "(default: benchmarks/BENCH_serve.json)")
    ap.add_argument("--kernels-json", default=None, metavar="PATH",
                    help="where kernels_bench persists BENCH_kernels.json "
                         "(default: benchmarks/BENCH_kernels.json)")
    args, _ = ap.parse_known_args()

    from .kernels_bench import kernel_rows_persisted
    from .roofline_table import roofline_rows
    from .tables import ALL_TABLES

    benches = dict(ALL_TABLES)
    benches["kernels"] = functools.partial(
        kernel_rows_persisted, json_path=args.kernels_json)
    benches["roofline"] = roofline_rows
    if not args.skip_lm:
        from .lm_dfq import lm_dfq_all
        from .serve_engine import serve_rows

        benches["lm_dfq"] = lm_dfq_all
        benches["serve_engine"] = functools.partial(
            serve_rows, json_path=args.serve_json)

    selected = benches
    if args.only:
        keys = args.only.split(",")
        unknown = [k for k in keys if k not in benches]
        if unknown:
            ap.error(f"unknown benchmark(s) {unknown}; available with the "
                     f"current flags: {sorted(benches)}")
        selected = {k: benches[k] for k in keys}

    print("name,us_per_call,derived")
    for bench_name, fn in selected.items():
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            _emit(f"{bench_name}.ERROR", 0.0, repr(e)[:80])
            continue
        dt_us = (time.perf_counter() - t0) * 1e6
        for row_name, value in rows:
            _emit(f"{bench_name}.{row_name}", dt_us / max(len(rows), 1), value)


if __name__ == "__main__":
    main()
