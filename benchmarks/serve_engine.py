"""Continuous-batching engine vs the static whole-batch serving baseline.

Both paths share the same per-slot cache machinery and chunked prefill, so
the comparison isolates the scheduling policy:

  * **static** — every request gets its own lane up front (num_slots = N);
    lanes are never recycled, so the decode batch stays N-wide until the
    longest request finishes (the pre-engine ``launch/serve.py`` behavior,
    generalized to mixed lengths).
  * **engine** — a fixed pool of K << N slots with FIFO admission; finished
    requests retire and their slots are immediately refilled, so the decode
    batch stays small and busy.

On a skewed mixed-length trace (log-uniform lengths: many short requests, a
few long) the static batch decays to a nearly-empty wide batch while the
engine keeps occupancy high — that is the tokens/s gap reported here, plus
the KV-memory gap (K vs N live slots).

    PYTHONPATH=src python benchmarks/serve_engine.py
"""
from __future__ import annotations

import dataclasses
import time

import jax

import repro
from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServingEngine, synthetic_trace

# mid-size config: big enough that decode cost scales with batch width on
# CPU (smoke dims are dispatch-bound, which would mask the scheduling win)
CFG = dataclasses.replace(
    get_config("qwen2-0.5b", smoke=True),
    name="qwen2-serve-bench",
    n_layers=4, d_model=256, n_heads=8, head_dim=32, n_kv_heads=2,
    d_ff=1024, vocab_size=2048, max_seq=256,
)

N_REQUESTS = 24
SLOTS = 8
PREFILL_CHUNK = 16
PROMPT_LENS = (4, 32)
GEN_LENS = (4, 64)


def _run(engine: ServingEngine, trace) -> dict:
    """Serve ``trace`` on a warmed engine; returns tokens/s + occupancy."""
    gen0 = engine.stats["generated_tokens"]
    steps0 = engine.stats["decode_steps"]
    occ0 = engine.stats["occupancy_sum"]
    esteps0 = engine.stats["engine_steps"]
    t0 = time.perf_counter()
    results = engine.run(trace)
    dt = time.perf_counter() - t0
    esteps = engine.stats["engine_steps"] - esteps0
    return {
        "tok_s": (engine.stats["generated_tokens"] - gen0) / dt,
        "decode_steps": engine.stats["decode_steps"] - steps0,
        "occupancy": (engine.stats["occupancy_sum"] - occ0) / max(esteps, 1),
        "seconds": dt,
        "tokens": {r.rid: tuple(r.tokens) for r in results.values()},
    }


def bench_variant(label: str, model, params, max_len: int) -> dict:
    warmup = synthetic_trace(1, 4, vocab_size=CFG.vocab_size,
                             prompt_lens=PROMPT_LENS, gen_lens=(4, 8))
    trace = synthetic_trace(0, N_REQUESTS, vocab_size=CFG.vocab_size,
                            prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS)

    rows = {}
    for mode, slots in (("static", N_REQUESTS), ("engine", SLOTS)):
        eng = ServingEngine(model, params, CFG, num_slots=slots,
                            max_len=max_len, prefill_chunk=PREFILL_CHUNK)
        eng.run([dataclasses.replace(r, rid=1000 + r.rid) for r in warmup])
        rows[mode] = _run(eng, trace)
    # parity guard: both scheduling policies must emit identical tokens
    assert rows["static"]["tokens"] == rows["engine"]["tokens"], (
        "scheduling policy changed generated tokens — batch invariance broken"
    )
    speedup = rows["engine"]["tok_s"] / rows["static"]["tok_s"]
    print(f"{label:12s} engine {rows['engine']['tok_s']:8.1f} tok/s "
          f"(occ {rows['engine']['occupancy']:.2f}, "
          f"{rows['engine']['decode_steps']} steps, {SLOTS} slots)  |  "
          f"static {rows['static']['tok_s']:8.1f} tok/s "
          f"(occ {rows['static']['occupancy']:.2f}, "
          f"{rows['static']['decode_steps']} steps, {N_REQUESTS} slots)  |  "
          f"{speedup:.2f}x")
    return {"label": label, "speedup": speedup, **rows["engine"]}


def main():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 96  # fits max(ceil(32/16)*16, 32+64-1)

    print(f"trace: {N_REQUESTS} requests, prompt {PROMPT_LENS}, "
          f"gen {GEN_LENS} (log-uniform), closed arrivals")
    results = [bench_variant("fp32", model, params, max_len)]

    qm = repro.quantize(model, params=params, recipe="serve-w8a16")
    results.append(bench_variant("serve-w8a16", qm.model, qm.params, max_len))
    return results


def serve_rows():
    """benchmarks.run harness adapter: (name, value) CSV rows."""
    rows = []
    for r in main():
        rows.append((f"{r['label']}.engine_tok_s", round(r["tok_s"], 1)))
        rows.append((f"{r['label']}.speedup_vs_static", round(r["speedup"], 3)))
        rows.append((f"{r['label']}.mean_occupancy", round(r["occupancy"], 3)))
    return rows


if __name__ == "__main__":
    main()
